"""Fault-tolerance drill across every architecture family: crash 2 of 4
devices mid-decode and verify bit-exact recovery (paper §4.4), then show
the recovery-time story on the simulator (paper Figs. 15-17).

    PYTHONPATH=src python examples/recovery_drill.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core import simulator as sim
from repro.core.engine import PipeBoostEngine, generate
from repro.models import transformer as T


def main():
    key = jax.random.PRNGKey(0)
    print("functional drill (reduced models, CPU, 4 logical devices):")
    for arch, layers in [("qwen3-1.7b", 8), ("mamba2-780m", 8),
                         ("recurrentgemma-2b", 6), ("qwen2-moe-a2.7b", 4)]:
        cfg = get_arch(arch).reduced(n_layers=layers)
        params = T.init_params(cfg, key)
        batch = {"tokens": jax.random.randint(key, (2, 16), 0,
                                              cfg.vocab_size)}
        ref_eng = PipeBoostEngine(cfg, params, 4, max_len=64)
        ref_eng.load_round()
        ref = generate(ref_eng, batch, 8)
        eng = PipeBoostEngine(cfg, params, 4, max_len=64)
        eng.load_round()
        out = generate(eng, batch, 8, crash_at=4, crash_devices=[1, 2])
        ok = np.array_equal(np.asarray(ref), np.asarray(out))
        st = [s for e, s in eng.events if e == "recover"][0]
        detail = st.get("reconstruct", {})
        print(f"  {arch:22s} exact={ok}  kv_reused={detail.get('kv_reused', 0)}"
              f" full_prefill={detail.get('full_prefill', 0)}"
              f" skipped={detail.get('layers_skipped', 0)}")

    print("\nsimulated recovery (paper testbed, Mistral-7B, 4 devices):")
    pp = sim.simulate_loading_failure(
        get_arch("qwen3-1.7b"), sim.GPU_PAPER, 4, failed=[1, 2], mode="pp")
    fl = sim.simulate_loading_failure(
        get_arch("qwen3-1.7b"), sim.GPU_PAPER, 4, failed=[1, 2], mode="full")
    print(f"  loading-stage recovery: PP={pp.recovery_time:.2f}s "
          f"full-restart={fl.recovery_time:.2f}s "
          f"(cut {100*(1-pp.recovery_time/fl.recovery_time):.0f}%)")
    tl_pp = sim.simulate_inference_failure(get_arch("qwen3-1.7b"),
                                           sim.GPU_PAPER, 4, mode="pp")
    tl_fl = sim.simulate_inference_failure(get_arch("qwen3-1.7b"),
                                           sim.GPU_PAPER, 4, mode="full")
    halt = sum(1 for _, thr in tl_fl if thr == 0.0) * 0.25
    dip = min(thr for t, thr in tl_pp if t > 6.0)
    peak = tl_pp[0][1]
    print(f"  inference-stage crash : PP dips to {dip:.0f} tok/s "
          f"(from {peak:.0f}) with NO halt; full restart halts {halt:.1f}s")


if __name__ == "__main__":
    main()
