"""End-to-end training driver: data pipeline -> train loop -> async
checkpointing -> simulated crash -> restart-and-resume (exact).

Default is CPU-sized (~6M params, 120 steps, <2 min).  ``--model-100m``
scales to a ~100M-parameter qwen3-family config for real hardware runs
(same code path; on TPU pass --arch/--shape through launch/train.py).

    PYTHONPATH=src python examples/train_e2e.py [--steps N] [--model-100m]
"""
import argparse
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.training.checkpoint import Checkpointer
from repro.training.data import SyntheticLM
from repro.training.optimizer import AdamWConfig
from repro.training.train import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--model-100m", action="store_true")
    ap.add_argument("--ckpt-every", type=int, default=40)
    args = ap.parse_args()

    if args.model_100m:
        cfg = get_arch("qwen3-1.7b").reduced(
            n_layers=12, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
            d_ff=2048, vocab_size=32768)
    else:
        cfg = get_arch("qwen3-1.7b").reduced(n_layers=4, d_model=128,
                                             n_heads=4, n_kv_heads=2,
                                             d_ff=512, vocab_size=2048)
    n_params = cfg.param_count()
    print(f"training {cfg.name}-reduced: {n_params/1e6:.1f}M params")

    opt = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps,
                      grad_clip=1.0)
    state = init_train_state(cfg, jax.random.PRNGKey(0), jnp.float32)
    step_fn = jax.jit(make_train_step(cfg, opt, remat=False))
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8,
                     seed=7)
    ckdir = tempfile.mkdtemp(prefix="pipeboost_ckpt_")
    ck = Checkpointer(ckdir, keep=2)

    crash_at = args.steps // 2
    losses = []
    step = 0
    while step < crash_at:
        b = ds.next_batch()
        state, m = step_fn(state, {k: jnp.asarray(v) for k, v in b.items()})
        step += 1
        losses.append(float(m["loss"]))
        if step % args.ckpt_every == 0:
            ck.save(step, state, extra={"data": ds.state()}, async_=True)
        if step % 20 == 0:
            print(f"  step {step:4d} loss {m['loss']:.3f} "
                  f"lr {m['lr']:.2e} gnorm {m['grad_norm']:.2f}")
    ck.save(step, state, extra={"data": ds.state()}, async_=True)
    ck.wait()

    print(f"-- simulated crash at step {step}; "
          f"restarting from {ckdir} --")
    del state, ds
    tmpl = init_train_state(cfg, jax.random.PRNGKey(0), jnp.float32)
    state, extra = ck.restore(tmpl)
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8,
                     seed=7)
    ds.restore(extra["data"])
    print(f"   resumed at data step {ds.step}, opt step "
          f"{int(state.opt.step)}")

    while step < args.steps:
        b = ds.next_batch()
        state, m = step_fn(state, {k: jnp.asarray(v) for k, v in b.items()})
        step += 1
        losses.append(float(m["loss"]))
        if step % 20 == 0:
            print(f"  step {step:4d} loss {m['loss']:.3f}")

    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'DECREASED' if losses[-1] < losses[0] else 'FLAT'})")
    shutil.rmtree(ckdir, ignore_errors=True)


if __name__ == "__main__":
    main()
