"""Serve a base model + two LoRA adapters with continuous batching and
epoch-based adapter switching (paper §4.3.2 / Fig. 14) — end-to-end driver.

    PYTHONPATH=src python examples/serve_lora.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core.adapter_scheduler import (EagerPolicy, EpochSchedulerPolicy,
                                          simulate_adapter_serving)
from repro.lora.adapters import init_lora, merge_lora, randomize_lora
from repro.models import transformer as T
from repro.serving.engine import ServeRequest, ServingEngine


def main():
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=4)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)

    adapters = {}
    for name in ("math", "code"):
        lora = randomize_lora(jax.random.fold_in(key, hash(name) % 1000),
                              init_lora(key, cfg, rank=4, name=name))
        adapters[name] = merge_lora(params, lora)

    eng = ServingEngine(cfg, params, n_slots=3, max_len=96,
                        policy=EpochSchedulerPolicy(epoch_budget=3,
                                                    max_batch=3),
                        adapter_params=adapters)
    rng = np.random.default_rng(0)
    lanes = [None, "math", "code"]
    for i in range(9):
        eng.submit(ServeRequest(i, rng.integers(0, 250, size=8),
                                max_new_tokens=5, adapter=lanes[i % 3]))
    done = eng.run()
    print(f"served {len(done)} requests with "
          f"{eng.n_adapter_switches} adapter switches (epoch-batched)")
    for r in done[:6]:
        print(f"  req{r.rid} adapter={r.adapter or 'base':5s} "
              f"tokens={r.generated}")

    print("\nFig.14-style comparison (simulated, 20 RPS, 20% switch prob):")
    ep = simulate_adapter_serving(EpochSchedulerPolicy(epoch_budget=8),
                                  rps=20, horizon=30, switch_prob=0.2)
    eg = simulate_adapter_serving(EagerPolicy(), rps=20, horizon=30,
                                  switch_prob=0.2)
    print(f"  epoch-based: mean={ep['mean']*1e3:.0f}ms "
          f"var={ep['var']:.3f} merges={ep['merges']:.0f}")
    print(f"  eager      : mean={eg['mean']*1e3:.0f}ms "
          f"var={eg['var']:.3f} merges={eg['merges']:.0f}")
    print(f"  latency cut: {100*(1-ep['mean']/eg['mean']):.1f}% "
          f"(paper reports 63.1% at 25 RPS)")


if __name__ == "__main__":
    main()
