"""Quickstart: PipeBoost cold start, serving-during-loading, crash recovery.

Runs on CPU in ~a minute with a reduced model.  Shows the paper's three
headline behaviours end-to-end through the public API:

  1. the server is ready to infer after each device loads only 1/N of the
     model (pipeline-parallel loading);
  2. tokens served during background loading are identical to a fully
     loaded server;
  3. a 2-device crash mid-decode recovers exactly (layer reassignment +
     KV reconstruction).

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core.engine import PipeBoostEngine, generate
from repro.core import simulator as sim
from repro.models import transformer as T


def main():
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=8)
    print(f"model: {cfg.name} (reduced: {cfg.n_layers}L d={cfg.d_model})")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, cfg.vocab_size)}

    # --- 1. pipeline-parallel cold start --------------------------------
    eng = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    print(f"before loading: ready={eng.ready}")
    eng.load_round()                      # ONE segment per device (1/N each)
    print(f"after 1 round : ready={eng.ready}  "
          f"loaded={eng.loaded_map()}  chain={eng.chain()}")

    t0 = time.perf_counter()
    logits = eng.prefill(batch)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    print(f"first token in {time.perf_counter() - t0:.2f}s (CPU, reduced): "
          f"{np.asarray(tok)}")

    # --- 2. serve during background loading == fully loaded -------------
    e_early = PipeBoostEngine(cfg, params, 4, max_len=64)
    e_early.load_round()
    early = generate(e_early, batch, 8)
    e_full = PipeBoostEngine(cfg, params, 4, max_len=64)
    while e_full.load_round():
        pass
    full = generate(e_full, batch, 8)
    print(f"partial-load tokens == full-load tokens: "
          f"{np.array_equal(np.asarray(early), np.asarray(full))}")

    # --- 3. crash mid-decode + pipeline-parallel recovery ---------------
    e_crash = PipeBoostEngine(cfg, params, 4, max_len=64)
    e_crash.load_round()
    out = generate(e_crash, batch, 8, crash_at=4, crash_devices=[1, 2])
    print(f"crash@token4 (devices 1,2) tokens still equal: "
          f"{np.array_equal(np.asarray(out), np.asarray(full))}")
    print(f"engine events: {[e for e, _ in e_crash.events]}")

    # --- what this buys at real scale (byte-accurate simulator) ---------
    print("\ncold-start TTFT on the paper's 2xA100 testbed (simulated):")
    for strat in ("transformers", "serverlessllm", "pipeboost"):
        r = sim.simulate_cold_start(get_arch("pipeboost-opt-1.3b"),
                                    sim.GPU_PAPER, 2, strat)
        print(f"  {strat:14s} TTFT={r.ttft:.2f}s  (ready@{r.t_ready:.2f}s, "
              f"fully loaded@{r.t_full:.2f}s)")


if __name__ == "__main__":
    main()
