"""Sharding rules (DESIGN.md §4).

Scheme (works for every assigned arch — no head-count divisibility traps):

  * activations: batch over ('pod','data'), sequence over 'model'
                 (sequence/context parallelism);
  * weights: FSDP/ZeRO-3-style — each >=2D leaf shards its largest
             mesh-divisible dim over ('data','model') [+'pod' replication],
             gathered at use by SPMD;  embedding/lm_head shard the vocab dim;
  * KV caches: batch over ('pod','data'), cache-sequence over 'model'
               (decode attention becomes sequence-parallel flash-decode with
               a tiny logsumexp all-reduce);
  * SSM/LRU states: batch over ('pod','data'), heads/width over 'model';
  * optimizer state: same as the parameter it tracks (ZeRO).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch.mesh import data_axes


def _axsize(mesh: Mesh, names: Tuple[str, ...]) -> int:
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def shard_leaf_spec(shape: Tuple[int, ...], mesh: Mesh,
                    weight_axes: Tuple[str, ...],
                    skip_leading: int = 0) -> P:
    """Shard the largest dim (after ``skip_leading``) divisible by the
    weight-axis product; fall back to any dim divisible by 'model' alone;
    else replicate."""
    want = _axsize(mesh, weight_axes)
    dims = list(range(skip_leading, len(shape)))
    # largest first
    for d in sorted(dims, key=lambda i: -shape[i]):
        if shape[d] % want == 0 and shape[d] >= want:
            spec = [None] * len(shape)
            spec[d] = weight_axes if len(weight_axes) > 1 else weight_axes[0]
            return P(*spec)
    if "model" in mesh.axis_names:
        m = mesh.shape["model"]
        for d in sorted(dims, key=lambda i: -shape[i]):
            if shape[d] % m == 0 and shape[d] >= m:
                spec = [None] * len(shape)
                spec[d] = "model"
                return P(*spec)
    return P()


def param_specs(cfg: ArchConfig, params_shape, mesh: Mesh,
                mode: str = "fsdp") -> Any:
    """PartitionSpec pytree matching ``params_shape`` (a pytree of
    ShapeDtypeStruct or arrays).

    mode='fsdp'      — weights over ('data','model') (training / big archs)
    mode='replicated'— weights replicated (per-replica serving after the
                       PipeBoost strategy switch)
    mode='model'     — weights over 'model' only (serving TP-ish storage)
    """
    if mode == "replicated":
        return jax.tree.map(lambda a: P(), params_shape)

    if mode == "2dtp":
        # serving 2-D tensor parallelism: every block weight shards its
        # input dim over 'data' and output dim over 'model'; batch is
        # replicated.  Weight-resident decode: only activation-sized psums
        # cross the wire (EXPERIMENTS.md §Perf decode hillclimb).
        d_ax, m_ax = "data", "model"

        def rule2d(path, leaf):
            shape = leaf.shape
            names = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
            skip = 1 if "blocks" in names else 0
            if "embed" in names and leaf.ndim == 2:
                spec: list = [None, None]
                if shape[0] % mesh.shape[m_ax] == 0:
                    spec[0] = m_ax          # vocab over model
                if shape[1] % mesh.shape[d_ax] == 0:
                    spec[1] = d_ax          # d_model over data
                return P(*spec)
            if "lm_head" in names and leaf.ndim == 2:
                spec = [None, None]
                if shape[0] % mesh.shape[d_ax] == 0:
                    spec[0] = d_ax
                if shape[1] % mesh.shape[m_ax] == 0:
                    spec[1] = m_ax
                return P(*spec)
            if leaf.ndim < 2 + skip:
                return P()
            spec = [None] * leaf.ndim
            if shape[-2] % mesh.shape[d_ax] == 0:
                spec[-2] = d_ax
            if shape[-1] % mesh.shape[m_ax] == 0:
                spec[-1] = m_ax
            return P(*spec)

        return jax.tree_util.tree_map_with_path(rule2d, params_shape)

    waxes: Tuple[str, ...] = ("data", "model") if mode == "fsdp" else ("model",)
    waxes = tuple(a for a in waxes if a in mesh.axis_names)

    def rule(path, leaf):
        shape = leaf.shape
        names = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
        is_blocks = "blocks" in names
        skip = 1 if is_blocks else 0       # stacked layer dim never sharded
        if "embed" in names or "lm_head" in names:
            # shard the vocab dim (padded to %256) — biggest win for tied LMs
            vdim = 0 if "embed" in names else 1
            if shape[vdim] % _axsize(mesh, waxes) == 0:
                spec = [None] * len(shape)
                spec[vdim] = waxes if len(waxes) > 1 else waxes[0]
                return P(*spec)
        if leaf.ndim <= 1 + skip:          # norms / biases / scalars
            return P()
        return shard_leaf_spec(shape, mesh, waxes, skip_leading=skip)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def batch_specs(cfg: ArchConfig, batch_shape, mesh: Mesh,
                shard_seq: bool = True, dp="__auto__") -> Any:
    """tokens/labels (B, S) -> P(dp, 'model'); embeds (B, S, D);
    positions (B, S[, 3])."""
    if dp == "__auto__":
        dpa = data_axes(mesh)
        dp = dpa if len(dpa) > 1 else (dpa[0] if dpa else None)
    seq = "model" if (shard_seq and "model" in mesh.axis_names) else None

    def rule(leaf):
        nd = leaf.ndim
        if nd == 1:
            return P(dp)
        if nd == 2:
            return P(dp, seq)
        return P(dp, seq, *([None] * (nd - 2)))

    return jax.tree.map(rule, batch_shape)


def cache_specs(cfg: ArchConfig, cache_shape, mesh: Mesh,
                dp="__auto__") -> Any:
    """KV/state cache specs: (L, B, C, kv, hd) -> batch over dp, C over
    'model'; ssm/rec states shard heads/width over 'model'."""
    if dp == "__auto__":
        dpa = data_axes(mesh)
        dp = dpa if len(dpa) > 1 else (dpa[0] if dpa else None)
    m = "model" if "model" in mesh.axis_names else None
    msize = mesh.shape["model"] if m else 1

    def rule(path, leaf):
        names = [getattr(p, "key", None) for p in path]
        if "pos" in names:
            return P()
        shape = leaf.shape
        if "attn" in names:        # (L, B, C, kv, hd)
            cspec = m if (m and shape[2] % msize == 0) else None
            return P(None, dp, cspec, None, None)
        if "conv" in names:        # (L, B, K-1, ch)
            cspec = m if (m and shape[3] % msize == 0) else None
            return P(None, dp, None, cspec)
        if "state" in names:       # (L, B, H, P, N) ssm state
            hspec = m if (m and shape[2] % msize == 0) else None
            return P(None, dp, hspec, None, None)
        if "h" in names:           # (L, B, W) rglru state
            wspec = m if (m and shape[2] % msize == 0) else None
            return P(None, dp, wspec)
        return P()

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def named(mesh: Mesh, spec_tree) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def opt_specs(param_spec_tree) -> Any:
    """Optimizer m/v shard like their parameters (ZeRO)."""
    return param_spec_tree
