"""PipeBoost pipeline-parallel serve step as a shard_map lowering.

This is the paper's §4.3 technique in distributed form: stage *i* holds a
contiguous slice of the (stacked) layers; microbatches flow stage→stage via
``lax.ppermute`` over the 'stage' mesh axis on a GPipe belt schedule:

    tick t:  stage s computes microbatch (t - s), then the belt shifts.

All stages execute the same program (SPMD); off-belt stages compute on a
zeros buffer whose results are discarded — the standard JAX collective-
-permute pipeline (cf. GPipe [arXiv:1811.06965] / DAPPLE collective
schedules), TPU-native rather than a torch.distributed port.

Used for the TTFT-critical cold-start prefill (after strategy switching the
engine serves per-replica, so decode rides the standard lowering).  Uniform
layer stacks only (dense/GQA/MoE/SSM/encoder); the hybrid arch pipelines in
the functional engine but is excluded from this lowering (DESIGN.md §5).

See ``docs/ARCHITECTURE.md`` § "Distributed: the pipeline belt".
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.attention import default_block_q
from repro.launch.mesh import make_pipeline_mesh, pipeline_stages_for
from repro.models import transformer
from repro.models.transformer import _apply_norm


def stage_mesh(n_stages: int, n_data: Optional[int] = None) -> Mesh:
    """('data', 'stage') mesh over a SUBSET of the visible XLA devices.

    ``jax.make_mesh`` wants the axis product to equal the device count;
    elastic repartition needs stage counts that do NOT divide it (3 stages
    on an 8-device host after a 4→3 shrink).  This builds the mesh over the
    first ``n_data * n_stages`` devices instead — the idle remainder simply
    doesn't participate in the belt.  ``n_data`` defaults to the largest
    replica count that fits.
    """
    import numpy as np
    devs = jax.devices()
    if n_data is None:
        n_data = max(1, len(devs) // n_stages)
    need = n_data * n_stages
    if need > len(devs):
        raise ValueError(
            f"stage_mesh({n_stages=}, {n_data=}) needs {need} devices, "
            f"have {len(devs)}")
    arr = np.array(devs[:need]).reshape(n_data, n_stages)
    return Mesh(arr, ("data", "stage"))


def _uniform_kind(cfg: ArchConfig) -> str:
    kinds = set(cfg.layer_kinds())
    if len(kinds) != 1:
        raise ValueError(
            f"pipeline lowering needs a uniform layer stack; {cfg.name} has "
            f"{sorted(kinds)} (hybrid pipelines run via core/engine.py)")
    return next(iter(kinds))


def build_pipeline_prefill(cfg: ArchConfig, *, n_stages: int, n_micro: int,
                           mesh: Mesh, seq_len: int,
                           max_len: Optional[int] = None,
                           return_cache: bool = False):
    """Returns f(params, batch) -> last-token logits (B, V), shard_map'ed.

    params['blocks'][kind] leaves are (L, ...) sharded over 'stage' on dim 0;
    embed/head replicated (stage 0 embeds, last stage unembeds — replication
    costs HBM but keeps the belt code uniform; refining this is a recorded
    perf lever).

    ``return_cache=True`` (the engine's overlapped cold-start wiring): f
    additionally returns the per-layer decode state — attn KV sized to
    ``attn_cache_capacity(cfg, max_len)`` (ring-rolled like the standard
    prefill when windowed) or SSM conv/state — stacked (L, B, ...) with the
    layer dim sharded over 'stage' (each stage's KV lives where its
    segment's layers live) and B over 'data'.  Shapes match
    ``transformer.forward(mode="prefill")``'s cache exactly, so the fused
    per-replica decode step consumes it WITHOUT a retrace: the TTFT-
    critical prefill runs on the partial pipeline chain, then decoding
    strategy-switches seamlessly (paper §4.3.3).

    ``batch["last_index"]`` (B,) int32, optional: per-row true last prompt
    token for right-padded (bucketed) prompts — logits are gathered there
    (the serving engine's padded-admission contract, mirroring
    ``transformer.forward(last_index=...)``; attn-only, like bucketing).
    """
    kind = _uniform_kind(cfg)
    L_local = cfg.n_layers // n_stages
    cap = transformer.attn_cache_capacity(cfg, max_len or seq_len)

    def body(params, batch):
        # --- local (per-stage) program -----------------------------------
        stage = jax.lax.axis_index("stage")
        tokens = batch.get("tokens")
        embeds = batch.get("embeds")
        last_index = batch.get("last_index")
        B = (tokens if tokens is not None else embeds).shape[0]
        mb = B // n_micro
        D = cfg.d_model
        blocks = params["blocks"][kind]          # (L_local, ...) local slice

        positions = jnp.broadcast_to(jnp.arange(seq_len)[None, :],
                                     (mb, seq_len))

        def embed_mb(i):
            if tokens is not None:
                sl = jax.lax.dynamic_slice_in_dim(tokens, i * mb, mb, 0)
                return jnp.take(params["embed"], sl, axis=0)
            return jax.lax.dynamic_slice_in_dim(embeds, i * mb, mb, 0)

        def run_local_layers(x):
            def layer(x, p_l):
                if kind == "ssm":
                    from repro.models import mamba2
                    x, st = mamba2.ssm_block_fwd(cfg, p_l, x)
                    return x, (st if return_cache else None)
                x, kv, _ = transformer.attn_layer_fwd(
                    cfg, p_l, x, positions,
                    kv_write=cap if return_cache else None)
                return x, (kv if return_cache else None)
            x, st = jax.lax.scan(layer, x, blocks)
            return x, st

        n_ticks = n_micro + n_stages - 1
        logits_buf = jnp.zeros((n_micro, mb, cfg.padded_vocab), jnp.float32)
        # per-stage decode-state buffer: (L_local, n_micro, mb, ...) — each
        # stage only materializes its OWN layers' state (1/n_stages of it)
        if not return_cache:
            st_buf = {}
        elif kind == "ssm":
            ch = cfg.d_inner + 2 * cfg.ssm_state
            st_buf = {
                "conv": jnp.zeros((L_local, n_micro, mb, cfg.ssm_conv - 1,
                                   ch), jnp.dtype(cfg.dtype)),
                "state": jnp.zeros((L_local, n_micro, mb, cfg.ssm_heads,
                                    cfg.ssm_head_dim, cfg.ssm_state),
                                   jnp.float32),
            }
        else:
            hd = cfg.resolved_head_dim
            st_buf = {
                "k": jnp.zeros((L_local, n_micro, mb, cap, cfg.n_kv_heads,
                                hd), jnp.dtype(cfg.dtype)),
                "v": jnp.zeros((L_local, n_micro, mb, cap, cfg.n_kv_heads,
                                hd), jnp.dtype(cfg.dtype)),
            }

        def tick(carry, t):
            belt, logits_buf, st_buf = carry     # belt: (mb, S, D)
            mb_idx = t - stage                   # microbatch this stage sees
            feed = jnp.clip(mb_idx, 0, n_micro - 1)
            x_in = jnp.where(jnp.equal(stage, 0)[..., None, None],
                             embed_mb(feed), belt)
            x_out, st = run_local_layers(x_in)
            # last stage: final norm + last-token unembed (at the row's
            # true last prompt position when the batch is right-padded)
            if last_index is not None:
                li = jax.lax.dynamic_slice_in_dim(last_index, feed * mb,
                                                  mb, 0)
                x_last = x_out[jnp.arange(mb), li][:, None, :]
            else:
                x_last = x_out[:, -1:, :]
            xl = _apply_norm(cfg, params["final_norm"], x_last)
            head = params["embed"].T if cfg.tie_embeddings \
                else params["lm_head"]
            lg = jnp.einsum("bsd,dv->bsv", xl, head,
                            preferred_element_type=jnp.float32)[:, 0]
            on_belt = (mb_idx >= 0) & (mb_idx < n_micro)
            is_mine = jnp.equal(stage, n_stages - 1) & on_belt
            logits_buf = jax.lax.cond(
                is_mine,
                lambda b: jax.lax.dynamic_update_slice_in_dim(
                    b, lg[None], feed, 0),
                lambda b: b, logits_buf)
            if return_cache:
                new = ({"conv": st[0], "state": st[1]} if kind == "ssm"
                       else {"k": st[0], "v": st[1]})
                # off-belt ticks compute on the zeros belt — their state is
                # garbage and must not land in the buffer
                st_buf = jax.lax.cond(
                    on_belt,
                    lambda b: {key: jax.lax.dynamic_update_slice_in_dim(
                        b[key], new[key][:, None].astype(b[key].dtype),
                        feed, 1) for key in b},
                    lambda b: b, st_buf)
            # belt shift: stage s -> s+1 (last stage's output is dropped
            # by feeding zeros around the ring into stage 0, which ignores it)
            nxt = jax.lax.ppermute(
                x_out, "stage",
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, logits_buf, st_buf), None

        belt0 = jnp.zeros((mb, seq_len, D), jnp.dtype(cfg.dtype))
        (_, logits_buf, st_buf), _ = jax.lax.scan(
            tick, (belt0, logits_buf, st_buf), jnp.arange(n_ticks))
        # only the last stage wrote real logits; share them along the belt
        logits_buf = jax.lax.psum(logits_buf, "stage")
        logits = logits_buf.reshape(B, cfg.padded_vocab)
        if not return_cache:
            return logits
        state = {("ssm" if kind == "ssm" else "attn"): {
            key: st_buf[key].reshape((L_local, B) + st_buf[key].shape[3:])
            for key in st_buf}}
        return logits, state

    # --- shard_map wiring --------------------------------------------------
    def pspec_params(path, leaf):
        names = [getattr(p, "key", None) for p in path]
        if "blocks" in names and leaf.ndim >= 1:
            return P("stage", *([None] * (leaf.ndim - 1)))
        return P()          # embed/head/final_norm replicated per stage

    def f(params, batch):
        pspecs = jax.tree_util.tree_map_with_path(pspec_params, params)
        bspecs = jax.tree.map(
            lambda a: P("data", *([None] * (a.ndim - 1))), batch)
        if return_cache:
            # state leaves: layer dim over 'stage', batch dim over 'data'
            if kind == "ssm":
                state_specs = {"ssm": {"conv": P("stage", "data", None, None),
                                       "state": P("stage", "data", None,
                                                  None, None)}}
            else:
                kv_spec = P("stage", "data", None, None, None)
                state_specs = {"attn": {"k": kv_spec, "v": kv_spec}}
            out_specs = (P("data", None), state_specs)
        else:
            out_specs = P("data", None)
        with default_block_q(512):
            return shard_map(
                body, mesh=mesh,
                in_specs=(pspecs, bspecs),
                out_specs=out_specs,
                check_rep=False,
            )(params, batch)

    return f


def build_pipeline_prefill_seqchunk(cfg: ArchConfig, *, n_stages: int,
                                    n_chunks: int, mesh: Mesh,
                                    seq_len: int):
    """TeraPipe-style pipeline prefill: microbatches are SEQUENCE CHUNKS of
    the same requests [arXiv:2102.07988], not batch splits.

    With tiny per-replica batches (the cold-start regime), batch-split
    GPipe has n_micro <= B_local and drowns in bubbles (utilization
    n_micro/(n_micro+S-1)).  Chunking the sequence gives n_chunks = S/chunk
    microbatches regardless of batch: each stage keeps the KV of its local
    layers for already-seen chunks and attends causally (q_offset +
    kv_valid_len); the belt carries one (B, chunk, D) block per tick —
    also ~n_chunks x smaller hidden-state hops.  See EXPERIMENTS.md §Perf.
    """
    kind = _uniform_kind(cfg)
    if kind not in ("attn", "moe"):
        raise ValueError("seq-chunk pipeline needs attention KV semantics")
    assert seq_len % n_chunks == 0
    chunk = seq_len // n_chunks
    hd = cfg.resolved_head_dim

    def body(params, batch):
        stage = jax.lax.axis_index("stage")
        tokens = batch.get("tokens")
        embeds = batch.get("embeds")
        B = (tokens if tokens is not None else embeds).shape[0]
        D = cfg.d_model
        blocks = params["blocks"][kind]
        L_local = jax.tree.leaves(blocks)[0].shape[0]

        def embed_chunk(i):
            if tokens is not None:
                sl = jax.lax.dynamic_slice_in_dim(tokens, i * chunk, chunk, 1)
                return jnp.take(params["embed"], sl, axis=0)
            return jax.lax.dynamic_slice_in_dim(embeds, i * chunk, chunk, 1)

        n_ticks = n_chunks + n_stages - 1
        logits_buf = jnp.zeros((B, cfg.padded_vocab), jnp.float32)
        kv0 = jnp.zeros((L_local, 2, B, seq_len, cfg.n_kv_heads, hd),
                        jnp.dtype(cfg.dtype))

        def tick(carry, t):
            belt, kv, logits_buf = carry
            ci = jnp.clip(t - stage, 0, n_chunks - 1)     # chunk index here
            x_in = jnp.where(jnp.equal(stage, 0), embed_chunk(ci), belt)
            q_off = ci * chunk
            positions = q_off + jnp.broadcast_to(jnp.arange(chunk)[None, :],
                                                 (B, chunk))
            if cfg.mrope:  # text-like stub stream: t=h=w position ids
                positions = jnp.broadcast_to(positions[..., None],
                                             (B, chunk, 3))

            def layer(x, per):
                p_l, kv_l = per
                from repro.models.transformer import (_apply_norm, _ACTS,
                                                      _apply_mlp,
                                                      _project_qkv, _rope)
                from repro.models import attention as attn_lib
                from repro.models import moe as moe_lib
                h = _apply_norm(cfg, p_l["ln1"], x)
                q, k, v = _project_qkv(cfg, p_l, h)
                q = _rope(cfg, q, positions)
                k = _rope(cfg, k, positions)
                kv_l = jax.lax.dynamic_update_slice(
                    kv_l, jnp.stack([k, v]), (0, 0, q_off, 0, 0))
                # causal over [0, q_off + chunk): prefix chunks full,
                # current chunk causal — one blocked pass over the buffer
                o = attn_lib.finalize_partial(
                    attn_lib.attention_partial(
                        q, kv_l[0], kv_l[1], causal=True, window=0,
                        q_offset=q_off, k_offset=0,
                        kv_valid_len=q_off + chunk,
                        block_k=max(chunk, 1024)), q.dtype)
                o = o.reshape(B, chunk, -1) @ p_l["wo"]
                x = x + o
                h2 = _apply_norm(cfg, p_l["ln2"], x)
                if "router" in p_l["mlp"]:
                    y, _ = moe_lib.moe_mlp(cfg, p_l["mlp"], h2,
                                           _ACTS[cfg.act])
                else:
                    y = _apply_mlp(cfg, p_l["mlp"], h2)
                return x + y, kv_l

            x_out, kv = jax.lax.scan(layer, x_in, (blocks, kv))
            # last stage, last chunk: final norm + last-token unembed
            xl = _apply_norm(cfg, params["final_norm"], x_out[:, -1:, :])
            head = params["embed"].T if cfg.tie_embeddings \
                else params["lm_head"]
            lg = jnp.einsum("bsd,dv->bsv", xl, head,
                            preferred_element_type=jnp.float32)[:, 0]
            is_last = (jnp.equal(stage, n_stages - 1)
                       & jnp.equal(t - stage, n_chunks - 1))
            logits_buf = jnp.where(is_last, lg, logits_buf)
            nxt = jax.lax.ppermute(
                x_out, "stage",
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, kv, logits_buf), None

        belt0 = jnp.zeros((B, chunk, D), jnp.dtype(cfg.dtype))
        (_, _, logits_buf), _ = jax.lax.scan(
            tick, (belt0, kv0, logits_buf), jnp.arange(n_ticks))
        logits_buf = jax.lax.psum(logits_buf, "stage")
        return logits_buf

    def pspec_params(path, leaf):
        names = [getattr(p, "key", None) for p in path]
        if "blocks" in names and leaf.ndim >= 1:
            return P("stage", *([None] * (leaf.ndim - 1)))
        return P()

    def f(params, batch):
        pspecs = jax.tree_util.tree_map_with_path(pspec_params, params)
        bspecs = jax.tree.map(
            lambda a: P("data", *([None] * (a.ndim - 1))), batch)
        return shard_map(body, mesh=mesh, in_specs=(pspecs, bspecs),
                         out_specs=P("data", None), check_rep=False,
                         )(params, batch)

    return f


def build_pipeline_cell(cfg: ArchConfig, shape: ShapeConfig, *,
                        total_chips: int = 256, n_micro: Optional[int] = None,
                        seq_chunk: bool = False) -> Tuple[Any, tuple]:
    """Dry-run entry: returns (jitted fn, arg structs) for the pipeline
    prefill of one (arch x shape) cell."""
    if shape.kind != "prefill":
        raise ValueError("pipeline lowering targets the prefill (TTFT) step")
    n_stages = pipeline_stages_for(cfg.n_layers)
    B = shape.global_batch
    n_data = total_chips // n_stages
    while n_data > 1 and B % n_data != 0:
        n_data //= 2        # idle replicas rather than unshardable batch
    mesh = make_pipeline_mesh(n_stages, total=n_data * n_stages)
    n_micro = n_micro or max(2, min(8, B // max(1, n_data)))

    params_struct = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0),
                                        jnp.bfloat16))
    if cfg.family in ("audio", "vlm"):
        batch_struct = {"embeds": jax.ShapeDtypeStruct(
            (B, shape.seq_len, cfg.d_model), jnp.bfloat16)}
    else:
        batch_struct = {"tokens": jax.ShapeDtypeStruct((B, shape.seq_len),
                                                       jnp.int32)}

    if seq_chunk:
        n_chunks = max(n_stages, 8)
        f = build_pipeline_prefill_seqchunk(
            cfg, n_stages=n_stages, n_chunks=n_chunks, mesh=mesh,
            seq_len=shape.seq_len)
    else:
        f = build_pipeline_prefill(cfg, n_stages=n_stages, n_micro=n_micro,
                                   mesh=mesh, seq_len=shape.seq_len)

    def pspec_params(path, leaf):
        names = [getattr(p, "key", None) for p in path]
        if "blocks" in names and leaf.ndim >= 1:
            return P("stage", *([None] * (leaf.ndim - 1)))
        return P()

    pshard = jax.tree_util.tree_map_with_path(
        lambda pa, l: NamedSharding(mesh, pspec_params(pa, l)), params_struct)
    bshard = jax.tree.map(
        lambda a: NamedSharding(mesh, P("data", *([None] * (a.ndim - 1)))),
        batch_struct)
    fn = jax.jit(f, in_shardings=(pshard, bshard),
                 out_shardings=NamedSharding(mesh, P("data", None)))
    return fn, (params_struct, batch_struct)
