"""Activation-sharding policy hook.

Model code stays distribution-agnostic: it calls ``constrain(x, role)`` at
layer boundaries, which is a no-op unless a policy is installed (the
dry-run / launchers install one).  This is the MaxText
``with_logical_constraint`` pattern — explicit constraints stop the SPMD
partitioner from inventing catastrophic activation reshardings in the
backward pass (observed: "involuntary full rematerialization" + 136 GiB/dev
peaks without them).

Roles:
  act    (B, S, D)   — residual stream:      (dp, seq, None)
  ffh    (B, S, F)   — FFN/inner hidden:     (dp, seq, None)
  heads  (B, S, H, d)— per-head activations: (dp, seq, None, None)
  logits (B, S, V)   — unembedded logits:    (dp, seq, None)
"""
from __future__ import annotations

import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


class ShardingPolicy:
    def __init__(self, mesh: Mesh, *, dp_axes: Tuple[str, ...] = ("data",),
                 seq_axis: Optional[str] = "model",
                 vocab_axis: Optional[str] = None,
                 ff_axis: Optional[str] = None):
        self.mesh = mesh
        dp = tuple(a for a in dp_axes if a in mesh.axis_names)
        self.dp = dp if len(dp) > 1 else (dp[0] if dp else None)
        self.seq = seq_axis if (seq_axis in mesh.axis_names) else None
        # decode: keep logits vocab-sharded (a full-vocab gather of the
        # lm_head costs GBs/step; argmax needs only a tiny reduce)
        self.vocab = vocab_axis if (vocab_axis in mesh.axis_names) else None
        # decode TP: FFN hidden stays sharded over 'model' between the
        # column- and row-parallel matmuls (Megatron pairing)
        self.ff = ff_axis if (ff_axis in mesh.axis_names) else None

    @property
    def token_groups(self) -> int:
        """Number of token shards (dp x seq) — MoE routes per group so
        capacity/dispatch stay local (GShard per-group semantics)."""
        n = 1
        if self.dp is not None:
            for a in (self.dp if isinstance(self.dp, tuple) else (self.dp,)):
                n *= self.mesh.shape[a]
        if self.seq is not None:
            n *= self.mesh.shape[self.seq]
        return n

    def spec(self, role: str, ndim: int) -> P:
        if role == "tok":
            # token-major (T, ...) where T = B*S flattened
            axes = []
            if self.dp is not None:
                axes += list(self.dp) if isinstance(self.dp, tuple) else [self.dp]
            if self.seq is not None:
                axes.append(self.seq)
            lead = [tuple(axes) if len(axes) > 1 else (axes[0] if axes else None)]
            return P(*(lead + [None] * (ndim - 1)))
        lead = [self.dp, self.seq]
        if role == "logits" and self.vocab is not None and ndim >= 3:
            return P(*(lead + [None] * (ndim - 3) + [self.vocab]))
        if role == "ffh" and self.ff is not None and ndim >= 3:
            return P(*(lead + [None] * (ndim - 3) + [self.ff]))
        return P(*(lead + [None] * (ndim - 2)))


def set_policy(policy: Optional[ShardingPolicy]):
    _STATE.policy = policy


def get_policy() -> Optional[ShardingPolicy]:
    return getattr(_STATE, "policy", None)


class use_policy:
    def __init__(self, policy: Optional[ShardingPolicy]):
        self.policy = policy

    def __enter__(self):
        self.prev = get_policy()
        set_policy(self.policy)
        return self.policy

    def __exit__(self, *exc):
        set_policy(self.prev)


def constrain(x, role: str = "act"):
    """Pin ``x`` to the policy's sharding for ``role`` (no-op w/o policy)."""
    pol = get_policy()
    if pol is None or x.ndim < 2:
        return x
    try:
        spec = pol.spec(role, x.ndim)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(pol.mesh, spec))
    except Exception:
        return x
