import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
# The two lines above MUST run before any jax import: jax locks the device
# count on first init.  Everything else follows.

import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ARCH_IDS, SHAPES, ArchConfig, ShapeConfig,
                                cell_is_applicable, get_arch)
from repro.distributed.context import ShardingPolicy, use_policy
from repro.core import analytic
from repro.distributed import shardings as shd
from repro.launch import rooflines as rf
from repro.launch.mesh import data_axes, make_production_mesh
from repro.models import transformer
from repro.training.optimizer import AdamWConfig
from repro.training.train import init_train_state, make_train_step

"""Multi-pod dry-run driver (deliverable (e)).

For every (arch x shape x mesh) cell:
  1. build ShapeDtypeStruct inputs (no allocation) + NamedShardings,
  2. ``jit(step).lower(...).compile()`` against the production mesh,
  3. record memory_analysis / cost_analysis / collective schedule,
  4. derive the three roofline terms (depth-extrapolated; see rooflines.py).

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""


def input_specs(cfg: ArchConfig, shape: ShapeConfig, *,
                batch_override: Optional[int] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    f = jnp.bfloat16
    if shape.kind == "train":
        if cfg.family in ("audio", "vlm"):
            specs = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), f),
                     "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
            if cfg.mrope:
                specs["positions"] = jax.ShapeDtypeStruct((B, S, 3), jnp.int32)
            return specs
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.family in ("audio", "vlm"):
            specs = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), f)}
            if cfg.mrope:
                specs["positions"] = jax.ShapeDtypeStruct((B, S, 3), jnp.int32)
            return specs
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    # decode: one new token against a seq_len-deep cache
    if cfg.family == "vlm":
        specs = {"embeds": jax.ShapeDtypeStruct((B, 1, cfg.d_model), f),
                 "positions": jax.ShapeDtypeStruct((B, 1, 3), jnp.int32)}
        return specs
    return {"tokens": jax.ShapeDtypeStruct((B,), jnp.int32)}


def _struct(tree):
    return jax.eval_shape(lambda: tree) if not callable(tree) else jax.eval_shape(tree)


def cell_policy(cfg: ArchConfig, shape: ShapeConfig, mesh,
                params_mode: str = "fsdp") -> ShardingPolicy:
    B = shape.global_batch
    dp = data_axes(mesh)
    dp_total = 1
    for a in dp:
        dp_total *= mesh.shape[a]
    dp_eff = dp if (dp_total and B % dp_total == 0) else ()
    if params_mode == "2dtp":
        dp_eff = ()   # 2-D TP: batch replicated, 'data' is a weight axis
    seq = "model" if shape.kind != "decode" else None
    vocab = "model" if shape.kind == "decode" else None
    ff = "model" if shape.kind == "decode" else None
    return ShardingPolicy(mesh, dp_axes=dp_eff, seq_axis=seq,
                          vocab_axis=vocab, ff_axis=ff)


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, *,
               unroll: int = 1, params_mode: str = "fsdp",
               batch_override: Optional[int] = None) -> Tuple[Any, tuple, Any]:
    """Returns (jitted_fn, arg_structs, out_shardings_info)."""
    B = batch_override or shape.global_batch
    pol = cell_policy(cfg, shape, mesh, params_mode)
    batch_struct = input_specs(cfg, shape, batch_override=batch_override)
    batch_spec = shd.batch_specs(cfg, batch_struct, mesh,
                                 shard_seq=(shape.kind != "decode"),
                                 dp=pol.dp)

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        state_struct = jax.eval_shape(
            lambda: init_train_state(cfg, jax.random.PRNGKey(0), jnp.bfloat16))
        pspec = shd.param_specs(cfg, state_struct.params, mesh, mode="fsdp")
        ospec = type(state_struct.opt)(P(), pspec, pspec)
        sspec = type(state_struct)(pspec, ospec)
        step = make_train_step(cfg, opt_cfg, remat=True, unroll=unroll,
                               grad_compression=os.environ.get(
                                   "REPRO_GRAD_COMPRESSION", "none"))
        fn = jax.jit(
            step,
            in_shardings=(shd.named(mesh, sspec), shd.named(mesh, batch_spec)),
            out_shardings=(shd.named(mesh, sspec), None),
            donate_argnums=(0,),
        )
        return fn, (state_struct, batch_struct), sspec

    params_struct = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0),
                                        jnp.bfloat16))
    pspec = shd.param_specs(cfg, params_struct, mesh, mode=params_mode)

    if shape.kind == "prefill":
        def fn_(params, batch):
            return transformer.forward(cfg, params, batch, mode="prefill",
                                       max_len=shape.seq_len, unroll=unroll)
        with use_policy(pol):
            logits_cache_struct = jax.eval_shape(fn_, params_struct,
                                                 batch_struct)
        cspec = shd.cache_specs(cfg, logits_cache_struct[1], mesh, dp=pol.dp)
        out_sh = (NamedSharding(mesh, P(pol.dp, None)),
                  shd.named(mesh, cspec))
        fn = jax.jit(fn_,
                     in_shardings=(shd.named(mesh, pspec),
                                   shd.named(mesh, batch_spec)),
                     out_shardings=out_sh)
        return fn, (params_struct, batch_struct), pspec

    # decode
    cache_struct = jax.eval_shape(
        lambda: transformer.init_cache(cfg, B, shape.seq_len, jnp.bfloat16))
    cache_struct = dict(cache_struct)
    cache_struct["pos"] = jax.ShapeDtypeStruct((B,), jnp.int32)
    cspec = shd.cache_specs(cfg, cache_struct, mesh, dp=pol.dp)

    def fn_(params, batch, cache):
        return transformer.decode_step(cfg, params, batch, cache,
                                       unroll=unroll)

    vshard = "model" if cfg.padded_vocab % mesh.shape["model"] == 0 else None
    logits_sh = NamedSharding(mesh, P(pol.dp, vshard))
    fn = jax.jit(fn_,
                 in_shardings=(shd.named(mesh, pspec),
                               shd.named(mesh, batch_spec),
                               shd.named(mesh, cspec)),
                 out_shardings=(logits_sh, shd.named(mesh, cspec)),
                 donate_argnums=(2,))
    return fn, (params_struct, batch_struct, cache_struct), pspec


def _reduced_depth_cfg(cfg: ArchConfig, mult: int) -> Tuple[ArchConfig, int]:
    """Depth-reduced config for cost extrapolation; depth = mult x period."""
    period = len(cfg.block_pattern) or 1
    L = mult * period
    return dataclasses.replace(cfg, n_layers=L), L


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             skip_cost: bool = False, pipeline_mode: bool = False,
             params_mode: str = "fsdp",
             arch_cfg: Optional[ArchConfig] = None) -> Dict:
    cfg = arch_cfg or get_arch(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_is_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()

    if pipeline_mode:
        from repro.distributed.pipeline import build_pipeline_cell
        fn, structs = build_pipeline_cell(
            cfg, shape, total_chips=n_chips,
            seq_chunk=bool(os.environ.get("REPRO_PIPE_SEQCHUNK")))
        lowered = fn.lower(*structs)
    else:
        fn, structs, _ = build_cell(cfg, shape, mesh, params_mode=params_mode)
        with use_policy(cell_policy(cfg, shape, mesh, params_mode)):
            lowered = fn.lower(*structs)
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "axes": list(mesh.axis_names),
        "n_chips": n_chips,
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes + mem.output_size_in_bytes
            - mem.alias_size_in_bytes,
        },
    }

    if pipeline_mode and not skip_cost:
        # tick-loop body is counted once by cost analysis; scale by ticks.
        from repro.launch.mesh import pipeline_stages_for
        n_stages = pipeline_stages_for(cfg.n_layers)
        seqchunk = bool(os.environ.get("REPRO_PIPE_SEQCHUNK"))
        n_micro = (max(n_stages, 8) if seqchunk
                   else max(2, min(8, shape.global_batch
                                   // max(1, n_chips // n_stages))))
        n_ticks = n_micro + n_stages - 1
        coll_once = rf.total_collective_bytes(compiled.as_text())
        ca = compiled.cost_analysis() or {}
        # per-tick collective = parsed / (ticks appear once in HLO)
        coll = float(coll_once) * n_ticks
        flops_tick = float(ca.get("flops", 0.0)) * n_ticks             * (cfg.n_layers // n_stages)
        util = n_micro / n_ticks
        mf = analytic.model_flops(cfg, shape.global_batch, shape.seq_len,
                                  shape.kind)
        # analytic per-device compute: useful work / chips / utilization
        comp_s = (mf / n_chips / rf.PEAK_FLOPS) / util
        terms = rf.make_terms(comp_s * rf.PEAK_FLOPS,
                              float(ca.get("bytes accessed", 0.0)) * n_ticks,
                              coll)
        result["cost"] = {
            "pipeline": {"n_stages": n_stages, "n_micro": n_micro,
                         "n_ticks": n_ticks, "utilization": util,
                         "seq_chunk": seqchunk},
            "coll_bytes_per_device": coll,
            "roofline": terms.to_dict(),
            "model_flops_total": mf,
            "model_flops_per_device": mf / n_chips,
        }
    if not skip_cost and not pipeline_mode:
        # depth-extrapolated cost: two unrolled shallow lowerings
        mults = (2, 4) if (len(cfg.block_pattern) or 1) == 1 else (2, 4)
        costs = []
        for mult in mults:
            c_red, L = _reduced_depth_cfg(cfg, mult)
            fn_r, structs_r, _ = build_cell(c_red, shape, mesh, unroll=L,
                                            params_mode=params_mode)
            with use_policy(cell_policy(c_red, shape, mesh, params_mode)):
                low_r = fn_r.lower(*structs_r)
            comp_r = low_r.compile()
            ca = comp_r.cost_analysis() or {}
            coll = rf.total_collective_bytes(comp_r.as_text())
            costs.append({"L": L, "flops": float(ca.get("flops", 0.0)),
                          "bytes": float(ca.get("bytes accessed", 0.0)),
                          "coll": float(coll)})
        L1, L2, Lf = costs[0]["L"], costs[1]["L"], cfg.n_layers
        flops = rf.extrapolate(costs[0]["flops"], costs[1]["flops"], L1, L2, Lf)
        bbytes = rf.extrapolate(costs[0]["bytes"], costs[1]["bytes"], L1, L2, Lf)
        coll = rf.extrapolate(costs[0]["coll"], costs[1]["coll"], L1, L2, Lf)
        terms = rf.make_terms(flops, bbytes, coll)
        mf = analytic.model_flops(cfg, shape.global_batch,
                                  shape.seq_len if shape.kind != "decode" else 1,
                                  shape.kind)
        result["cost"] = {
            "per_layer_points": costs,
            "hlo_flops_per_device": flops,
            "hlo_bytes_per_device": bbytes,
            "coll_bytes_per_device": coll,
            "roofline": terms.to_dict(),
            "model_flops_total": mf,
            "model_flops_per_device": mf / n_chips,
            "useful_flops_ratio": (mf / n_chips) / flops if flops else 0.0,
        }
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-cost", action="store_true")
    ap.add_argument("--pipeline", action="store_true",
                    help="lower the PipeBoost pipeline-parallel serve step")
    ap.add_argument("--params-mode", default="fsdp",
                    choices=["fsdp", "model", "replicated", "2dtp"],
                    help="weight sharding strategy (serving TP = 'model')")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        from repro.configs.base import cells as cell_list
        cells = [(a, s) for a, s, ok, _ in cell_list() if ok]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'multipod' if mp else 'singlepod'}" + \
                ("__pipeline" if args.pipeline else "") + \
                ("_seqchunk" if (args.pipeline and
                                 os.environ.get("REPRO_PIPE_SEQCHUNK"))
                 else "") + \
                (f"__{args.params_mode}" if args.params_mode != "fsdp" else "")
            try:
                res = run_cell(arch, shape, multi_pod=mp,
                               skip_cost=args.skip_cost,
                               pipeline_mode=args.pipeline,
                               params_mode=args.params_mode)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(res, f, indent=1)
                if "skipped" in res:
                    print(f"[SKIP] {tag}: {res['skipped']}")
                    continue
                peak = res["memory"]["peak_per_device"] / 2**30
                line = f"[OK]   {tag}: compile={res['compile_s']}s peak/dev={peak:.2f}GiB"
                if "cost" in res:
                    r = res["cost"]["roofline"]
                    line += (f" dom={r['dominant']}"
                             f" c={r['compute_s']*1e3:.2f}ms"
                             f" m={r['memory_s']*1e3:.2f}ms"
                             f" n={r['collective_s']*1e3:.2f}ms")
                print(line, flush=True)
            except Exception as e:  # record failures, keep sweeping
                failures += 1
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
                with open(os.path.join(args.out, tag + ".err"), "w") as f:
                    f.write(traceback.format_exc())
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
