"""Distributed training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        [--steps N] [--reduced] [--ckpt-dir DIR] [--resume] \
        [--accum K] [--grad-compression bf16]

On a real TPU slice this initializes jax.distributed (one process per host),
builds the production mesh over the global device set, and shards per
repro/distributed/shardings.py.  On CPU (this container) it runs the same
code over the local device(s) with a degenerate mesh — the point is that
the program text is identical at every scale.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_arch
from repro.distributed import shardings as shd
from repro.distributed.context import ShardingPolicy, use_policy
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer
from repro.training.checkpoint import Checkpointer
from repro.training.data import SyntheticLM
from repro.training.optimizer import AdamWConfig
from repro.training.train import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config (default on cpu backend)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "bf16"])
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (requires >=256 devices)")
    ap.add_argument("--distributed-init", action="store_true",
                    help="call jax.distributed.initialize() (multi-host)")
    args = ap.parse_args(argv)

    if args.distributed_init:
        jax.distributed.initialize()

    cfg = get_arch(args.arch)
    on_cpu = jax.default_backend() == "cpu"
    if args.reduced or on_cpu:
        cfg = cfg.reduced()
    dtype = jnp.float32 if on_cpu else jnp.bfloat16

    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    policy = ShardingPolicy(mesh, dp_axes=("data",), seq_axis="model")
    print(f"mesh: {dict(mesh.shape)}  arch: {cfg.name} "
          f"({cfg.param_count()/1e6:.1f}M params)  backend: "
          f"{jax.default_backend()}")

    opt = AdamWConfig(lr=1e-3, warmup_steps=max(args.steps // 10, 1),
                      total_steps=args.steps)
    state = init_train_state(cfg, jax.random.PRNGKey(0), dtype)
    pspec = shd.param_specs(cfg, state.params, mesh, mode="fsdp")
    from jax.sharding import PartitionSpec as P
    sspec = type(state)(pspec, type(state.opt)(P(), pspec, pspec))
    state = jax.device_put(state, shd.named(mesh, sspec))

    step_fn = jax.jit(
        make_train_step(cfg, opt, remat=not on_cpu, accum=args.accum,
                        grad_compression=args.grad_compression),
        donate_argnums=(0,))

    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                     batch_size=args.batch, seed=0,
                     rank=jax.process_index(), world=jax.process_count())
    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ck and args.resume and ck.latest_step() is not None:
        state, extra = ck.restore(state, shardings=shd.named(mesh, sspec))
        ds.restore(extra["data"])
        start = extra["step"]
        print(f"resumed from step {start}")

    bspec = None
    t0 = time.time()
    with use_policy(policy):
        for step in range(start, args.steps):
            b = ds.next_batch()
            if bspec is None:
                bspec = shd.named(mesh, shd.batch_specs(cfg, b, mesh))
            b = jax.device_put({k: jnp.asarray(v) for k, v in b.items()},
                               bspec)
            state, m = step_fn(state, b)
            if (step + 1) % 10 == 0 or step + 1 == args.steps:
                dt = (time.time() - t0) / (step + 1 - start)
                print(f"step {step+1:5d} loss {float(m['loss']):.4f} "
                      f"lr {float(m['lr']):.2e} "
                      f"gnorm {float(m['grad_norm']):.2f} "
                      f"({dt:.2f}s/step)")
            if ck and (step + 1) % args.ckpt_every == 0:
                ck.save(step + 1, state,
                        extra={"data": ds.state(), "step": step + 1},
                        async_=True)
    if ck:
        ck.save(args.steps, state,
                extra={"data": ds.state(), "step": args.steps})
        ck.wait()
    print("done")


if __name__ == "__main__":
    main()
