"""Production mesh builders.

Functions, not module constants: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before any jax init).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's production mesh: 16x16 per pod, 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_pipeline_mesh(n_stages: int, *, total: Optional[int] = None):
    """Mesh for the PipeBoost pipeline lowering: ('data', 'stage').

    n_stages is arch-dependent (largest divisor of n_layers that divides the
    chip budget); the remaining chips become data-parallel pipeline replicas.
    """
    total = total or 256
    assert total % n_stages == 0, (total, n_stages)
    return jax.make_mesh((total // n_stages, n_stages), ("data", "stage"))


def make_host_mesh(axes: Tuple[str, ...] = ("data", "model")):
    """Degenerate mesh over however many local devices exist (CPU tests)."""
    n = len(jax.devices())
    shape = [1] * len(axes)
    shape[0] = n
    return jax.make_mesh(tuple(shape), axes)


def pipeline_stages_for(n_layers: int, max_stages: int = 16) -> int:
    """Largest s <= max_stages with s | n_layers and s | 256."""
    for s in range(max_stages, 0, -1):
        if n_layers % s == 0 and 256 % s == 0:
            return s
    return 1


def data_axes(mesh) -> Tuple[str, ...]:
    """The batch-sharding axes of a mesh (everything except 'model'/'stage')."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
