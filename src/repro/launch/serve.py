"""Serving launcher: PipeBoost cold start -> continuous-batched serving ->
strategy switch, with optional crash injection.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        [--devices 4] [--requests 8] [--crash-at 3] [--adapters 2]

CPU runs use reduced configs (functional path); the same engine drives
device_put-sharded weights on a real slice.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_arch
from repro.core.adapter_scheduler import EpochSchedulerPolicy
from repro.core.engine import PipeBoostEngine
from repro.lora.adapters import init_lora, merge_lora, randomize_lora
from repro.models import transformer as T
from repro.serving.engine import ServeRequest, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-1.7b")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--crash-at", type=int, default=-1,
                    help="crash device 1 after this many completions")
    ap.add_argument("--adapters", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if jax.default_backend() == "cpu":
        period = max(1, len(cfg.block_pattern) or 1)
        depth = ((2 * args.devices + period - 1) // period) * period
        cfg = cfg.reduced(n_layers=depth)  # >= 1 segment per device
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no serve loop")
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)

    # cold start through the PipeBoost engine
    eng = PipeBoostEngine(cfg, params, n_devices=args.devices, max_len=96)
    t0 = time.perf_counter()
    eng.load_round()
    print(f"ready after 1 loading round ({time.perf_counter()-t0:.2f}s "
          f"wall): chain={eng.chain()}")

    adapter_params = {}
    for i in range(args.adapters):
        lora = randomize_lora(jax.random.fold_in(key, i),
                              init_lora(key, cfg, rank=4, name=f"lora{i}"))
        adapter_params[f"lora{i}"] = merge_lora(params, lora)

    srv = ServingEngine(cfg, params, n_slots=args.slots, max_len=96,
                        policy=EpochSchedulerPolicy(epoch_budget=4,
                                                    max_batch=args.slots),
                        adapter_params=adapter_params)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        adapter = (f"lora{i % args.adapters}" if args.adapters and i % 2
                   else None)
        srv.submit(ServeRequest(i, rng.integers(0, min(cfg.vocab_size, 250),
                                                size=8),
                                max_new_tokens=args.new_tokens,
                                adapter=adapter))
    done = srv.run()
    print(f"served {len(done)} requests "
          f"({srv.n_adapter_switches} adapter switches)")
    for r in done:
        print(f"  req{r.rid} adapter={r.adapter or 'base':6s} "
              f"-> {r.generated}")

    if args.crash_at >= 0:
        print(f"injecting crash on device 1 of the PipeBoost engine...")
        batch = {"tokens": jnp.asarray(rng.integers(
            0, min(cfg.vocab_size, 250), size=(1, 8)), jnp.int32)}
        logits = eng.prefill(batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(args.new_tokens):
            if i == args.crash_at:
                eng.crash([1])
                stats = eng.recover()
                print(f"  recovered: {stats.get('reconstruct')}")
            tok = jnp.argmax(eng.decode(tok), -1).astype(jnp.int32)
        print("  decode continued through the crash")


if __name__ == "__main__":
    main()
