"""Serving launcher: PipeBoost cold start -> continuous-batched serving ->
strategy switch, with optional crash injection.

Single server (the seed path):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        [--devices 4] [--requests 8] [--crash-at 3] [--adapters 2]

Serverless cluster (router + autoscaler + cross-server crash re-routing):

    PYTHONPATH=src python -m repro.launch.serve --cluster \
        --servers 2 --requests 16 --crash-at 3

``--cluster`` replays a bursty arrival trace across N PipeBoost-backed
server replicas, optionally crashes one server after ``--crash-at``
completions (its in-flight requests re-route to survivors and it rejoins
via a fresh pipelined cold start), and prints TTFT/TBT percentiles, queue
depth, and GPU-seconds.  CPU runs use reduced configs (functional path);
the same engines drive device_put-sharded weights on a real slice.

See ``docs/ARCHITECTURE.md`` § "Launch".
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_arch
from repro.core.adapter_scheduler import EpochSchedulerPolicy
from repro.core.engine import PipeBoostEngine
from repro.lora.adapters import init_lora, merge_lora, randomize_lora
from repro.models import transformer as T
from repro.serving.engine import ServeRequest, ServingEngine


def run_cluster(cfg, params, args):
    """Bursty trace -> router -> autoscaled PipeBoost servers; prints the
    TTFT/TBT percentile metrics the paper's cluster claims live on."""
    from repro.cluster import (Autoscaler, AutoscalerConfig, ClusterConfig,
                               ClusterRouter, WallClock, burst_wave_trace,
                               make_dispatch)
    key = jax.random.PRNGKey(0)
    adapter_params = {}
    for i in range(args.adapters):
        lora = randomize_lora(jax.random.fold_in(key, i),
                              init_lora(key, cfg, rank=4, name=f"lora{i}"))
        adapter_params[f"lora{i}"] = merge_lora(params, lora)
    trace = burst_wave_trace(args.requests, base_rate=2.0,
                             wave_rate=8.0 * max(args.servers, 1),
                             wave_at=0.5, wave_len=1.0, seed=args.seed,
                             max_new_tokens=args.new_tokens,
                             adapters=tuple(adapter_params))
    ccfg = ClusterConfig(n_devices=args.devices, n_slots=args.slots)
    scaler = Autoscaler(AutoscalerConfig(target_queue_per_server=args.slots,
                                         max_servers=args.max_servers,
                                         ttft_slo_s=1.0))
    # the same router/scheduler code runs logical ticks (default,
    # deterministic) or wall time (--wall-clock, real-slice mode): the
    # clock is injected, never branched on
    router = ClusterRouter(cfg, params, n_servers=args.servers, ccfg=ccfg,
                           autoscaler=scaler, adapter_params=adapter_params,
                           dispatch=make_dispatch(args.dispatch),
                           clock=WallClock() if args.wall_clock else None)
    t0 = time.perf_counter()
    crash = args.crash_at if args.crash_at >= 0 else None
    done = router.run(trace, crash_after_completions=crash,
                      crash_server_id=min(1, args.servers - 1),
                      rejoin_after_ticks=20 if crash is not None else None,
                      engine=args.engine)
    wall = time.perf_counter() - t0
    s = router.metrics.summary()
    print(f"cluster: {int(s['n_completed'])}/{len(trace)} requests completed "
          f"({wall:.1f}s wall, {int(s['servers_max'])} servers peak, "
          f"{scaler.n_scale_ups} scale-ups, "
          f"{int(s['n_rerouted'])} crash-rerouted)")
    print(f"  TTFT  p50={s['ttft_p50']:.3f}s  p99={s['ttft_p99']:.3f}s  "
          f"mean={s['ttft_mean']:.3f}s")
    print(f"  TBT   p50={s['tbt_p50']:.3f}s  p99={s['tbt_p99']:.3f}s  "
          f"mean={s['tbt_mean']:.3f}s")
    print(f"  queue_depth_max={int(s['queue_depth_max'])}  "
          f"gpu_seconds={s['gpu_seconds']:.1f}  "
          f"throughput={s['throughput_tok_s']:.1f}tok/s")
    for t, kind, detail in router.metrics.events:
        print(f"  [t={t:6.2f}] {kind:9s} {detail}")
    if args.metrics_json:
        router.metrics.to_json(args.metrics_json)
        print(f"  metrics written to {args.metrics_json}")
    if int(s["n_completed"]) != len(trace):
        raise SystemExit("cluster run did not complete all requests")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-1.7b")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--crash-at", type=int, default=-1,
                    help="single server: crash device 1 after this many "
                         "completions; --cluster: crash server 1 after this "
                         "many completions (re-route + rejoin)")
    ap.add_argument("--adapters", type=int, default=0)
    ap.add_argument("--cluster", action="store_true",
                    help="serverless cluster mode: bursty trace across "
                         "--servers autoscaled PipeBoost servers")
    ap.add_argument("--servers", type=int, default=2)
    ap.add_argument("--max-servers", type=int, default=8)
    ap.add_argument("--dispatch", default="least_loaded",
                    choices=("least_loaded", "slo_aware", "adapter_affine"),
                    help="--cluster: dispatch policy "
                         "(cluster/scheduler.py)")
    ap.add_argument("--wall-clock", action="store_true",
                    help="--cluster: run the router off time.monotonic "
                         "instead of logical ticks (real-slice mode)")
    ap.add_argument("--engine", default="event", choices=("event", "tick"),
                    help="--cluster: replay loop — 'event' jumps the "
                         "clock across quiescent gaps (default), 'tick' "
                         "polls every tick (the equivalence oracle; "
                         "identical token streams)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-json", default="",
                    help="--cluster: also dump ClusterMetrics JSON here")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if jax.default_backend() == "cpu":
        n_dev = args.devices if not args.cluster else min(args.devices, 2)
        if args.cluster and n_dev != args.devices:
            print(f"[cpu] clamping --devices {args.devices} -> {n_dev} "
                  f"per server (reduced functional configs)")
        period = max(1, len(cfg.block_pattern) or 1)
        depth = ((2 * n_dev + period - 1) // period) * period
        cfg = cfg.reduced(n_layers=depth)  # >= 1 segment per device
        if args.cluster:
            args.devices = n_dev
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no serve loop")
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)

    if args.cluster:
        run_cluster(cfg, params, args)
        return

    # overlapped cold start through the PipeBoost engine: one loading
    # round flips `ready` (each device holds ~1/N of the model); the rest
    # of the segments stream in on a background fill thread WHILE the
    # serving engine below admits and decodes
    eng = PipeBoostEngine(cfg, params, n_devices=args.devices, max_len=96)
    t0 = time.perf_counter()
    eng.load_round()
    print(f"ready after 1 loading round ({time.perf_counter()-t0:.2f}s "
          f"wall): chain={eng.chain()}")
    eng.start_fill()                   # background fill: load || serve

    adapter_params = {}
    for i in range(args.adapters):
        lora = randomize_lora(jax.random.fold_in(key, i),
                              init_lora(key, cfg, rank=4, name=f"lora{i}"))
        adapter_params[f"lora{i}"] = merge_lora(params, lora)

    srv = ServingEngine(cfg, params, n_slots=args.slots, max_len=96,
                        policy=EpochSchedulerPolicy(epoch_budget=4,
                                                    max_batch=args.slots),
                        adapter_params=adapter_params)
    if eng.enable_pipeline_prefill():
        # multi-device XLA: admission prefills ride the shard_map belt
        # until the engine's strategy switch (same wiring as ClusterServer)
        srv.batcher.set_pipeline_prefill(eng.serving_pipeline_prefill,
                                         fits=eng.serving_pipeline_fits)
        srv.batcher.prefill_backend = (
            lambda: "pipeline" if eng.strategy == "pipeline" else "single")
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        adapter = (f"lora{i % args.adapters}" if args.adapters and i % 2
                   else None)
        srv.submit(ServeRequest(i, rng.integers(0, min(cfg.vocab_size, 250),
                                                size=8),
                                max_new_tokens=args.new_tokens,
                                adapter=adapter))
    done = srv.run()
    eng.stop_fill()
    while eng.load_round():     # finish any tail the thread didn't reach
        pass
    cs = eng.cold_start_stats()
    overlapped = cs["time_to_fully_loaded"] is None \
        or cs["time_to_fully_loaded"] > cs["time_to_ready"]
    print(f"served {len(done)} requests "
          f"({srv.n_adapter_switches} adapter switches)")
    print(f"  cold start: time_to_ready={cs['time_to_ready']:.3f}s "
          f"time_to_fully_loaded={cs['time_to_fully_loaded']:.3f}s "
          f"({cs['n_rounds']} fill rounds, {cs['loaded_bytes']}B; "
          f"serving overlapped loading={overlapped})")
    for r in done:
        print(f"  req{r.rid} adapter={r.adapter or 'base':6s} "
              f"-> {r.generated}")

    if args.crash_at >= 0:
        print(f"injecting crash on device 1 of the PipeBoost engine...")
        batch = {"tokens": jnp.asarray(rng.integers(
            0, min(cfg.vocab_size, 250), size=(1, 8)), jnp.int32)}
        logits = eng.prefill(batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(args.new_tokens):
            if i == args.crash_at:
                eng.crash([1])
                stats = eng.recover()
                print(f"  recovered: {stats.get('reconstruct')}")
            tok = jnp.argmax(eng.decode(tok), -1).astype(jnp.int32)
        print("  decode continued through the crash")


if __name__ == "__main__":
    main()
