"""Roofline-term extraction from compiled dry-run artifacts.

Terms (EXPERIMENTS.md §Roofline), per device (SPMD programs are per-device):
    compute    = HLO_FLOPs / peak_FLOPs            [s]
    memory     = HLO_bytes / HBM_bw                [s]
    collective = collective_bytes / link_bw        [s]

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (assignment-provided).

XLA's ``cost_analysis`` counts a while-loop (lax.scan) body ONCE, so raw
numbers from a scanned-layer-stack lowering undercount by ~n_layers.  The
dry-run therefore lowers two depth-reduced *unrolled* variants (L1, L2) and
linearly extrapolates:  m(L) = m(L1) + (m(L2)-m(L1)) / (L2-L1) * (L-L1).
Exact for uniform stacks; ≤3% bias for the 26-layer hybrid (documented).
Collective bytes are parsed from the post-SPMD optimized HLO the same way.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\]\S*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output bytes (per device) in an HLO module.

    all-reduce is charged 2x (ring RS+AG equivalent bytes on the wire).
    ``*-start`` async forms are counted once (the matching ``*-done`` carries
    no shape of its own in post-opt HLO).
    """
    out: Dict[str, int] = {"all-gather": 0, "all-reduce": 0,
                           "reduce-scatter": 0, "all-to-all": 0,
                           "collective-permute": 0}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        if kind == "all-reduce":
            b *= 2
        out[kind] += b
    return out


def total_collective_bytes(hlo_text: str) -> int:
    return sum(collective_bytes(hlo_text).values())


@dataclass
class RooflineTerms:
    flops: float               # per device
    bytes_accessed: float      # per device (HBM proxy)
    coll_bytes: float          # per device
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def total_s(self) -> float:
        # optimistic perfectly-overlapped lower bound = max term
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> Dict:
        return {"flops": self.flops, "bytes": self.bytes_accessed,
                "coll_bytes": self.coll_bytes, "compute_s": self.compute_s,
                "memory_s": self.memory_s, "collective_s": self.collective_s,
                "dominant": self.dominant}


def make_terms(flops: float, bytes_accessed: float,
               coll_bytes: float) -> RooflineTerms:
    return RooflineTerms(
        flops=flops, bytes_accessed=bytes_accessed, coll_bytes=coll_bytes,
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_accessed / HBM_BW,
        collective_s=coll_bytes / ICI_BW,
    )


def extrapolate(m1: float, m2: float, l1: int, l2: int, l_full: int) -> float:
    """Linear-in-depth extrapolation of a cost metric."""
    per_layer = (m2 - m1) / max(l2 - l1, 1)
    return max(0.0, m1 + per_layer * (l_full - l1))
