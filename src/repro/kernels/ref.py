"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Each reference is the naive O(everything-in-memory) math — no tiling, no
online softmax — so a kernel bug cannot be hidden by shared structure.

See ``docs/ARCHITECTURE.md`` § "Models and kernels".
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0, q_offset=0,
                        scale=None):
    """q: (B, Hq, Sq, d); k/v: (B, Hkv, Sk, d) -> (B, Hq, Sq, d)."""
    B, Hq, Sq, d = q.shape
    _, Hkv, Sk, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else d ** -0.5
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None, None], p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vv.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, lens, *, slot_mask=None, scale=None):
    """q: (B, Hq, d); k/v: (B, Hkv, C, d); lens: (B,) -> (B, Hq, d).

    ``slot_mask`` (B, C): per-slot validity (ring-buffer eviction), ANDed
    with the prefix-length mask — the oracle for the masked kernel path."""
    B, Hq, d = q.shape
    _, Hkv, C, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else d ** -0.5
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhd,bhcd->bhc", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    mask = jnp.arange(C)[None, :] < lens[:, None]          # (B, C)
    if slot_mask is not None:
        mask = mask & jnp.asarray(slot_mask, bool)
    s = jnp.where(mask[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[:, None, :], p, 0.0)
    return jnp.einsum("bhc,bhcd->bhd", p,
                      vv.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x, dt, A, Bm, Cm) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential recurrent oracle.  x: (B,S,H,P); dt: (B,S,H); A: (H,);
    Bm/Cm: (B,S,N) -> (y (B,S,H,P), state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32

    def step(state, t):
        xt = x[:, t].astype(f32)                   # (B,H,P)
        dtt = dt[:, t].astype(f32)                 # (B,H)
        bt = Bm[:, t].astype(f32)                  # (B,N)
        ct = Cm[:, t].astype(f32)
        dA = jnp.exp(dtt * A)                      # (B,H)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dtt, bt, xt)
        state = state * dA[..., None, None] + dBx
        y = jnp.einsum("bn,bhpn->bhp", ct, state)
        return state, y

    state0 = jnp.zeros((Bsz, H, P, N), f32)
    state, ys = jax.lax.scan(step, state0, jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)     # (B,S,H,P)
    return y, state


def rglru_scan_ref(log_a, bx, h0=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential oracle. log_a/bx: (B,S,W) -> (h_seq (B,S,W), h_T (B,W))."""
    B, S, W = log_a.shape
    h = jnp.zeros((B, W), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        h = jnp.exp(log_a[:, t].astype(jnp.float32)) * h \
            + bx[:, t].astype(jnp.float32)
        return h, h

    h, ys = jax.lax.scan(step, h, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1).astype(log_a.dtype), h


def lora_merge_ref(W, A, B, scale):
    delta = jnp.einsum("ldr,lro->ldo", A.astype(jnp.float32),
                       B.astype(jnp.float32))
    return (W.astype(jnp.float32) + scale * delta).astype(W.dtype)
