"""Fused merged-LoRA weight update — Pallas TPU kernel (paper §4.3.2).

W' = W + scale * (A @ B), computed tile-by-tile: each grid step owns one
MXU-aligned (Bi, Bj) tile of W in VMEM, computes its slice of the low-rank
product from A's row block and B's column block, and adds in place — the
rank-r delta is never materialized in HBM.  This is the on-device TPU
replacement for the paper's CPU-side adapter merge: one streaming pass over
W at HBM bandwidth (the merge cost charged at every epoch-based adapter
switch).

Layouts: W (L, Din, Dout); A (L, Din, r); B (L, r, Dout); stacked over
layers L (grid dim 0), matching the model zoo's parameter layout.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lora_kernel(w_ref, a_ref, b_ref, o_ref, *, scale: float):
    w = w_ref[0]                                   # (Bi, Bj)
    a = a_ref[0].astype(jnp.float32)               # (Bi, r)
    b = b_ref[0].astype(jnp.float32)               # (r, Bj)
    delta = jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())))
    o_ref[0] = (w.astype(jnp.float32) + scale * delta).astype(o_ref.dtype)


def lora_merge(W: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
               scale: float, *, block_i: int = 256, block_j: int = 256,
               interpret: bool = True) -> jnp.ndarray:
    """W: (L, Din, Dout); A: (L, Din, r); B: (L, r, Dout) -> W + scale*A@B."""
    L, Din, Dout = W.shape
    r = A.shape[-1]
    block_i = min(block_i, Din)
    block_j = min(block_j, Dout)
    pad_i = (-Din) % block_i
    pad_j = (-Dout) % block_j
    Wp = jnp.pad(W, ((0, 0), (0, pad_i), (0, pad_j))) if (pad_i or pad_j) else W
    Ap = jnp.pad(A, ((0, 0), (0, pad_i), (0, 0))) if pad_i else A
    Bp = jnp.pad(B, ((0, 0), (0, 0), (0, pad_j))) if pad_j else B
    ni = (Din + pad_i) // block_i
    nj = (Dout + pad_j) // block_j

    out = pl.pallas_call(
        functools.partial(_lora_kernel, scale=scale),
        grid=(L, ni, nj),
        in_specs=[
            pl.BlockSpec((1, block_i, block_j), lambda l, i, j: (l, i, j)),
            pl.BlockSpec((1, block_i, r), lambda l, i, j: (l, i, 0)),
            pl.BlockSpec((1, r, block_j), lambda l, i, j: (l, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_i, block_j),
                               lambda l, i, j: (l, i, j)),
        out_shape=jax.ShapeDtypeStruct(Wp.shape, W.dtype),
        interpret=interpret,
    )(Wp, Ap, Bp)
    return out[:, :Din, :Dout]
