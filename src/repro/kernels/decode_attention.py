"""Flash-decode attention — Pallas TPU kernel.

One new query token against a long KV cache (the ``decode_32k`` /
``long_500k`` hot loop).  Split-K over the cache: grid (B, Hq, nk) with the
cache-block dimension innermost/sequential; online-logsumexp partials merge
in VMEM scratch.  Per-batch ``lens`` (valid cache entries — continuous
batching gives every slot its own length) is prefetched as a scalar so the
mask needs no extra HBM traffic.

Zero-copy serving mode: pass ``k_new``/``v_new`` (the current token's K/V,
not yet written to the cache) and the kernel folds them into the final
split-K block's online-softmax state — the cache is only *read*, so the
serving engine can defer the single-row cache write to one donated
post-scan scatter instead of rewriting cache-sized buffers every layer.

Ring-buffer (windowed) caches: pass ``slot_mask`` (B, C) — validity there
is per *slot*, not a prefix length (the slot the new token will overwrite
holds the evicted, out-of-window entry and must not be attended).  The
mask rides the same split-K blocking as K/V, so the windowed zero-copy
path no longer has to fall back to the XLA lowering.

Layouts: q (B, Hq, d); k/v (B, Hkv, C, d); lens (B,) int32;
k/v_new (B, Hkv, 1, d); slot_mask (B, C) bool/int -> out (B, Hq, d).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(lens_ref, q_ref, k_ref, v_ref, *rest, scale: float,
                   block_k: int, n_k: int, merge_new: bool,
                   masked: bool):
    smask_ref = None
    if masked:
        smask_ref, rest = rest[0], rest[1:]
    if merge_new:
        knew_ref, vnew_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        o_ref, acc_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (1, d) row
    k = k_ref[0, 0].astype(jnp.float32)                  # (Bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (1, Bk)
    valid = lens_ref[b]
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    mask = k_pos < valid
    if masked:
        # per-slot validity (ring buffers): ANDed with the prefix-length
        # mask, exactly like the XLA lowering's kv_slot_mask
        mask = mask & (smask_ref[...] != 0)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _fin():
        m = m_ref[...]
        l = l_ref[...]
        acc = acc_ref[...]
        if merge_new:
            # fold the current (not-yet-cached) token into the softmax state
            kn = knew_ref[0, 0].astype(jnp.float32)          # (1, d)
            vn = vnew_ref[0, 0].astype(jnp.float32)
            s_new = jax.lax.dot_general(q, kn, (((1,), (1,)), ((), ())))
            m2 = jnp.maximum(m, s_new)
            c = jnp.exp(m - m2)
            p_new = jnp.exp(s_new - m2)
            l = l * c + p_new
            acc = acc * c + p_new * vn
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :] = (acc / l)[0].astype(o_ref.dtype)


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     lens: jnp.ndarray, *, k_new: Optional[jnp.ndarray] = None,
                     v_new: Optional[jnp.ndarray] = None,
                     slot_mask: Optional[jnp.ndarray] = None,
                     scale: Optional[float] = None,
                     block_k: int = 512,
                     interpret: bool = True) -> jnp.ndarray:
    """q: (B, Hq, d); k/v: (B, Hkv, C, d); lens: (B,) -> (B, Hq, d).

    With ``k_new``/``v_new`` (B, Hkv, 1, d) the current token is attended
    as if written at position ``lens`` (zero-copy serving mode).  With
    ``slot_mask`` (B, C) only slots where the mask is nonzero are attended
    (ring-buffer eviction), ANDed with the ``lens`` prefix mask."""
    B, Hq, d = q.shape
    _, Hkv, C, _ = k.shape
    G = Hq // Hkv
    merge_new = k_new is not None
    masked = slot_mask is not None
    scale = scale if scale is not None else d ** -0.5
    block_k = min(block_k, C)
    pad = (-C) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_k = (C + pad) // block_k
    q4 = q[:, :, None, :]                                 # (B, Hq, 1, d)

    kernel = functools.partial(_decode_kernel, scale=scale,
                               block_k=block_k, n_k=n_k, merge_new=merge_new,
                               masked=masked)
    in_specs = [
        pl.BlockSpec((1, 1, 1, d), lambda b, h, ki, lens: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, block_k, d),
                     lambda b, h, ki, lens: (b, h // G, ki, 0)),
        pl.BlockSpec((1, 1, block_k, d),
                     lambda b, h, ki, lens: (b, h // G, ki, 0)),
    ]
    inputs = [q4, k, v]
    if masked:
        sm = jnp.asarray(slot_mask, jnp.int32)
        if pad:
            sm = jnp.pad(sm, ((0, 0), (0, pad)))
        in_specs.append(
            pl.BlockSpec((1, block_k), lambda b, h, ki, lens: (b, ki)))
        inputs.append(sm)
    if merge_new:
        in_specs += [
            pl.BlockSpec((1, 1, 1, d), lambda b, h, ki, lens: (b, h // G, 0, 0)),
            pl.BlockSpec((1, 1, 1, d), lambda b, h, ki, lens: (b, h // G, 0, 0)),
        ]
        inputs += [k_new, v_new]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, d), lambda b, h, ki, lens: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, d), q.dtype),
        interpret=interpret,
    )(lens.astype(jnp.int32), *inputs)
    return out
