"""Jit'd public wrappers around the Pallas kernels.

On this CPU container kernels execute in interpret mode (Python semantics,
exact math); on TPU the same calls compile to Mosaic.  ``interpret`` is
resolved from the backend unless forced.  Layout adapters translate from
the model zoo's (B, S, H, d) convention to the kernels' (B, H, S, d).

See ``docs/ARCHITECTURE.md`` § "Models and kernels".
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import lora_merge as _lm
from repro.kernels import rglru_scan as _rg
from repro.kernels import ssd_scan as _ssd


def _interpret(override: Optional[bool]) -> bool:
    if override is not None:
        return override
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    """Model-layout flash attention: q (B,S,Hq,d), k/v (B,S,Hkv,d)."""
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    o = _fa.flash_attention(qt, kt, vt, causal=causal, window=window,
                            q_offset=q_offset, block_q=block_q,
                            block_k=block_k, interpret=_interpret(interpret))
    return jnp.moveaxis(o, 1, 2)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, lens, *, k_new=None, v_new=None,
                     slot_mask=None, block_k: int = 512,
                     interpret: Optional[bool] = None):
    """Model-layout flash decode: q (B,1,Hq,d), caches (B,C,Hkv,d),
    lens (B,) -> (B,1,Hq,d).  Optional k/v_new (B,1,Hkv,d): the current
    token's K/V, merged in-kernel instead of read from the cache
    (zero-copy serving mode).  Optional slot_mask (B,C): per-slot cache
    validity for ring-buffered (windowed) caches."""
    qt = q[:, 0]                                     # (B,Hq,d)
    kt = jnp.moveaxis(k_cache, 1, 2)                 # (B,Hkv,C,d)
    vt = jnp.moveaxis(v_cache, 1, 2)
    kn = None if k_new is None else jnp.moveaxis(k_new, 1, 2)
    vn = None if v_new is None else jnp.moveaxis(v_new, 1, 2)
    o = _dec.decode_attention(qt, kt, vt, lens, k_new=kn, v_new=vn,
                              slot_mask=slot_mask, block_k=block_k,
                              interpret=_interpret(interpret))
    return o[:, None]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128,
             interpret: Optional[bool] = None):
    """Mamba2 SSD: x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,N)."""
    return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk,
                         interpret=_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_t", "block_w",
                                             "interpret"))
def rglru_scan(log_a, bx, h0=None, *, block_t: int = 128,
               block_w: int = 128, interpret: Optional[bool] = None):
    """RG-LRU recurrence: log_a/bx (B,S,W) f32."""
    return _rg.rglru_scan(log_a, bx, h0, block_t=block_t, block_w=block_w,
                          interpret=_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("scale", "block_i", "block_j",
                                             "interpret"))
def lora_merge(W, A, B, scale: float, *, block_i: int = 256,
               block_j: int = 256, interpret: Optional[bool] = None):
    """Fused W + scale*(A@B) over stacked layers: W (L,Din,Dout)."""
    return _lm.lora_merge(W, A, B, scale, block_i=block_i, block_j=block_j,
                          interpret=_interpret(interpret))
