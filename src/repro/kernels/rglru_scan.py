"""RG-LRU linear recurrence — Pallas TPU kernel (RecurrentGemma/Griffin
[arXiv:2402.19427], DESIGN.md §6).

h_t = a_t * h_{t-1} + b_t with per-channel gates.  Grid (B, nW, nT): width
is tiled over the lane dimension, time blocks run innermost/sequential with
the (1, Wb) state carried in VMEM scratch.  Within a time block the
recurrence materializes as a log-space *segmented* prefix product:

    h_{t} = exp(cumA_t) * h_in + sum_{k<=t} exp(cumA_t - cumA_k) * b_k

computed as a (Tb, Tb) masked matrix applied on the VPU — numerically safe
because cumA_t - cumA_k <= 0 within the mask (a_t in (0, 1]).

Layouts: log_a/bx (B, S, W) f32; h0 (B, W) f32 -> (y (B, S, W), h_T (B, W)).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(la_ref, bx_ref, h0_ref, y_ref, hT_ref, h_ref, *,
                  block_t: int, n_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = h0_ref[0][None, :]            # (1, Wb)

    la = la_ref[0].astype(jnp.float32)             # (Tb, Wb), <= 0
    bx = bx_ref[0].astype(jnp.float32)             # (Tb, Wb)

    cum = jnp.cumsum(la, axis=0)                   # (Tb, Wb)
    # decay[t, k] = exp(cum_t - cum_k) for k <= t else 0  — per channel this
    # is a (Tb, Tb) matrix; apply channel-blocked via einsum on the VPU.
    ti_idx = jax.lax.broadcasted_iota(jnp.int32, (block_t, block_t), 0)
    ki_idx = jax.lax.broadcasted_iota(jnp.int32, (block_t, block_t), 1)
    causal = ti_idx >= ki_idx
    # seg[t, k, w] = cum[t, w] - cum[k, w]
    seg = cum[:, None, :] - cum[None, :, :]
    dec = jnp.where(causal[:, :, None], jnp.exp(seg), 0.0)  # (Tb, Tb, Wb)
    y = jnp.einsum("tkw,kw->tw", dec, bx)
    y = y + jnp.exp(cum) * h_ref[...]              # carry-in contribution
    y_ref[0] = y.astype(y_ref.dtype)
    h_ref[...] = y[-1][None, :]

    @pl.when(ti == n_t - 1)
    def _fin():
        hT_ref[0] = h_ref[...][0].astype(hT_ref.dtype)


def rglru_scan(log_a: jnp.ndarray, bx: jnp.ndarray,
               h0: Optional[jnp.ndarray] = None, *, block_t: int = 128,
               block_w: int = 128,
               interpret: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """log_a/bx: (B, S, W); h0: (B, W) or None -> (y (B,S,W), h_T (B,W))."""
    B, S, W = log_a.shape
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)
    block_t = min(block_t, S)
    block_w = min(block_w, W)
    pad_t = (-S) % block_t
    pad_w = (-W) % block_w
    if pad_t or pad_w:
        # log_a=0 (a=1) + bx=0 padding is an exact no-op on the recurrence
        log_a = jnp.pad(log_a, ((0, 0), (0, pad_t), (0, pad_w)))
        bx = jnp.pad(bx, ((0, 0), (0, pad_t), (0, pad_w)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_w)))
    Sp, Wp = S + pad_t, W + pad_w
    n_t = Sp // block_t
    n_w = Wp // block_w

    kernel = functools.partial(_rglru_kernel, block_t=block_t, n_t=n_t)
    y, hT = pl.pallas_call(
        kernel,
        grid=(B, n_w, n_t),
        in_specs=[
            pl.BlockSpec((1, block_t, block_w),
                         lambda b, wi, ti: (b, ti, wi)),
            pl.BlockSpec((1, block_t, block_w),
                         lambda b, wi, ti: (b, ti, wi)),
            pl.BlockSpec((1, block_w), lambda b, wi, ti: (b, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_w),
                         lambda b, wi, ti: (b, ti, wi)),
            pl.BlockSpec((1, block_w), lambda b, wi, ti: (b, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sp, Wp), log_a.dtype),
            jax.ShapeDtypeStruct((B, Wp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        interpret=interpret,
    )(log_a, bx, h0)
    return y[:, :S, :W], hT[:, :W]
