"""Flash attention (prefill) — Pallas TPU kernel.

TTFT-critical compute of the PipeBoost cold start (DESIGN.md §6).  Online-
softmax tiling: grid (B, Hq, nq, nk) with the key dimension innermost
(sequential on TPU), per-(b,h,qblock) f32 accumulators live in VMEM scratch
across the k sweep.  GQA is folded into the index map (query head h reads
kv head h // group).  Causal and sliding-window masks are applied from
global positions; `q_offset` supports chunked/continued prefill.

Layouts: q (B, Hq, Sq, d), k/v (B, Hkv, Sk, d), out (B, Hq, Sq, d).
Block shapes default to MXU-aligned (128) multiples.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int, q_offset: int,
                  block_q: int, block_k: int, n_k: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (Bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (Bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (Bq, Bk)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos < seq_k
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                   # (Bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)                        # (Bq, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0, q_offset: int = 0,
                    scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (B, Hq, Sq, d); k/v: (B, Hkv, Sk, d) -> (B, Hq, Sq, d)."""
    B, Hq, Sq, d = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    scale = scale if scale is not None else d ** -0.5

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    n_q = (Sq + pad_q) // block_q
    n_k = (Sk + pad_k) // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, block_q=block_q, block_k=block_k, n_k=n_k,
        seq_k=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq + pad_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq, :]
