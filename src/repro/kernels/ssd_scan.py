"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

TPU adaptation of the SSD algorithm [arXiv:2405.21060] (DESIGN.md §6): the
GPU version leans on warp-level scans; here each grid step owns one
(batch, head, chunk) tile in VMEM — intra-chunk work is a masked (Q, Q)
quadratic form on the MXU, and the (P, N) inter-chunk state is carried in
VMEM scratch across the sequential chunk dimension (innermost grid axis).

Layouts: x (B, H, nc, Q, P); dt (B, H, nc, Q, 1); A (1, H);
         Bm/Cm (B, nc, Q, N)  [single B/C group broadcast over heads]
Outputs: y (B, H, nc, Q, P); final_state (B, H, P, N).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, fs_ref,
                state_ref, *, chunk: int, n_chunks: int):
    h = pl.program_id(1)
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)      # (Q, 1)
    a = a_ref[0, h].astype(jnp.float32)           # scalar (negative)
    Bm = b_ref[0, 0].astype(jnp.float32)          # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)          # (Q, N)

    dA = dt * a                                   # (Q, 1)
    cum = jnp.cumsum(dA, axis=0)                  # (Q, 1)

    # intra-chunk: G[q, k] = (C_q . B_k) * exp(cum_q - cum_k) * dt_k, q >= k
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (Q, Q)
    seg = cum - cum[:, 0][None, :]                # (Q, Q) = cum_q - cum_k
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = qi >= ki
    G = jnp.where(causal, scores * jnp.exp(seg) * dt[:, 0][None, :], 0.0)
    y = jax.lax.dot_general(G, x, (((1,), (0,)), ((), ())))        # (Q, P)

    # inter-chunk: y += exp(cum_q) * C_q . state_in   (state: (P, N))
    y = y + jnp.exp(cum) * jax.lax.dot_general(
        Cm, state_ref[...], (((1,), (1,)), ((), ())))

    # state update: state_out = exp(cum_last)*state_in + sum_k w_k x_k B_k^T
    w = jnp.exp(cum[-1, 0] - cum) * dt            # (Q, 1)
    S_c = jax.lax.dot_general(x * w, Bm, (((0,), (0,)), ((), ())))  # (P, N)
    state_ref[...] = jnp.exp(cum[-1, 0]) * state_ref[...] + S_c

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _fin():
        fs_ref[0, 0] = state_ref[...].astype(fs_ref.dtype)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             Bm: jnp.ndarray, Cm: jnp.ndarray, *, chunk: int = 128,
             interpret: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, H, P); dt: (B, S, H) (post-softplus); A: (H,) negative;
    Bm/Cm: (B, S, N).  Returns (y (B, S, H, P), final_state (B, H, P, N))."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:  # dt=0 padding is an exact no-op on the recurrence
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk

    xk = jnp.moveaxis(x.reshape(B, nc, chunk, H, P), 3, 1)      # (B,H,nc,Q,P)
    dtk = jnp.moveaxis(dt.reshape(B, nc, chunk, H), 3, 1)[..., None]
    bk = Bm.reshape(B, nc, chunk, N)
    ck = Cm.reshape(B, nc, chunk, N)
    a2 = A[None, :].astype(jnp.float32)                          # (1, H)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    y, fs = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, 1), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, H), lambda b, h, c: (0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, chunk, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xk, dtk, a2, bk, ck)
    y = jnp.moveaxis(y, 1, 3).reshape(B, Sp, H, P)[:, :S]
    return y, fs
