"""Serving engine: request lifecycle + continuous batching (Orca-style,
which the paper adopts) over slot-indexed KV caches, with epoch-based
LoRA adapter scheduling and PipeBoost cold-start/recovery integration.

Slots: the engine owns one batched cache of ``n_slots``; a new request's
prefill is computed and written into a free slot while other slots keep
decoding — requests join/leave the batch at token granularity (continuous
batching).  Per-slot positions ride in ``cache["pos"]`` (B,).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.adapter_scheduler import EpochSchedulerPolicy
from repro.models import transformer


@dataclass
class ServeRequest:
    rid: int
    tokens: np.ndarray                   # prompt (S,)
    max_new_tokens: int
    adapter: Optional[str] = None
    arrival: float = 0.0
    generated: List[int] = field(default_factory=list)
    slot: int = -1
    done: bool = False
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    eos_id: Optional[int] = None


class ContinuousBatcher:
    """Slot-based continuous batching over the stacked-cache models."""

    def __init__(self, cfg: ArchConfig, params, n_slots: int, max_len: int,
                 sampler: Optional[Callable] = None):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = transformer.init_cache(cfg, n_slots, max_len,
                                            jnp.dtype(cfg.dtype))
        self.cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
        self.active: Dict[int, ServeRequest] = {}     # slot -> request
        self.free: List[int] = list(range(n_slots))
        self.sampler = sampler or (lambda lg: jnp.argmax(lg, axis=-1))
        self._decode = jax.jit(
            lambda p, t, c: transformer.decode_step(cfg, p, {"tokens": t}, c))

    # ------------------------------------------------------------------
    def admit(self, req: ServeRequest) -> bool:
        """Prefill ``req`` into a free slot; False if the batch is full."""
        if not self.free:
            return False
        slot = self.free.pop()
        req.slot = slot
        prompt = jnp.asarray(req.tokens, jnp.int32)[None, :]
        logits, c1 = transformer.forward(self.cfg, self.params,
                                         {"tokens": prompt}, mode="prefill",
                                         max_len=self.max_len)
        self._write_slot(slot, c1)
        tok = int(np.asarray(self.sampler(logits))[0])
        req.generated.append(tok)
        self.active[slot] = req
        return True

    def _write_slot(self, slot: int, c1: Dict):
        def write(stack_key: str):
            if stack_key in c1:
                for leaf in c1[stack_key]:
                    self.cache[stack_key][leaf] = \
                        self.cache[stack_key][leaf].at[:, slot].set(
                            c1[stack_key][leaf][:, 0])
        for k in ("attn", "ssm", "rec"):
            write(k)
        self.cache["pos"] = self.cache["pos"].at[slot].set(int(c1["pos"][0]))

    def step(self) -> List[ServeRequest]:
        """One decode step for all active slots; returns finished requests."""
        if not self.active:
            return []
        toks = np.zeros((self.n_slots,), np.int32)
        for slot, req in self.active.items():
            toks[slot] = req.generated[-1]
        logits, self.cache = self._decode(self.params,
                                          jnp.asarray(toks), self.cache)
        nxt = np.asarray(self.sampler(logits))
        finished = []
        for slot, req in list(self.active.items()):
            tok = int(nxt[slot])
            req.generated.append(tok)
            at_eos = req.eos_id is not None and tok == req.eos_id
            if len(req.generated) >= req.max_new_tokens or at_eos:
                req.done = True
                finished.append(req)
                del self.active[slot]
                self.free.append(slot)
        return finished

    @property
    def n_active(self) -> int:
        return len(self.active)


class ServingEngine:
    """Request dispatcher + continuous batcher + adapter epochs.

    ``set_params`` supports the PipeBoost adapter switch (merged weights
    swapped between epochs) and the post-recovery parameter refresh.
    """

    def __init__(self, cfg: ArchConfig, params, *, n_slots: int = 4,
                 max_len: int = 256,
                 policy: Optional[EpochSchedulerPolicy] = None,
                 adapter_params: Optional[Dict[str, Any]] = None):
        self.cfg = cfg
        self.batcher = ContinuousBatcher(cfg, params, n_slots, max_len)
        self.policy = policy or EpochSchedulerPolicy()
        self.policy_state = self.policy.make_state()
        self.adapter_params = adapter_params or {}
        self.base_params = params
        self.active_adapter: Optional[str] = None
        self.clock = 0.0
        self.completed: List[ServeRequest] = []
        self.n_adapter_switches = 0

    def submit(self, req: ServeRequest):
        from repro.core.adapter_scheduler import Request as PolicyReq
        req.arrival = self.clock
        self.policy.enqueue(self.policy_state, _PolicyItem(req))

    def _switch_adapter(self, name: Optional[str]):
        if name == self.active_adapter:
            return
        params = self.base_params if name is None \
            else self.adapter_params[name]
        self.batcher.params = params
        self.batcher._decode = jax.jit(
            lambda p, t, c: transformer.decode_step(self.cfg, p,
                                                    {"tokens": t}, c))
        self.active_adapter = name
        self.n_adapter_switches += 1

    def run(self, max_steps: int = 10_000) -> List[ServeRequest]:
        """Drain all queues: admit per the adapter policy, decode until done.

        Epoch barrier: merged-LoRA means a switch swaps the weights for
        EVERY active slot, so a different adapter is only admitted once the
        batch has drained (the paper's epoch semantics, Fig. 5).
        """
        for _ in range(max_steps):
            while self.batcher.free:
                nxt = self.policy.peek_adapter(self.policy_state)
                if nxt is None:
                    break
                nxt_name = None if nxt == "__base__" else nxt
                if self.batcher.active and nxt_name != self.active_adapter:
                    break  # drain before switching (epoch barrier)
                adapter, batch = self.policy.next_batch(self.policy_state)
                if adapter is None:
                    break
                self._switch_adapter(adapter if adapter != "__base__" else None)
                for item in batch:
                    ok = self.batcher.admit(item.req)
                    assert ok
            if not self.batcher.active:
                if self.policy.peek_adapter(self.policy_state) is None:
                    break
                continue
            done = self.batcher.step()
            self.clock += 1.0  # logical step clock
            for r in done:
                r.finished_at = self.clock
                self.completed.append(r)
        return self.completed


class _PolicyItem:
    """Adapter-scheduler item wrapping a ServeRequest."""

    def __init__(self, req: ServeRequest):
        self.req = req
        self.adapter = req.adapter or "__base__"
        self.arrival = req.arrival
        self.service = 0.0
