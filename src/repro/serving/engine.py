"""Serving engine: request lifecycle + continuous batching (Orca-style,
which the paper adopts) over slot-indexed KV caches, with epoch-based
LoRA adapter scheduling and PipeBoost cold-start/recovery integration.

Slots: the engine owns one batched cache of ``n_slots``; a new request's
prefill is computed and written into a free slot while other slots keep
decoding — requests join/leave the batch at token granularity (continuous
batching).  Per-slot positions ride in ``cache["pos"]`` (B,).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.adapter_scheduler import EpochSchedulerPolicy
from repro.models import transformer


def quantized_greedy(logits):
    """Quantize-then-argmax greedy sampler: sub-1e-3 fp differences between
    batched and solo kernels land in the same bin, so the pick only flips in
    the (vanishingly rare) case where near-tied logits straddle a bin edge.
    The cluster layer uses this for exact replay after crash re-routing."""
    return jnp.argmax(jnp.round(logits.astype(jnp.float32) * 1e3), axis=-1)


@dataclass
class ServeRequest:
    rid: int
    tokens: np.ndarray                   # prompt (S,)
    max_new_tokens: int
    adapter: Optional[str] = None
    arrival: Optional[float] = None      # stamped at submit if unset
    generated: List[int] = field(default_factory=list)
    slot: int = -1
    done: bool = False
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    eos_id: Optional[int] = None


class ContinuousBatcher:
    """Slot-based continuous batching over the stacked-cache models."""

    def __init__(self, cfg: ArchConfig, params, n_slots: int, max_len: int,
                 sampler: Optional[Callable] = None):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = transformer.init_cache(cfg, n_slots, max_len,
                                            jnp.dtype(cfg.dtype))
        self.cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
        self.active: Dict[int, ServeRequest] = {}     # slot -> request
        self.free: List[int] = list(range(n_slots))
        self.sampler = sampler or (lambda lg: jnp.argmax(lg, axis=-1))
        self._decode = jax.jit(
            lambda p, t, c: transformer.decode_step(cfg, p, {"tokens": t}, c))

    # ------------------------------------------------------------------
    def admit(self, req: ServeRequest) -> bool:
        """Prefill ``req`` into a free slot; False if the batch is full.

        Re-submission: a request that already carries ``generated`` tokens
        (drained from a crashed server) is prefilled over prompt + generated,
        so greedy decoding continues exactly where it left off.
        """
        if not self.free:
            return False
        slot = self.free.pop()
        req.slot = slot
        toks = np.asarray(req.tokens, np.int64)
        if req.generated:
            toks = np.concatenate([toks, np.asarray(req.generated, np.int64)])
        prompt = jnp.asarray(toks, jnp.int32)[None, :]
        logits, c1 = transformer.forward(self.cfg, self.params,
                                         {"tokens": prompt}, mode="prefill",
                                         max_len=self.max_len)
        self._write_slot(slot, c1)
        tok = int(np.asarray(self.sampler(logits))[0])
        req.generated.append(tok)
        at_eos = req.eos_id is not None and tok == req.eos_id
        if len(req.generated) >= req.max_new_tokens or at_eos:
            req.done = True           # satisfied at admission (re-submit tail)
            self.free.append(slot)
            req.slot = -1
            return True
        self.active[slot] = req
        return True

    def _write_slot(self, slot: int, c1: Dict):
        def write(stack_key: str):
            if stack_key in c1:
                for leaf in c1[stack_key]:
                    self.cache[stack_key][leaf] = \
                        self.cache[stack_key][leaf].at[:, slot].set(
                            c1[stack_key][leaf][:, 0])
        for k in ("attn", "ssm", "rec"):
            write(k)
        self.cache["pos"] = self.cache["pos"].at[slot].set(int(c1["pos"][0]))

    def step(self) -> List[ServeRequest]:
        """One decode step for all active slots; returns finished requests."""
        if not self.active:
            return []
        toks = np.zeros((self.n_slots,), np.int32)
        for slot, req in self.active.items():
            toks[slot] = req.generated[-1]
        logits, self.cache = self._decode(self.params,
                                          jnp.asarray(toks), self.cache)
        nxt = np.asarray(self.sampler(logits))
        finished = []
        for slot, req in list(self.active.items()):
            tok = int(nxt[slot])
            req.generated.append(tok)
            at_eos = req.eos_id is not None and tok == req.eos_id
            if len(req.generated) >= req.max_new_tokens or at_eos:
                req.done = True
                finished.append(req)
                del self.active[slot]
                self.free.append(slot)
        return finished

    def drain(self) -> List[ServeRequest]:
        """Pull every in-flight request out of the batch (server crash /
        re-route path): slots are freed, requests keep their generated
        prefix so ``admit`` elsewhere resumes them exactly."""
        drained = []
        for slot, req in sorted(self.active.items()):
            req.slot = -1
            self.free.append(slot)
            drained.append(req)
        self.active.clear()
        return drained

    @property
    def n_active(self) -> int:
        return len(self.active)


class ServingEngine:
    """Request dispatcher + continuous batcher + adapter epochs.

    ``set_params`` supports the PipeBoost adapter switch (merged weights
    swapped between epochs) and the post-recovery parameter refresh.
    """

    def __init__(self, cfg: ArchConfig, params, *, n_slots: int = 4,
                 max_len: int = 256,
                 policy: Optional[EpochSchedulerPolicy] = None,
                 adapter_params: Optional[Dict[str, Any]] = None):
        self.cfg = cfg
        self.batcher = ContinuousBatcher(cfg, params, n_slots, max_len)
        self.policy = policy or EpochSchedulerPolicy()
        self.policy_state = self.policy.make_state()
        self.adapter_params = adapter_params or {}
        self.base_params = params
        self.active_adapter: Optional[str] = None
        self.clock = 0.0
        self.completed: List[ServeRequest] = []
        self.n_adapter_switches = 0

    def submit(self, req: ServeRequest):
        # stamp fresh requests that carry no arrival of their own; requests
        # with a trace arrival or a generated prefix (re-submits) keep theirs
        if req.arrival is None:
            req.arrival = self.clock
        self.policy.enqueue(self.policy_state, _PolicyItem(req))

    def _switch_adapter(self, name: Optional[str]):
        if name == self.active_adapter:
            return
        params = self.base_params if name is None \
            else self.adapter_params[name]
        self.batcher.params = params
        self.batcher._decode = jax.jit(
            lambda p, t, c: transformer.decode_step(self.cfg, p,
                                                    {"tokens": t}, c))
        self.active_adapter = name
        self.n_adapter_switches += 1

    def _admit_pending(self) -> List[ServeRequest]:
        """Admit queued requests per the adapter policy into free slots.

        Epoch barrier: merged-LoRA means a switch swaps the weights for
        EVERY active slot, so a different adapter is only admitted once the
        batch has drained (the paper's epoch semantics, Fig. 5).  Returns
        requests already satisfied at admission (re-submitted tails).
        """
        satisfied: List[ServeRequest] = []
        while self.batcher.free:
            nxt = self.policy.peek_adapter(self.policy_state)
            if nxt is None:
                break
            nxt_name = None if nxt == "__base__" else nxt
            if self.batcher.active and nxt_name != self.active_adapter:
                break  # drain before switching (epoch barrier)
            adapter, batch = self.policy.next_batch(self.policy_state)
            if adapter is None:
                break
            self._switch_adapter(adapter if adapter != "__base__" else None)
            for pos, item in enumerate(batch):
                if not self.batcher.free:
                    # policy batch can exceed free slots under staggered
                    # occupancy — hand the tail back for the next tick
                    self.policy.requeue_front(self.policy_state, batch[pos:])
                    break
                ok = self.batcher.admit(item.req)
                assert ok
                if item.req.first_token_at is None:
                    item.req.first_token_at = self.clock
                if item.req.done:
                    item.req.finished_at = self.clock
                    self.completed.append(item.req)
                    satisfied.append(item.req)
        return satisfied

    def step(self, now: Optional[float] = None) -> List[ServeRequest]:
        """One scheduling + decode tick; returns requests finished this tick.

        With ``now`` the caller owns the clock (the cluster router drives
        many servers off one shared clock); without it the engine advances
        its own logical step clock by 1 per decode.
        """
        if now is not None:
            self.clock = now
        finished = self._admit_pending()
        if not self.batcher.active:
            return finished
        done = self.batcher.step()
        if now is None:
            self.clock += 1.0  # logical step clock
        for r in done:
            r.finished_at = self.clock
            self.completed.append(r)
        return finished + done

    def drain_inflight(self) -> List[ServeRequest]:
        """Remove every in-flight AND queued request (crash re-route path);
        in-flight requests keep their generated prefix for exact resumption
        on another server."""
        out = self.batcher.drain()
        while True:
            adapter, batch = self.policy.next_batch(self.policy_state)
            if adapter is None:
                break
            out.extend(item.req for item in batch)
        return out

    def queued_requests(self) -> List[ServeRequest]:
        """Requests enqueued but not yet admitted (no first token yet)."""
        out: List[ServeRequest] = []
        for q in self.policy_state.get("queues", {}).values():
            out.extend(it.req for it in q)
        out.extend(it.req for it in self.policy_state.get("fifo", ()))
        return out

    @property
    def n_pending(self) -> int:
        """Queued (not yet admitted) + in-flight requests."""
        return len(self.queued_requests()) + self.batcher.n_active

    def run(self, max_steps: int = 10_000) -> List[ServeRequest]:
        """Drain all queues: admit per the adapter policy, decode until done."""
        for _ in range(max_steps):
            self.step()
            if not self.batcher.active \
                    and self.policy.peek_adapter(self.policy_state) is None:
                break
        return self.completed


class _PolicyItem:
    """Adapter-scheduler item wrapping a ServeRequest."""

    def __init__(self, req: ServeRequest):
        self.req = req
        self.adapter = req.adapter or "__base__"
        self.arrival = req.arrival
        self.service = 0.0
