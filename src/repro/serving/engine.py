"""Serving engine: request lifecycle + continuous batching (Orca-style,
which the paper adopts) over slot-indexed KV caches, with epoch-based
LoRA adapter scheduling and PipeBoost cold-start/recovery integration.

Slots: the engine owns one batched cache of ``n_slots``; a new request's
prefill is computed and written into a free slot while other slots keep
decoding — requests join/leave the batch at token granularity (continuous
batching).  Per-slot positions ride in ``cache["pos"]`` (B,).

Hot-path design (the zero-copy decode loop)
-------------------------------------------
* **Donated fused decode+sample**: one jitted step runs
  ``decode_step`` + the sampler with ``donate_argnums`` on the cache, so
  every token updates the KV buffers in place instead of copying the
  whole slot-stacked cache.  Exactly one small (B,) device->host transfer
  happens per step (the sampled tokens); the token array itself stays on
  device between steps.
* **Bucketed prefill**: prompts are right-padded to power-of-two length
  buckets (``bucket_sizes``) so XLA compiles once per bucket, not once
  per prompt length.  Padding is masked in-kernel: causal attention means
  trailing pads never contaminate real positions, the last-token logits
  are gathered at the true prompt end (``forward(..., last_index=...)``),
  and ``cache["pos"]`` records the true length so decode attention masks
  the pad K/V.  Same-bucket requests prefill together in one batched
  call, and the slot write happens in-jit on the donated cache (a
  select/scatter over stacked leaves) instead of a per-leaf Python loop.
* **One jit for the engine's lifetime**: params are a traced argument, so
  an adapter epoch switch swaps ``params`` without retracing; free slots
  are masked in-jit (their ``pos`` is frozen and their token is passed
  through) so inactive lanes can't hit sampler edge cases.

``compile_stats()`` / ``hotpath_stats()`` surface compile counts and
decode throughput for benchmarks, the cluster metrics, and the CI
compile-count regression guard.

See ``docs/ARCHITECTURE.md`` § "Serving: continuous batching".
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.adapter_scheduler import EpochSchedulerPolicy
from repro.models import transformer
from repro.serving.snapshot import KVSnapshot, export_slot, export_slots

BUCKET_MIN = 16


def quantized_greedy(logits):
    """Quantize-then-argmax greedy sampler: sub-1e-3 fp differences between
    batched and solo kernels land in the same bin, so the pick only flips in
    the (vanishingly rare) case where near-tied logits straddle a bin edge.
    The cluster layer uses this for exact replay after crash re-routing."""
    return jnp.argmax(jnp.round(logits.astype(jnp.float32) * 1e3), axis=-1)


def bucket_sizes(max_len: int, bmin: int = BUCKET_MIN) -> List[int]:
    """The prefill length buckets for ``max_len``: powers of two from
    ``bmin`` up, with ``max_len`` itself as the final bucket."""
    out = []
    b = bmin
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return out


@dataclass
class ServeRequest:
    rid: int
    tokens: np.ndarray                   # prompt (S,)
    max_new_tokens: int
    adapter: Optional[str] = None
    arrival: Optional[float] = None      # stamped at submit if unset
    model: Optional[str] = None          # fleet pool name (multi-model)
    deadline: Optional[float] = None     # absolute TTFT deadline (clock s);
                                         # None = no SLO attached
    generated: List[int] = field(default_factory=list)
    slot: int = -1
    done: bool = False
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    eos_id: Optional[int] = None
    # decode state exported at drain time (crash migration); carried so a
    # survivor can resume without re-prefill — excluded from equality
    snapshot: Optional[KVSnapshot] = field(default=None, repr=False,
                                           compare=False)


class ContinuousBatcher:
    """Slot-based continuous batching over the stacked-cache models."""

    def __init__(self, cfg: ArchConfig, params, n_slots: int, max_len: int,
                 sampler: Optional[Callable] = None):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        # prefill backend dispatch (overlapped cold start): while the host
        # engine is mid-load in pipeline strategy, admission prefills lower
        # through the injected pipeline fn (shard_map belt on multi-device
        # backends); after the strategy switch — or when nothing was
        # injected — through the engine's own fused single lowering
        self.prefill_backend: Callable[[], str] = lambda: "single"
        self._pipe_prefill: Optional[Callable] = None
        self._pipe_fits: Callable[[int, int], bool] = lambda P, S: True
        self.cache = transformer.init_cache(cfg, n_slots, max_len,
                                            jnp.dtype(cfg.dtype))
        self.cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
        self.active: Dict[int, ServeRequest] = {}     # slot -> request
        self.free: List[int] = list(range(n_slots))
        # Bucketed (padded) prefill is exact only when every layer is
        # batch-row-independent AND per-token causal (pure attention with a
        # full-length cache): SSM/recurrent states integrate pad tokens, MoE
        # capacity couples rows, and a ring buffer would evict real K/V.
        self._can_bucket = (
            set(cfg.layer_kinds()) <= {"attn"}
            and transformer.attn_cache_capacity(cfg, max_len) == max_len)
        # device-resident step I/O (rebuilt only when slot membership
        # changes; in steady state nothing crosses the host boundary except
        # the sampled tokens)
        self._dev_tokens = jnp.zeros((n_slots,), jnp.int32)
        self._dev_active = jnp.zeros((n_slots,), bool)
        self._io_dirty = True
        # hot-path counters
        self.n_decode_steps = 0
        self.decode_time_s = 0.0
        self.n_prefill_calls = 0
        self.n_prefill_reqs = 0
        self.n_prefill_pipeline = 0      # requests prefilled via the
                                         # pipeline (cold-start) lowering
        # migration counters (snapshot imports; tokens whose prefill was
        # skipped because their state arrived with them)
        self.n_migrated_in = 0
        self.migrated_tokens_in = 0
        self.n_batched_imports = 0       # import_snapshots scatter calls
        self.n_relay_scatters = 0        # relay_inflight scatter calls
                                         # (repartition re-lay)
        # cross-request prefix reuse (serving/prefix_cache.py): when a
        # store is attached, admission probes it per request, imports hits
        # through the shared donated scatter, and prefills only the
        # uncached suffix; completed/drained prompts deposit their rows
        self.prefix_cache = None
        self._prefix_evict_base = 0      # evictions before attach (delta)
        self.n_prefill_tokens = 0        # real (unpadded) tokens prefilled
        self.prefix_hits = 0             # admissions served from the cache
        self.prefix_hit_tokens = 0       # prompt tokens NOT re-prefilled
        self._sampler = sampler or (lambda lg: jnp.argmax(lg, axis=-1))
        self._build_jits()

    # ------------------------------------------------------------------
    # jitted hot-path functions (built once; params stay a traced argument
    # so adapter switches never retrace)
    # ------------------------------------------------------------------
    @property
    def sampler(self) -> Callable:
        return self._sampler

    @sampler.setter
    def sampler(self, fn: Callable) -> None:
        # the sampler is fused into the jitted step, so swapping it needs a
        # fresh trace (done here, never on adapter switches)
        self._sampler = fn
        self._build_jits()

    def _build_jits(self) -> None:
        cfg, n_slots, max_len = self.cfg, self.n_slots, self.max_len

        def fused_decode(p, toks, active_mask, cache):
            old_pos = cache["pos"]
            logits, cache = transformer.decode_step(cfg, p, {"tokens": toks},
                                                    cache)
            # freeze free slots: their position must not advance (a wrapped
            # ring-buffer pos would corrupt a later admission) and their
            # garbage logits must not reach EOS bookkeeping
            cache["pos"] = jnp.where(active_mask, cache["pos"], old_pos)
            nxt = self._sampler(logits).astype(jnp.int32)
            nxt = jnp.where(active_mask, nxt, toks)
            return nxt, cache

        self._decode_fused = jax.jit(fused_decode, donate_argnums=(3,))

        def write_rows(cache, rows, slots, valid, pos):
            """Scatter per-request row stacks into the donated cache.

            ``rows``: kind -> leaf -> (L, P, ...) stacked rows (a prefill's
            fresh cache, a batch of migrated snapshots, or the pipeline
            prefill's state); slot j takes row src[j] iff some valid row
            targets it — one select per leaf, no per-row dispatch.
            """
            sel = (slots[None, :] == jnp.arange(n_slots)[:, None]) \
                & valid[None, :]                       # (n_slots, P)
            written = sel.any(axis=1)                  # (n_slots,)
            src = jnp.argmax(sel.astype(jnp.int32), axis=1)
            for key in ("attn", "ssm", "rec"):
                if key in rows:
                    for leaf in rows[key]:
                        old = cache[key][leaf]
                        new = jnp.take(rows[key][leaf], src, axis=1)
                        w = written.reshape((1, -1) + (1,) * (old.ndim - 2))
                        cache[key][leaf] = jnp.where(w, new, old)
            cache["pos"] = jnp.where(written, jnp.take(pos, src),
                                     cache["pos"])
            return cache

        def fused_prefill(p, toks, last_idx, slots, valid, cache):
            """Prefill padded prompts and write them into ``slots`` in-jit.

            toks (P, bucket) int32 right-padded; last_idx (P,) true last
            token index; slots (P,) target slot per row; valid (P,) row
            mask (pad rows are ignored).  The cache is donated: the write
            is a per-slot select over the stacked leaves, not a Python
            ``.at[].set`` loop with one dispatch per leaf.
            """
            logits, c1 = transformer.forward(
                cfg, p, {"tokens": toks}, mode="prefill", max_len=max_len,
                last_index=last_idx)
            rows = {k: c1[k] for k in ("attn", "ssm", "rec") if k in c1}
            cache = write_rows(cache, rows, slots, valid, last_idx + 1)
            first = self._sampler(logits).astype(jnp.int32)
            return first, cache

        self._prefill_fused = jax.jit(fused_prefill, donate_argnums=(5,))

        def fused_scatter(cache, rows, slots, pos, valid):
            """Standalone donated row scatter (one compile for its
            lifetime): batched snapshot import — N migrated requests land
            in ONE call — and the pipeline-prefill slot write both ride
            this.  Row count is pinned to ``n_slots`` (pad rows masked by
            ``valid``) so every caller shares the compilation."""
            return write_rows(cache, rows, slots, valid, pos)

        self._scatter_fused = jax.jit(fused_scatter, donate_argnums=(0,))

        def fused_import(cache, rows, slot, pos):
            """Scatter one request's per-layer state rows into ``slot``.

            ``rows``: kind -> leaf -> (L, ...) arrays (a KVSnapshot's rows
            or a reconstructed slot).  One donated in-place scatter for the
            whole model — no host round-trip per layer, no cache copy.
            ``slot``/``pos`` are traced scalars so every import shares one
            compilation.
            """
            for kind in ("attn", "ssm", "rec"):
                if kind in rows:
                    for leaf in rows[kind]:
                        cache[kind][leaf] = \
                            cache[kind][leaf].at[:, slot].set(rows[kind][leaf])
            cache["pos"] = cache["pos"].at[slot].set(pos)
            return cache

        self._import_fused = jax.jit(fused_import, donate_argnums=(0,))

    # ------------------------------------------------------------------
    # prefill / admission
    # ------------------------------------------------------------------
    def set_pipeline_prefill(self, fn: Callable,
                             fits: Optional[Callable[[int, int], bool]]
                             = None) -> None:
        """Inject the pipeline prefill lowering for cold-start dispatch.

        ``fn(params, {"tokens": (P, S), "last_index": (P,)})`` must return
        ``(last-index logits (P, V), state {kind: {leaf: (L, P, ...)}})``
        — the contract of ``distributed.pipeline.build_pipeline_prefill``
        with ``return_cache=True`` (see ``PipeBoostEngine.
        serving_pipeline_prefill``).  ``fits(P, S)`` pre-checks mesh
        divisibility; unfit shapes fall back to the single lowering.
        Admission uses it only while ``prefill_backend()`` says
        "pipeline" (i.e. mid-load, before the strategy switch).
        """
        self._pipe_prefill = fn
        if fits is not None:
            self._pipe_fits = fits

    def _choose_prefill_backend(self, P: int, bucket: int) -> str:
        if (self._pipe_prefill is not None and self._can_bucket
                and self.prefill_backend() == "pipeline"
                and self._pipe_fits(P, bucket)):
            return "pipeline"
        return "single"

    def _total_len(self, req: ServeRequest) -> int:
        return len(req.tokens) + len(req.generated)

    def bucket_for(self, req: ServeRequest) -> int:
        """Padded prefill length for ``req`` (exact length when the model
        can't be padded safely — see ``_can_bucket``)."""
        L = self._total_len(req)
        if not self._can_bucket:
            return L
        # derive from bucket_sizes so the ladder the engine pads with and
        # the ladder the compile-count guards bound against can't drift
        for b in bucket_sizes(self.max_len):
            if b >= L:
                return b
        return L        # out-of-contract (L > max_len): exact length

    def admit(self, req: ServeRequest) -> bool:
        """Prefill ``req`` into a free slot; False if the batch is full.

        Re-submission: a request that already carries ``generated`` tokens
        (drained from a crashed server) is prefilled over prompt + generated,
        so greedy decoding continues exactly where it left off.
        """
        if not self.free:
            return False
        self.admit_batch([req])
        return True

    def attach_prefix_cache(self, cache) -> None:
        """Attach (or detach with ``None``) a ``PrefixCache``.

        Eviction accounting is delta-based from this moment, so a store
        that moves between servers via the cluster's ``StateTier`` never
        double-counts its history into two servers' hot-path stats.
        Prefix reuse rides the bucketed-attention cache contract
        (``_can_bucket``): SSM/recurrent state integrates every token and
        a ring buffer evicts real K/V, so those models skip probing.
        """
        self.prefix_cache = cache
        self._prefix_evict_base = 0 if cache is None else cache.evictions

    def admit_batch(self, reqs: Sequence[ServeRequest]) -> None:
        """Prefill several requests in one batched, bucketed call.

        Caller guarantees ``len(reqs) <= len(self.free)``.  Requests are
        padded to the largest bucket in the group (the scheduler groups by
        bucket, so normally they share one).  Models that can't pad safely
        are prefilled one by one at exact length.

        With a prefix cache attached, each fresh request first probes it:
        hits import their cached prompt-prefix rows and replay only the
        uncached suffix (``_admit_prefix_hits``); misses — and re-submits
        carrying a generated prefix — take the normal prefill path.
        """
        assert len(reqs) <= len(self.free), (len(reqs), len(self.free))
        hits: List[Tuple[ServeRequest, Any]] = []
        misses: List[ServeRequest] = []
        for r in reqs:
            h = None
            if (self.prefix_cache is not None and self._can_bucket
                    and not r.generated):
                h = self.prefix_cache.probe(self.cfg.name, r.adapter,
                                            np.asarray(r.tokens, np.int64))
            if h is None:
                misses.append(r)
            else:
                hits.append((r, h))
        if hits:
            self._admit_prefix_hits(hits)
        if not misses:
            return
        if not self._can_bucket:
            for r in misses:
                self._admit_rows([r])
        else:
            self._admit_rows(misses)

    def _admit_prefix_hits(self, hits: List[Tuple[ServeRequest, Any]]
                           ) -> None:
        """Admit prefix-cache hits: import cached rows, walk the suffix.

        The cached rows land in ONE donated ``fused_scatter`` — the same
        compilation batched migration and the pipeline prefill share, so
        cache imports add zero compiles — with each hit's slot position
        set to its usable prefix length ``k``.  The uncached suffix then
        replays through the already-compiled fused decode step: walk step
        ``i`` feeds suffix token ``i`` of every hit still walking, while
        finished hits and unrelated live slots are frozen by the active
        mask (the existing free-slot mechanism: their pos is restored and
        the garbage write at their uncommitted index is overwritten by
        their next real step).  Each hit therefore emits exactly ONE
        sampled token at admission — the observable shape of a cold
        prefill.  Sampled tokens accumulate on device; a single host read
        at the end picks each hit's first generated token (the sample
        after its last prompt token).  Bit-identity with cold prefill
        rides on the same quantized-sampler argument as snapshot resume:
        rows are exact host copies, and causal attention makes prefix KV
        a function of prefix tokens only.
        """
        P = self.n_slots
        slots_np = np.zeros((P,), np.int32)
        pos_np = np.zeros((P,), np.int32)
        valid_np = np.zeros((P,), bool)
        rows: Dict[str, Dict[str, np.ndarray]] = {}
        assigned: List[Tuple[int, ServeRequest, int, np.ndarray]] = []
        for j, (req, (entry, k)) in enumerate(hits):
            slot = self.free.pop()
            req.slot = slot
            slots_np[j] = slot
            pos_np[j] = k
            valid_np[j] = True
            for kind, leaves in entry.rows.items():
                dst = rows.setdefault(kind, {})
                for leaf, a in leaves.items():
                    if leaf not in dst:
                        dst[leaf] = np.zeros((a.shape[0], P) + a.shape[1:],
                                             a.dtype)
                    dst[leaf][:, j] = a
            assigned.append((slot, req, k,
                             np.asarray(req.tokens, np.int64)[k:]))
        self.cache = self._scatter_fused(
            self.cache, rows, jnp.asarray(slots_np), jnp.asarray(pos_np),
            jnp.asarray(valid_np))
        for _, (entry, _k) in hits:
            self.prefix_cache.release(entry)
        W = max(len(sfx) for _, _, _, sfx in assigned)
        toks = np.zeros((W, P), np.int32)
        act = np.zeros((W, P), bool)
        for slot, _req, _k, sfx in assigned:
            w = len(sfx)
            toks[:w, slot] = sfx
            act[:w, slot] = True
        outs = []
        for i in range(W):
            nxt, self.cache = self._decode_fused(
                self.params, jnp.asarray(toks[i]), jnp.asarray(act[i]),
                self.cache)
            outs.append(nxt)
        # pbcheck: disable=R2 (designed sync: ONE host read for the whole suffix walk; admission needs the hits' first tokens)
        walked = np.asarray(jnp.stack(outs))
        for slot, req, k, sfx in assigned:
            self.prefix_hits += 1
            self.prefix_hit_tokens += k
            self.n_prefill_tokens += len(sfx)
            tok = int(walked[len(sfx) - 1, slot])
            req.generated.append(tok)
            at_eos = req.eos_id is not None and tok == req.eos_id
            if len(req.generated) >= req.max_new_tokens or at_eos:
                req.done = True
                self.free.append(slot)
                req.slot = -1
            else:
                self.active[slot] = req
        self._io_dirty = True

    def _admit_rows(self, reqs: List[ServeRequest]) -> None:
        bucket = max(self.bucket_for(r) for r in reqs)
        # Row count is pinned to n_slots on the bucketed path so prefill
        # compile counts depend ONLY on the length bucket (the compile-cache
        # contract the CI guard enforces).  Pad rows cost extra FLOPs when
        # admitting fewer requests than slots, but the cost is bounded by
        # n_slots x bucket and the batch dim is underutilized at these
        # sizes anyway; variable row counts would multiply the compile
        # bound by a row-bucket factor.
        P = self.n_slots if self._can_bucket else len(reqs)
        toks = np.zeros((P, bucket), np.int32)
        last_idx = np.zeros((P,), np.int32)
        slots = np.zeros((P,), np.int32)
        valid = np.zeros((P,), bool)
        assigned: List[Tuple[int, int, ServeRequest]] = []
        for i, req in enumerate(reqs):
            t = np.asarray(req.tokens, np.int64)
            if req.generated:
                t = np.concatenate([t, np.asarray(req.generated, np.int64)])
            L = len(t)
            self.n_prefill_tokens += L
            toks[i, :L] = t
            last_idx[i] = L - 1
            slot = self.free.pop()
            req.slot = slot
            slots[i] = slot
            valid[i] = True
            assigned.append((i, slot, req))
        backend = self._choose_prefill_backend(P, bucket)
        if backend == "pipeline":
            # TTFT-critical cold-start path: the prompt runs the shard_map
            # pipeline belt over the partially-loaded stage chain; the slot
            # write reuses the shared donated scatter
            logits, state = self._pipe_prefill(
                self.params, {"tokens": jnp.asarray(toks),
                              "last_index": jnp.asarray(last_idx)})
            self.cache = self._scatter_fused(
                self.cache, state, jnp.asarray(slots),
                jnp.asarray(last_idx + 1), jnp.asarray(valid))
            first = self._sampler(logits).astype(jnp.int32)
            self.n_prefill_pipeline += len(reqs)
        else:
            first, self.cache = self._prefill_fused(
                self.params, jnp.asarray(toks), jnp.asarray(last_idx),
                jnp.asarray(slots), jnp.asarray(valid), self.cache)
        # pbcheck: disable=R2 (designed sync: admission reads first tokens to catch immediate EOS before slot commit)
        first_host = np.asarray(first)
        self.n_prefill_calls += 1
        self.n_prefill_reqs += len(reqs)
        for i, slot, req in assigned:
            tok = int(first_host[i])
            req.generated.append(tok)
            at_eos = req.eos_id is not None and tok == req.eos_id
            if len(req.generated) >= req.max_new_tokens or at_eos:
                req.done = True       # satisfied at admission (re-submit tail)
                self.free.append(slot)
                req.slot = -1
            else:
                self.active[slot] = req
        self._io_dirty = True

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def step(self) -> List[ServeRequest]:
        """One decode step for all active slots; returns finished requests."""
        if not self.active:
            return []        # no sampler/decode work when nothing is active
        t0 = time.perf_counter()
        if self._io_dirty:
            toks = np.zeros((self.n_slots,), np.int32)
            act = np.zeros((self.n_slots,), bool)
            for slot, req in self.active.items():
                toks[slot] = req.generated[-1]
                act[slot] = True
            self._dev_tokens = jnp.asarray(toks)
            self._dev_active = jnp.asarray(act)
            self._io_dirty = False
        nxt, self.cache = self._decode_fused(
            self.params, self._dev_tokens, self._dev_active, self.cache)
        self._dev_tokens = nxt
        # pbcheck: disable=R2 (designed sync: THE one host transfer per decode step; EOS checks need the token ids)
        nxt_host = np.asarray(nxt)
        self.n_decode_steps += 1
        finished = []
        done_slots: List[Tuple[int, ServeRequest]] = []
        for slot, req in list(self.active.items()):
            tok = int(nxt_host[slot])
            req.generated.append(tok)
            at_eos = req.eos_id is not None and tok == req.eos_id
            if len(req.generated) >= req.max_new_tokens or at_eos:
                req.done = True
                finished.append(req)
                done_slots.append((slot, req))
                del self.active[slot]
                self.free.append(slot)
        if finished:
            self._io_dirty = True        # active mask changed
            if self.prefix_cache is not None and self._can_bucket:
                # deposit finished prompts before their slots are reused
                # (nothing else touches the cache within this step)
                self._deposit_prefixes(done_slots)
        self.decode_time_s += time.perf_counter() - t0
        return finished

    def _deposit_prefixes(self, pairs: Sequence[Tuple[int, ServeRequest]]
                          ) -> None:
        """Insert finished requests' prompt-prefix KV into the attached
        prefix cache.  Prompts the store already covers are skipped
        BEFORE exporting, so the device->host row transfer only happens
        for genuinely new prefixes; the batched ``export_slots`` keeps it
        to one transfer per kind leaf for the rest."""
        todo: List[Tuple[int, ServeRequest, np.ndarray]] = []
        for slot, req in pairs:
            toks = np.asarray(req.tokens, np.int64)
            if toks.shape[0] < 2:
                continue                 # nothing reusable below 2 tokens
            if self.prefix_cache.covers(self.cfg.name, req.adapter, toks):
                continue
            todo.append((slot, req, toks))
        if not todo:
            return
        snaps = export_slots(self.cache, [s for s, _, _ in todo],
                             arch=self.cfg.name, max_len=self.max_len)
        for (_slot, req, toks), snap in zip(todo, snaps):
            self.prefix_cache.insert(self.cfg.name, req.adapter, toks,
                                     min(toks.shape[0], snap.pos),
                                     rows=snap.rows)

    def drain(self, export_state: bool = True) -> List[ServeRequest]:
        """Pull every in-flight request out of the batch (server crash /
        re-route path): slots are freed, requests keep their generated
        prefix so ``admit`` elsewhere resumes them exactly.

        With ``export_state`` each request also carries a ``KVSnapshot``
        of its slot (per-layer KV/recurrent rows + pos), so a survivor can
        ``import_snapshot`` it into a free slot and continue decoding with
        ZERO re-prefilled tokens instead of recomputing prompt+prefix.
        """
        items = sorted(self.active.items())
        if export_state and items:
            # batched export: one host transfer per kind leaf total
            snaps = export_slots(self.cache, [s for s, _ in items],
                                 arch=self.cfg.name, max_len=self.max_len)
            for (_, req), snap in zip(items, snaps):
                req.snapshot = snap
                # the rows are already on host: deposit the prompt prefix
                # for free (drain insertion — the other half of the
                # completion-time deposit)
                if self.prefix_cache is not None and self._can_bucket:
                    toks = np.asarray(req.tokens, np.int64)
                    if toks.shape[0] >= 2 and not self.prefix_cache.covers(
                            self.cfg.name, req.adapter, toks):
                        self.prefix_cache.insert(
                            self.cfg.name, req.adapter, toks,
                            min(toks.shape[0], snap.pos), rows=snap.rows)
        drained = []
        for slot, req in items:
            req.slot = -1
            self.free.append(slot)
            drained.append(req)
        self.active.clear()
        self._io_dirty = True
        return drained

    def export_snapshot(self, slot: int) -> KVSnapshot:
        """Snapshot ``slot``'s state to host memory (see serving.snapshot)."""
        return export_slot(self.cache, slot, arch=self.cfg.name,
                           max_len=self.max_len)

    def import_snapshot(self, req: ServeRequest, snap: KVSnapshot) -> bool:
        """Resume ``req`` from a migrated snapshot in a free slot.

        The state rows are scattered into the donated cache in one jitted
        call; the request starts decoding from its last sampled token on
        the next ``step`` — no prefill happens.  False if the batch is
        full or the snapshot's shapes don't match this batcher.
        """
        if not self.free:
            return False
        if not snap.compatible_with(self.cache, self.cfg.name, self.max_len):
            return False
        slot = self.free.pop()
        # numpy rows go straight into the jitted call (the transfer happens
        # as part of the one dispatch — no per-leaf host round-trip)
        self.cache = self._import_fused(
            self.cache, snap.rows, jnp.asarray(slot, jnp.int32),
            jnp.asarray(snap.pos, jnp.int32))
        req.slot = slot
        self.active[slot] = req
        self._io_dirty = True
        self.n_migrated_in += 1
        self.migrated_tokens_in += snap.pos
        return True

    def import_snapshots(self, pairs: Sequence[Tuple[ServeRequest,
                                                     KVSnapshot]]
                         ) -> List[ServeRequest]:
        """Batched migration import: N displaced requests' snapshots land
        in ONE donated scatter (one dispatch, one compile shared with the
        other row-scatter users) instead of N sequential
        ``import_snapshot`` calls — the survivor-absorbs-several-victims
        path after a whole-server crash.

        Imports as many pairs as there are free slots / compatible
        snapshots (in order) and returns the requests actually admitted;
        the caller re-routes the rest.
        """
        usable: List[Tuple[ServeRequest, KVSnapshot]] = []
        for req, snap in pairs:
            if len(usable) >= len(self.free):
                break
            if snap is not None and snap.compatible_with(
                    self.cache, self.cfg.name, self.max_len):
                usable.append((req, snap))
        if not usable:
            return []
        P = self.n_slots
        slots = np.zeros((P,), np.int32)
        pos = np.zeros((P,), np.int32)
        valid = np.zeros((P,), bool)
        # stack each leaf's per-request rows (L, ...) -> (L, P, ...); pad
        # rows stay zero and are masked out by ``valid``
        rows: Dict[str, Dict[str, np.ndarray]] = {}
        for kind, leaves in usable[0][1].rows.items():
            rows[kind] = {}
            for leaf, a in leaves.items():
                buf = np.zeros((a.shape[0], P) + a.shape[1:], a.dtype)
                for j, (_, s) in enumerate(usable):
                    buf[:, j] = s.rows[kind][leaf]
                rows[kind][leaf] = buf
        out: List[ServeRequest] = []
        for j, (req, snap) in enumerate(usable):
            slot = self.free.pop()
            slots[j] = slot
            pos[j] = snap.pos
            valid[j] = True
            req.slot = slot
            self.active[slot] = req
            self.n_migrated_in += 1
            self.migrated_tokens_in += snap.pos
            out.append(req)
        self.cache = self._scatter_fused(
            self.cache, rows, jnp.asarray(slots), jnp.asarray(pos),
            jnp.asarray(valid))
        self.n_batched_imports += 1
        self._io_dirty = True
        return out

    def warm_import(self) -> None:
        """Pre-compile the snapshot-import jits (recovery-path warm-up).

        Writes slot 0's own rows back to itself — a semantic no-op — so
        the first real migration pays steady-state import cost, not an
        XLA compile, inside the post-crash TTFT window.  The batched
        scatter is warmed with an all-invalid write for the same reason.
        """
        rows = {kind: {leaf: arr[:, 0]
                       for leaf, arr in self.cache[kind].items()}
                for kind in ("attn", "ssm", "rec") if kind in self.cache}
        self.cache = self._import_fused(
            self.cache, rows, jnp.asarray(0, jnp.int32),
            self.cache["pos"][0])
        zeros = {kind: {leaf: jnp.zeros_like(arr)
                        for leaf, arr in self.cache[kind].items()}
                 for kind in ("attn", "ssm", "rec") if kind in self.cache}
        P = self.n_slots
        self.cache = self._scatter_fused(
            self.cache, zeros, jnp.zeros((P,), jnp.int32),
            jnp.zeros((P,), jnp.int32), jnp.zeros((P,), bool))

    def reconstruct_inflight(self, has_state: Sequence[bool]
                             ) -> Dict[str, float]:
        """Partial-crash recovery (paper §4.4.2) for the live batch: rebuild
        only the layers whose state died, per active slot, via
        ``core.kv_reconstruct.reconstruct_cache`` — attention layers with
        surviving KV get the Q-only recompute, missing layers a full
        per-layer prefill, layers above the deepest missing one are
        untouched.  Requests stay in their slots; decode resumes exactly.
        Returns the summed per-layer work stats."""
        from repro.core.kv_reconstruct import reconstruct_cache
        totals: Dict[str, float] = {}
        if not self.active or all(has_state):
            return totals
        for slot, req in sorted(self.active.items()):
            # tokens processed so far: prompt + generated prefix minus the
            # last sampled token (it is the NEXT decode step's input)
            seq = np.asarray(req.tokens, np.int64)
            tail = req.generated[:-1]
            if tail:
                seq = np.concatenate([seq, np.asarray(tail, np.int64)])
            view = {"pos": self.cache["pos"][slot:slot + 1]}
            for kind in ("attn", "ssm", "rec"):
                if kind in self.cache:
                    view[kind] = {leaf: arr[:, slot:slot + 1]
                                  for leaf, arr in self.cache[kind].items()}
            rebuilt, stats = reconstruct_cache(
                self.cfg, self.params, {"tokens": jnp.asarray(seq)[None]},
                view, has_state, max_len=self.max_len)
            rows = {kind: {leaf: arr[:, 0]
                           for leaf, arr in rebuilt[kind].items()}
                    for kind in ("attn", "ssm", "rec") if kind in rebuilt}
            self.cache = self._import_fused(
                self.cache, rows, jnp.asarray(slot, jnp.int32),
                jnp.asarray(len(seq), jnp.int32))
            for k, v in stats.items():
                totals[k] = totals.get(k, 0.0) + float(v)
            totals["reconstructed_reqs"] = \
                totals.get("reconstructed_reqs", 0.0) + 1.0
        return totals

    def relay_inflight(self, has_state: Sequence[bool]) -> Dict[str, float]:
        """Repartition re-lay of the live batch onto a changed partition:
        rebuild the layers whose KV died for EVERY active slot, then land
        all rebuilt rows in ONE donated scatter (the same ``fused_scatter``
        batched migration uses — no new compile) instead of one import per
        slot.  Slots with equal merged-sequence length share one batched
        ``reconstruct_cache`` call (exact — no padding), so the recompute
        cost scales with the number of distinct lengths, not requests.
        Requests keep their slots and their sampled prefix; decode resumes
        bit-identically with ZERO re-prefilled tokens.  Surviving layers
        are reused verbatim (Q-only recompute where possible), like
        ``reconstruct_inflight``, whose per-layer work stats this returns
        summed over requests, under ``relayed_reqs``."""
        from repro.core.kv_reconstruct import reconstruct_cache
        totals: Dict[str, float] = {}
        if not self.active or all(has_state):
            return totals
        P = self.n_slots
        slots = np.zeros((P,), np.int32)
        pos = np.zeros((P,), np.int32)
        valid = np.zeros((P,), bool)
        rows: Dict[str, Dict[str, np.ndarray]] = {}
        groups: Dict[int, List] = {}
        for j, (slot, req) in enumerate(sorted(self.active.items())):
            seq = np.asarray(req.tokens, np.int64)
            tail = req.generated[:-1]
            if tail:
                seq = np.concatenate([seq, np.asarray(tail, np.int64)])
            groups.setdefault(len(seq), []).append((j, slot, seq))
        for _, members in sorted(groups.items()):
            g_slots = np.asarray([s for _, s, _ in members], np.int32)
            tokens = jnp.asarray(np.stack([q for _, _, q in members]))
            view = {"pos": self.cache["pos"][g_slots]}
            for kind in ("attn", "ssm", "rec"):
                if kind in self.cache:
                    view[kind] = {leaf: arr[:, g_slots]
                                  for leaf, arr in self.cache[kind].items()}
            rebuilt, stats = reconstruct_cache(
                self.cfg, self.params, {"tokens": tokens}, view, has_state,
                max_len=self.max_len)
            for kind in ("attn", "ssm", "rec"):
                if kind not in rebuilt:
                    continue
                dst = rows.setdefault(kind, {})
                for leaf, arr in rebuilt[kind].items():
                    a = np.asarray(arr)
                    if leaf not in dst:
                        dst[leaf] = np.zeros(
                            (a.shape[0], P) + a.shape[2:], a.dtype)
                    for gi, (j, _, _) in enumerate(members):
                        dst[leaf][:, j] = a[:, gi]
            for gi, (j, slot, seq) in enumerate(members):
                slots[j] = slot
                pos[j] = len(seq)
                valid[j] = True
            # per-layer/token work counts are batch-invariant in
            # reconstruct_cache: scale by group size to keep the
            # sum-over-requests semantics of the per-slot path
            for k, v in stats.items():
                totals[k] = totals.get(k, 0.0) + float(v) * len(members)
            totals["relayed_reqs"] = totals.get("relayed_reqs", 0.0) \
                + float(len(members))
        self.cache = self._scatter_fused(
            self.cache, rows, jnp.asarray(slots), jnp.asarray(pos),
            jnp.asarray(valid))
        self.n_relay_scatters += 1
        self._io_dirty = True
        return totals

    @property
    def n_active(self) -> int:
        return len(self.active)

    # ------------------------------------------------------------------
    # instrumentation
    # ------------------------------------------------------------------
    def compile_stats(self) -> Dict[str, int]:
        """XLA compile counts of the two hot-path functions.  The decode
        count must stay 1 for the engine's lifetime (adapter switches swap
        params, never retrace); the prefill count is bounded by the number
        of length buckets actually seen."""
        def _n(fn):
            try:
                return int(fn._cache_size())
            except Exception:       # private API moved — report -1, don't die
                return -1
        return {"decode_compiles": _n(self._decode_fused),
                "prefill_compiles": _n(self._prefill_fused)}

    def hotpath_stats(self) -> Dict[str, float]:
        s: Dict[str, float] = {
            "n_decode_steps": float(self.n_decode_steps),
            "decode_time_s": self.decode_time_s,
            "decode_steps_per_s": (self.n_decode_steps / self.decode_time_s
                                   if self.decode_time_s > 0 else 0.0),
            "n_prefill_calls": float(self.n_prefill_calls),
            "n_prefill_reqs": float(self.n_prefill_reqs),
            "n_prefill_pipeline": float(self.n_prefill_pipeline),
            "n_batched_imports": float(self.n_batched_imports),
            "n_relay_scatters": float(self.n_relay_scatters),
            "n_prefill_tokens": float(self.n_prefill_tokens),
            "prefix_hits": float(self.prefix_hits),
            "prefix_hit_tokens": float(self.prefix_hit_tokens),
            "prefix_evictions": (
                0.0 if self.prefix_cache is None
                else float(self.prefix_cache.evictions
                           - self._prefix_evict_base)),
        }
        s.update({k: float(v) for k, v in self.compile_stats().items()})
        return s


class ServingEngine:
    """Request dispatcher + continuous batcher + adapter epochs.

    ``set_params`` supports the PipeBoost adapter switch (merged weights
    swapped between epochs) and the post-recovery parameter refresh.
    """

    def __init__(self, cfg: ArchConfig, params, *, n_slots: int = 4,
                 max_len: int = 256,
                 policy: Optional[EpochSchedulerPolicy] = None,
                 adapter_params: Optional[Dict[str, Any]] = None):
        self.cfg = cfg
        self.batcher = ContinuousBatcher(cfg, params, n_slots, max_len)
        self.policy = policy or EpochSchedulerPolicy()
        self.policy_state = self.policy.make_state()
        self.adapter_params = adapter_params or {}
        self.base_params = params
        self.active_adapter: Optional[str] = None
        self.clock = 0.0
        self.completed: List[ServeRequest] = []
        self.n_adapter_switches = 0

    def submit(self, req: ServeRequest):
        # stamp fresh requests that carry no arrival of their own; requests
        # with a trace arrival or a generated prefix (re-submits) keep theirs
        if req.arrival is None:
            req.arrival = self.clock
        self.policy.enqueue(self.policy_state, _PolicyItem(req))

    def _switch_adapter(self, name: Optional[str]):
        if name == self.active_adapter:
            return
        # params are a traced argument of the batcher's jitted hot path, so
        # an epoch switch is a pointer swap — no retrace, no recompile
        self.batcher.params = self.base_params if name is None \
            else self.adapter_params[name]
        self.active_adapter = name
        self.n_adapter_switches += 1

    def _admit_pending(self) -> List[ServeRequest]:
        """Admit queued requests per the adapter policy into free slots.

        Epoch barrier: merged-LoRA means a switch swaps the weights for
        EVERY active slot, so a different adapter is only admitted once the
        batch has drained (the paper's epoch semantics, Fig. 5).  Same-bucket
        requests within a policy batch prefill together in one padded call.
        Returns requests already satisfied at admission (re-submitted tails).
        """
        satisfied: List[ServeRequest] = []
        while self.batcher.free:
            nxt = self.policy.peek_adapter(self.policy_state)
            if nxt is None:
                break
            nxt_name = None if nxt == "__base__" else nxt
            if self.batcher.active and nxt_name != self.active_adapter:
                break  # drain before switching (epoch barrier)
            adapter, batch = self.policy.next_batch(self.policy_state)
            if adapter is None:
                break
            self._switch_adapter(adapter if adapter != "__base__" else None)
            n_free = len(self.batcher.free)
            if len(batch) > n_free:
                # policy batch can exceed free slots under staggered
                # occupancy — hand the tail back for the next tick
                self.policy.requeue_front(self.policy_state, batch[n_free:])
                batch = batch[:n_free]
            groups: Dict[int, List[_PolicyItem]] = {}
            for item in batch:
                groups.setdefault(self.batcher.bucket_for(item.req),
                                  []).append(item)
            for _, items in sorted(groups.items()):
                self.batcher.admit_batch([it.req for it in items])
                for it in items:
                    if it.req.first_token_at is None:
                        it.req.first_token_at = self.clock
                    if it.req.done:
                        it.req.finished_at = self.clock
                        self.completed.append(it.req)
                        satisfied.append(it.req)
        return satisfied

    def step(self, now: Optional[float] = None) -> List[ServeRequest]:
        """One scheduling + decode tick; returns requests finished this tick.

        With ``now`` the caller owns the clock (the cluster router drives
        many servers off one shared clock); without it the engine advances
        its own logical step clock by 1 per decode.
        """
        if now is not None:
            self.clock = now
        finished = self._admit_pending()
        if not self.batcher.active:
            return finished
        done = self.batcher.step()
        if now is None:
            self.clock += 1.0  # logical step clock
        for r in done:
            r.finished_at = self.clock
            self.completed.append(r)
        return finished + done

    def admit_with_state(self, req: ServeRequest) -> bool:
        """Admit a migrated request by importing its ``KVSnapshot`` into a
        free slot — the state-preserving alternative to ``submit`` for
        requests drained off a crashed server.  Zero prompt tokens are
        re-prefilled; decode continues from the request's last sampled
        token.

        Falls back (returns False, snapshot kept) when: no free slot, the
        snapshot's shapes don't match, the request needs an adapter this
        engine doesn't have, or the batch is mid-epoch on a *different*
        adapter (merged-LoRA weights apply to every slot, so importing
        across the epoch barrier would decode with the wrong weights).
        """
        snap = req.snapshot
        if snap is None or not self.batcher.free:
            return False
        name = req.adapter
        if name is not None and name not in self.adapter_params:
            return False
        if self.batcher.active:
            if name != self.active_adapter:
                return False
        else:
            self._switch_adapter(name)
        if not self.batcher.import_snapshot(req, snap):
            return False
        if req.arrival is None:
            req.arrival = self.clock
        req.snapshot = None
        return True

    def admit_with_state_batch(self, reqs: Sequence[ServeRequest]
                               ) -> List[ServeRequest]:
        """Batched ``admit_with_state``: displaced requests sharing an
        adapter import their snapshots in ONE donated scatter (one
        dispatch) instead of one call each — how a survivor absorbs
        several victims of a whole-server crash.  Applies the same guards
        (free slots, shape compatibility, adapter availability, epoch
        barrier) and returns the requests actually admitted; the caller
        falls back to re-prefill for the rest.
        """
        accepted: List[ServeRequest] = []
        groups: Dict[Optional[str], List[ServeRequest]] = {}
        for r in reqs:
            if r.snapshot is not None:
                groups.setdefault(r.adapter, []).append(r)
        for name, group in groups.items():
            if name is not None and name not in self.adapter_params:
                continue
            if self.batcher.active:
                if name != self.active_adapter:
                    continue            # epoch barrier (see admit_with_state)
            else:
                self._switch_adapter(name)
            done = self.batcher.import_snapshots(
                [(r, r.snapshot) for r in group])
            for r in done:
                if r.arrival is None:
                    r.arrival = self.clock
                r.snapshot = None
                accepted.append(r)
        return accepted

    def drain_inflight(self, export_state: bool = True) -> List[ServeRequest]:
        """Remove every in-flight AND queued request (crash re-route path);
        in-flight requests keep their generated prefix — and, with
        ``export_state``, their KV snapshot — for exact resumption on
        another server."""
        out = self.batcher.drain(export_state=export_state)
        while True:
            adapter, batch = self.policy.next_batch(self.policy_state)
            if adapter is None:
                break
            out.extend(item.req for item in batch)
        return out

    def attach_prefix_cache(self, cache) -> None:
        """Attach a cross-request ``PrefixCache`` to the batcher (see
        ContinuousBatcher.attach_prefix_cache)."""
        self.batcher.attach_prefix_cache(cache)

    def reconstruct_inflight(self, has_state) -> Dict[str, float]:
        """Partial-crash in-place rebuild of the live batch's lost layers
        (see ContinuousBatcher.reconstruct_inflight)."""
        return self.batcher.reconstruct_inflight(has_state)

    def relay_inflight(self, has_state) -> Dict[str, float]:
        """Repartition re-lay: rebuild lost layers for the whole live
        batch and land them in one donated scatter (see
        ContinuousBatcher.relay_inflight)."""
        return self.batcher.relay_inflight(has_state)

    # ---- scheduling surface (consumed by cluster/scheduler.py policies) --
    def resident_adapters(self) -> set:
        """Adapters admittable RIGHT NOW without an epoch-switch stall.

        Merged-LoRA semantics: while the batch is busy, only the active
        adapter's weights are merged in — admitting anything else must
        wait for the epoch to drain.  An idle batch can switch to any
        loaded adapter with a pointer swap (params are a traced argument),
        so everything this engine holds is resident.  ``None`` names the
        base model.
        """
        if self.batcher.active:
            return {self.active_adapter}
        return set(self.adapter_params) | {None, self.active_adapter}

    def predicted_step_cost_s(self, default: float = 0.05) -> float:
        """Measured mean wall-clock cost of one decode step (the
        SLO-aware dispatch's unit of predicted work); ``default`` until
        this engine has decoded anything."""
        b = self.batcher
        if b.n_decode_steps > 0 and b.decode_time_s > 0:
            return b.decode_time_s / b.n_decode_steps
        return default

    def queued_requests(self) -> List[ServeRequest]:
        """Requests enqueued but not yet admitted (no first token yet)."""
        out: List[ServeRequest] = []
        for q in self.policy_state.get("queues", {}).values():
            out.extend(it.req for it in q)
        out.extend(it.req for it in self.policy_state.get("fifo", ()))
        return out

    @property
    def n_pending(self) -> int:
        """Queued (not yet admitted) + in-flight requests."""
        return len(self.queued_requests()) + self.batcher.n_active

    def hotpath_stats(self) -> Dict[str, float]:
        return self.batcher.hotpath_stats()

    def run(self, max_steps: int = 10_000) -> List[ServeRequest]:
        """Drain all queues: admit per the adapter policy, decode until done."""
        for _ in range(max_steps):
            self.step()
            if not self.batcher.active \
                    and self.policy.peek_adapter(self.policy_state) is None:
                break
        return self.completed


class _PolicyItem:
    """Adapter-scheduler item wrapping a ServeRequest."""

    def __init__(self, req: ServeRequest):
        self.req = req
        self.adapter = req.adapter or "__base__"
        self.arrival = req.arrival
        self.service = 0.0
