"""Cross-request prefix cache: fleet-wide KV reuse for shared prompts.

PipeBoost's thesis — serverless replicas share almost all of their state,
so move/reuse it instead of recomputing — applies to KV state too:
system prompts and few-shot templates are shared by huge request
populations, yet a vanilla serving stack re-prefills every admission from
token zero.  This module is the host-side store behind the serving
engine's prefix reuse: completed (or drained) requests deposit the KV
rows of their prompt; a later admission whose prompt shares a token
prefix imports those rows through the batcher's existing donated-scatter
path and prefills ONLY the uncached suffix.

Design
------
* **Entries keyed by (arch, adapter)** and matched by *longest common
  prefix* over the stored full token arrays — NOT by a per-length hash.
  LCP matching is what makes the shared-prefix/different-suffix case
  work: a donor prompt of length 388 serves a new prompt that shares
  only its first 384 tokens, with no entry ever having been inserted at
  length 384.
* **Rows are host numpy in ``KVSnapshot`` layout** (kind -> leaf ->
  ``(L, ...)``), i.e. exactly what ``export_slots`` produces and what the
  batcher's shared ``fused_scatter`` consumes — import costs one donated
  dispatch, zero new compiles.  Rows past the usable prefix are stale
  but harmless: attention masks beyond ``pos`` and the suffix walk
  overwrites them in place.
* **Rows-less entries** (``rows=None`` with an explicit ``nbytes``)
  support the modeled cluster backend (``cluster/simserver.py``), which
  tracks hit/byte accounting without holding real KV.
* **Deterministic LRU + byte budget**: recency is a logical counter (no
  wall clock), so fleet replays are bit-reproducible under both the tick
  and the event engine.  Eviction skips **ref-counted (pinned)** entries:
  ``probe`` acquires a reference that the importer releases only after
  the scatter has consumed the rows, so eviction can never race an
  in-flight import.
* **Spill/resurrect**: ``export_entries``/``import_entries`` move the
  whole store through the cluster's host-side ``StateTier``
  (``cluster/state_tier.py``) when an idle server retires, so a later
  spawn for the same pool starts warm.

See ``docs/ARCHITECTURE.md`` § "Fleet state tier".
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

GroupKey = Tuple[str, Optional[str]]      # (arch name, adapter name|None)


def _lcp(a: np.ndarray, b: np.ndarray) -> int:
    """Longest common prefix length of two 1-D token arrays."""
    n = min(a.shape[0], b.shape[0])
    if n == 0:
        return 0
    m = a[:n] == b[:n]
    return n if m.all() else int(np.argmin(m))


@dataclass(eq=False)
class PrefixEntry:
    """One cached prompt prefix: the full token array it was deposited
    under, the number of leading tokens with valid KV state (``pos``),
    and the per-layer rows in ``KVSnapshot`` wire layout (host numpy;
    ``None`` for modeled/accounting-only entries).

    ``eq=False``: entries are identity-compared — the generated ``__eq__``
    would compare token *arrays* and break ``list.remove`` on eviction."""
    tokens: np.ndarray                    # full prompt tokens (S,)
    pos: int                              # leading tokens with cached state
    rows: Optional[Dict[str, Dict[str, np.ndarray]]]
    nbytes: int
    last_used: int = 0                    # logical LRU stamp
    refs: int = 0                         # pinned by in-flight imports


class PrefixCache:
    """LRU + byte-budget store of prompt-prefix KV rows.

    One instance serves one server's batcher (the cluster attaches a
    fresh cache per spawned server and moves its contents through the
    ``StateTier`` on retirement), but nothing prevents sharing: all
    state is host-side and keyed by (arch, adapter).
    """

    def __init__(self, capacity_bytes: int = 64 << 20):
        self.capacity_bytes = capacity_bytes
        self._groups: Dict[GroupKey, List[PrefixEntry]] = {}
        self._tick = 0                    # deterministic recency counter
        self.bytes_used = 0
        self.hits = 0
        self.hit_tokens = 0
        self.evictions = 0
        self.insertions = 0

    # ------------------------------------------------------------------
    # lookup
    # ------------------------------------------------------------------
    def _best(self, arch: str, adapter: Optional[str],
              tokens: np.ndarray) -> Tuple[Optional[PrefixEntry], int]:
        """Entry with the longest usable cached prefix for ``tokens``.

        Usable length is ``min(lcp, len(tokens) - 1, entry.pos)``: at
        least one suffix token must remain to produce the first sampled
        logits, and only positions the entry actually holds state for
        count.  Returns ``(None, 0)`` when nothing matches.
        """
        toks = np.asarray(tokens).ravel()
        cap = toks.shape[0] - 1
        best: Optional[PrefixEntry] = None
        best_k = 0
        for e in self._groups.get((arch, adapter), ()):
            k = min(_lcp(toks, e.tokens), cap, e.pos)
            if k > best_k:
                best, best_k = e, k
        return best, best_k

    def match_len(self, arch: str, adapter: Optional[str],
                  tokens: np.ndarray) -> int:
        """Longest usable cached prefix length for ``tokens`` — a pure
        read (no LRU bump, no ref, no hit accounting).  Dispatch pricing
        (``SloAware.prefix_bonus_s_per_token``) uses this."""
        _, k = self._best(arch, adapter, tokens)
        return k

    def probe(self, arch: str, adapter: Optional[str], tokens: np.ndarray
              ) -> Optional[Tuple[PrefixEntry, int]]:
        """Look up the best prefix match for an admission.

        On a hit (usable prefix >= 1 token) the entry is **pinned**
        (``refs += 1``) and its recency bumped; the caller MUST call
        :meth:`release` once the import has consumed the rows.  Hit
        counters accrue here.  Returns ``(entry, k)`` or ``None``.
        """
        e, k = self._best(arch, adapter, tokens)
        if e is None or k < 1:
            return None
        self._tick += 1
        e.last_used = self._tick
        e.refs += 1
        self.hits += 1
        self.hit_tokens += k
        return e, k

    def release(self, entry: PrefixEntry) -> None:
        """Unpin an entry acquired by :meth:`probe` (import landed)."""
        entry.refs = max(0, entry.refs - 1)

    def covers(self, arch: str, adapter: Optional[str], tokens: np.ndarray,
               pos: Optional[int] = None) -> bool:
        """True when an existing entry already holds state for the first
        ``pos`` tokens (default: all) of ``tokens`` — insertion would be
        a no-op, so callers can skip the device->host export entirely."""
        toks = np.asarray(tokens).ravel()
        want = toks.shape[0] if pos is None else min(pos, toks.shape[0])
        for e in self._groups.get((arch, adapter), ()):
            if e.pos >= want and _lcp(toks, e.tokens) >= want:
                return True
        return False

    # ------------------------------------------------------------------
    # insertion / eviction
    # ------------------------------------------------------------------
    def insert(self, arch: str, adapter: Optional[str], tokens: np.ndarray,
               pos: int, rows: Optional[Dict[str, Dict[str, np.ndarray]]]
               = None, nbytes: Optional[int] = None) -> bool:
        """Deposit a prompt's prefix state; True if it was admitted.

        Skips exact/covering duplicates, drops entries the new one
        strictly dominates (their tokens are a prefix of ours and their
        ``pos`` no larger), then evicts LRU-first to the byte budget —
        never touching pinned entries.  ``nbytes`` is derived from
        ``rows`` when omitted (rows-less entries must pass it).
        """
        toks = np.asarray(tokens).ravel()
        pos = int(min(pos, toks.shape[0]))
        if pos < 1:
            return False
        if nbytes is None:
            if rows is None:
                raise ValueError("rows-less insert needs an explicit nbytes")
            nbytes = int(toks.nbytes
                         + sum(a.nbytes for leaves in rows.values()
                               for a in leaves.values()))
        if nbytes > self.capacity_bytes:
            return False                  # larger than the whole budget
        group = self._groups.setdefault((arch, adapter), [])
        dominated: List[PrefixEntry] = []
        for e in group:
            k = _lcp(toks, e.tokens)
            if k >= pos and e.pos >= pos:
                return False              # already covered: keep theirs
            if (k == e.tokens.shape[0] and e.pos <= pos and e.refs == 0):
                dominated.append(e)       # ours strictly covers e
        for e in dominated:
            group.remove(e)
            self.bytes_used -= e.nbytes
            self.evictions += 1
        self._tick += 1
        group.append(PrefixEntry(tokens=toks.copy(), pos=pos, rows=rows,
                                 nbytes=int(nbytes), last_used=self._tick))
        self.bytes_used += int(nbytes)
        self.insertions += 1
        self._evict_to_budget()
        return True

    def _evict_to_budget(self) -> None:
        """LRU eviction down to ``capacity_bytes``; pinned entries are
        skipped (an in-flight import may still be reading their rows),
        so the store can transiently overshoot while refs are held."""
        while self.bytes_used > self.capacity_bytes:
            victim_key = None
            victim = None
            for key, group in self._groups.items():
                for e in group:
                    if e.refs > 0:
                        continue
                    if victim is None or e.last_used < victim.last_used:
                        victim_key, victim = key, e
            if victim is None:
                return                    # everything left is pinned
            self._groups[victim_key].remove(victim)
            self.bytes_used -= victim.nbytes
            self.evictions += 1

    # ------------------------------------------------------------------
    # spill / resurrect
    # ------------------------------------------------------------------
    def export_entries(self) -> List[Tuple[GroupKey, PrefixEntry]]:
        """Flat ``(key, entry)`` list of the whole store, deterministic
        order — what an idle retirement spills to the ``StateTier``."""
        out: List[Tuple[GroupKey, PrefixEntry]] = []
        for key in sorted(self._groups, key=lambda k: (k[0], k[1] or "")):
            for e in self._groups[key]:
                out.append((key, e))
        return out

    def import_entries(self, items) -> int:
        """Merge spilled ``(key, entry)`` pairs back in (resurrection on
        a fresh spawn); returns how many entries were admitted."""
        n = 0
        for (arch, adapter), e in items:
            if self.insert(arch, adapter, e.tokens, e.pos, rows=e.rows,
                           nbytes=e.nbytes):
                n += 1
        return n

    # ------------------------------------------------------------------
    # accounting
    # ------------------------------------------------------------------
    @property
    def n_entries(self) -> int:
        """Total entries across all (arch, adapter) groups."""
        return sum(len(g) for g in self._groups.values())

    def stats(self) -> Dict[str, float]:
        """Counter snapshot (hits/tokens/evictions/insertions/bytes)."""
        return {
            "prefix_hits": float(self.hits),
            "prefix_hit_tokens": float(self.hit_tokens),
            "prefix_evictions": float(self.evictions),
            "prefix_insertions": float(self.insertions),
            "prefix_bytes": float(self.bytes_used),
            "prefix_entries": float(self.n_entries),
        }
