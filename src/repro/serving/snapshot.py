"""Portable per-request KV/recurrent state snapshots (crash migration).

PipeBoost's recovery claim (§4.4) is that surviving hardware keeps serving
*without* redoing prefill.  At cluster scale that means a crashed server's
in-flight requests must carry their decode state to a survivor instead of
re-prefilling prompt+prefix there (λScale's fast state handoff).  The unit
of transfer is a ``KVSnapshot``: one batch slot's slice of every cache
leaf — per-layer KV rows (or ring-buffer rows, unrotated), SSM/RG-LRU
states — plus the slot position and enough config identity to refuse an
incompatible import.

Layout notes
------------
* Cache leaves are stacked by layer kind with shape (L, B, ...); a
  snapshot holds the (L, ...) slice at one batch index, so the per-layer
  structure survives verbatim and import is a single scatter back into
  any free slot of a same-shaped cache.
* Ring-buffer (windowed) caches need no special casing: slot occupancy is
  a pure function of ``pos`` (slot j holds position p with p % C == j),
  which travels with the snapshot — importing rows + pos reproduces the
  ring exactly.
* Rows are host numpy (the "wire format"): a snapshot can cross process
  boundaries; re-upload happens once, inside the importer's donated jit.

See ``docs/ARCHITECTURE.md`` § "Serving: continuous batching".
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

import numpy as np


@dataclass
class KVSnapshot:
    """One in-flight request's decode state, detached from its batch slot.

    ``pos`` is the number of tokens whose state the snapshot holds
    (prompt + generated prefix, minus the last sampled-but-unprocessed
    token) — also exactly the number of tokens a survivor does NOT have to
    re-prefill.
    """
    arch: str                                   # cfg.name of the producer
    max_len: int                                # producer cache max_len
    pos: int                                    # tokens with state
    rows: Dict[str, Dict[str, np.ndarray]] = field(default_factory=dict)
    # kind ("attn" | "ssm" | "rec") -> leaf -> (L, ...) one slot's rows

    @property
    def n_state_tokens(self) -> int:
        return self.pos

    def nbytes(self) -> int:
        return sum(a.nbytes for leaves in self.rows.values()
                   for a in leaves.values())

    def compatible_with(self, cache: Dict, arch: str, max_len: int) -> bool:
        """True iff this snapshot can be scattered into ``cache`` (same
        arch + max_len and every leaf's per-slot shape matches)."""
        if self.arch != arch or self.max_len != max_len:
            return False
        for kind, leaves in self.rows.items():
            if kind not in cache:
                return False
            for leaf, a in leaves.items():
                if leaf not in cache[kind]:
                    return False
                dst = cache[kind][leaf]
                if a.shape != dst.shape[:1] + dst.shape[2:]:
                    return False
        return True


def export_slot(cache: Dict, slot: int, *, arch: str,
                max_len: int) -> KVSnapshot:
    """Snapshot one batch slot of a slot-stacked cache to host memory.

    One device->host transfer per *kind leaf* (k, v, conv, state, h — a
    handful total, NOT one per layer: leaves are stacked across layers).
    This is the crash path; the latency-critical direction is import,
    which is a single in-jit scatter (see ContinuousBatcher).
    """
    rows: Dict[str, Dict[str, np.ndarray]] = {}
    for kind in ("attn", "ssm", "rec"):
        if kind in cache:
            rows[kind] = {leaf: np.asarray(arr[:, slot])
                          for leaf, arr in cache[kind].items()}
    return KVSnapshot(arch=arch, max_len=max_len,
                      pos=int(np.asarray(cache["pos"][slot])), rows=rows)


def export_slots(cache: Dict, slots, *, arch: str,
                 max_len: int) -> list:
    """Batched multi-slot export (whole-server drain path).

    One device->host transfer per kind leaf TOTAL — the full (L, B, ...)
    leaf crosses once and is sliced on host — instead of one transfer per
    (slot, leaf) as repeated ``export_slot`` calls would do.  Slices are
    copied so the snapshots don't pin the full-batch host buffers.
    Returns snapshots in the order of ``slots``.
    """
    slots = list(slots)
    if not slots:
        return []
    host: Dict[str, Dict[str, np.ndarray]] = {}
    for kind in ("attn", "ssm", "rec"):
        if kind in cache:
            host[kind] = {leaf: np.asarray(arr)
                          for leaf, arr in cache[kind].items()}
    pos = np.asarray(cache["pos"])
    return [KVSnapshot(arch=arch, max_len=max_len, pos=int(pos[s]),
                       rows={kind: {leaf: a[:, s].copy()
                                    for leaf, a in leaves.items()}
                             for kind, leaves in host.items()})
            for s in slots]
