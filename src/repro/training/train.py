"""Training substrate: loss, train_step factory (with remat + gradient
accumulation + optional gradient compression), TrainState.

``make_train_step`` returns a pure jit-able function; distribution is pure
sharding metadata (repro/distributed), never baked in here.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.context import constrain
from repro.models import transformer
from repro.training.optimizer import (AdamWConfig, OptState, adamw_update,
                                      init_opt_state)


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def init_train_state(cfg: ArchConfig, key, dtype=None) -> TrainState:
    params = transformer.init_params(cfg, key, dtype)
    return TrainState(params, init_opt_state(params))


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  ignore_id: int = -1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean CE over non-ignored tokens. logits (B,S,V) f32, labels (B,S)."""
    logits = constrain(logits, "logits")
    mask = (labels != ignore_id)
    labels_safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_safe[..., None],
                               axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1)
    return jnp.sum(nll) / denom, denom.astype(jnp.float32)


def make_loss_fn(cfg: ArchConfig, *, remat: bool = True, unroll: int = 1):
    def loss_fn(params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        logits, aux = transformer.forward(cfg, params, batch, mode="train",
                                          remat=remat, unroll=unroll)
        ce, n_tok = cross_entropy(logits, batch["labels"])
        loss = ce + cfg.router_aux_coef * aux
        return loss, {"ce": ce, "aux": aux, "tokens": n_tok}
    return loss_fn


def compress_grads(grads, method: str):
    """Gradient compression for the DP all-reduce (DESIGN.md §4: fewer bytes
    on the wire).  'bf16' casts before the (automatic) all-reduce — with
    error-feedback left to the caller if used iteratively."""
    if method == "none":
        return grads
    if method == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    raise ValueError(method)


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig, *,
                    remat: bool = True, accum: int = 1,
                    grad_compression: str = "none", unroll: int = 1
                    ) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    """Returns train_step(state, batch) -> (state, metrics).

    accum > 1 splits the batch into microbatches scanned sequentially
    (gradient accumulation), bounding activation memory.
    """
    loss_fn = make_loss_fn(cfg, remat=remat, unroll=unroll)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        if accum == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            def micro(carry, mb):
                (l, ms), g = grad_fn(state.params, mb)
                g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 carry[0], g)
                return (g, carry[1] + l), ms

            B = batch["tokens"].shape[0] if "tokens" in batch else \
                batch["embeds"].shape[0]
            assert B % accum == 0, (B, accum)
            mbs = jax.tree.map(
                lambda a: a.reshape(accum, B // accum, *a.shape[1:]), batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                state.params)
            (grads, loss_sum), ms = jax.lax.scan(micro, (zero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            metrics = jax.tree.map(lambda x: x[-1], ms)
            metrics["ce"] = loss  # accumulated mean
        grads = compress_grads(grads, grad_compression)
        new_params, new_opt, om = adamw_update(opt_cfg, state.params, grads,
                                               state.opt)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        return TrainState(new_params, new_opt), metrics

    return train_step


def make_eval_step(cfg: ArchConfig):
    loss_fn = make_loss_fn(cfg, remat=False)

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return {"loss": loss, **metrics}
    return eval_step
