"""Deterministic, resumable, shardable data pipeline.

Two sources:
  * ``SyntheticLM``  — seeded synthetic token stream with learnable structure
                       (a fixed random bigram table) so small models visibly
                       learn; used by benches/dry-runs/examples.
  * ``CorpusLM``     — byte-level corpus batcher for the quickstart demo.

Both expose ``state()`` / ``restore(state)`` so a restart from a checkpoint
resumes the exact stream position (fault-tolerance requirement), and
``shard(rank, world)`` for data parallelism.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    batch_size: int           # per-host batch
    seed: int = 0
    rank: int = 0
    world: int = 1
    step: int = 0
    structured: bool = True   # sample from a fixed bigram chain

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        if self.structured:
            # sparse-ish bigram transition: each token has 8 likely successors
            succ = rng.integers(0, self.vocab_size,
                                size=(self.vocab_size, 8))
            self._succ = succ
        else:
            self._succ = None

    def _rng_for(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed * 1_000_003 + step) * self.world + self.rank)

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = self._rng_for(self.step)
        B, S, V = self.batch_size, self.seq_len, self.vocab_size
        if self._succ is not None:
            toks = np.empty((B, S + 1), np.int32)
            toks[:, 0] = rng.integers(0, V, size=B)
            choices = rng.integers(0, 8, size=(B, S))
            for t in range(S):
                toks[:, t + 1] = self._succ[toks[:, t], choices[:, t]]
        else:
            toks = rng.integers(0, V, size=(B, S + 1)).astype(np.int32)
        self.step += 1
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def state(self) -> Dict:
        return {"step": self.step, "seed": self.seed, "rank": self.rank,
                "world": self.world}

    def restore(self, state: Dict):
        assert state["seed"] == self.seed
        self.step = state["step"]

    def shard(self, rank: int, world: int) -> "SyntheticLM":
        return dataclasses.replace(self, rank=rank, world=world)


@dataclass
class CorpusLM:
    """Byte-level batches over a text corpus (quickstart demo)."""
    text: str
    seq_len: int
    batch_size: int
    seed: int = 0
    step: int = 0

    def __post_init__(self):
        self._data = np.frombuffer(self.text.encode("utf-8"),
                                   dtype=np.uint8).astype(np.int32)

    @property
    def vocab_size(self) -> int:
        return 256

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed * 7_777_777 + self.step)
        n = len(self._data) - self.seq_len - 1
        starts = rng.integers(0, n, size=self.batch_size)
        toks = np.stack([self._data[s:s + self.seq_len + 1] for s in starts])
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def state(self) -> Dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: Dict):
        self.step = state["step"]
