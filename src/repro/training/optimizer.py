"""AdamW from scratch (no optax in this environment) + LR schedules.

Optimizer state is a pytree shaped like params, so it inherits whatever
sharding the parameters use (ZeRO-style sharding falls out of the
NamedSharding rules in repro/distributed/shardings.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray   # () int32
    m: Any              # pytree like params (f32)
    v: Any              # pytree like params (f32)


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree.map(jnp.copy, zeros))


def lr_at(cfg: AdamWConfig, step) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def _is_matrix(path: Tuple, leaf) -> bool:
    """Weight decay only on >=2D weights (not norms/biases) — GPT-3 recipe."""
    return leaf.ndim >= 2


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState
                 ) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"lr": lr, "grad_norm": gn}
