"""Fault-tolerant checkpointing: layer-addressable, mesh-agnostic,
atomic, async-capable.

Layout (one directory per step):
    <dir>/step_000123/
        MANIFEST.json            # tree structure, shapes, dtypes, data state
        arrays.npz               # flat {path -> ndarray}, or
        arrays_<k>.npz           # sharded into k volumes for big trees
    <dir>/LATEST                 # atomic pointer (rename) to the newest step

Mesh-agnostic: arrays are saved unsharded (host-gathered); on restore the
caller supplies target shardings and we ``jax.device_put`` accordingly —
so an elastic restart onto a *different* mesh Just Works (DESIGN.md §4).
Async mode writes on a background thread; ``wait()`` joins before the next
save (checkpoint/restart requirement for 1000+-node runs: the train loop
never blocks on I/O).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten_with_paths(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten_with_paths(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(paths: Dict[str, Any], spec) -> Any:
    def build(spec, prefix=""):
        if isinstance(spec, dict):
            return {k: build(v, f"{prefix}{k}/") for k, v in spec.items()}
        if isinstance(spec, (tuple, list)):
            vals = [build(v, f"{prefix}{i}/") for i, v in enumerate(spec)]
            return type(spec)(vals) if not hasattr(spec, "_fields") \
                else type(spec)(*vals)
        return paths[prefix[:-1]]
    return build(spec)


def _treespec(tree) -> Any:
    if isinstance(tree, dict):
        return {k: _treespec(v) for k, v in tree.items()}
    if isinstance(tree, (tuple, list)):
        if hasattr(tree, "_fields"):  # NamedTuple
            return type(tree)(*[_treespec(v) for v in tree])
        return type(tree)([_treespec(v) for v in tree])
    return None


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             async_: bool = False):
        """Snapshot ``tree`` (pytree of arrays) + JSON-serializable extras."""
        self.wait()

        def to_host(a):
            a = np.asarray(a)
            if a.dtype.name == "bfloat16":  # npz-unsupported: lossless upcast
                a = a.astype(np.float32)
            return a

        host = jax.tree.map(to_host, tree)

        def work():
            try:
                self._write(step, host, extra or {})
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if async_:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            self._raise_if_failed()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _write(self, step: int, host_tree, extra: Dict):
        flat = _flatten_with_paths(host_tree)
        name = f"step_{step:09d}"
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=f".{name}.")
        try:
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{k: v for k, v in flat.items()})
            manifest = {
                "step": step,
                "extra": extra,
                "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in flat.items()},
            }
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump(manifest, f)
            final = os.path.join(self.dir, name)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)                      # atomic publish
            self._point_latest(name)
            self._gc()
        finally:
            if os.path.isdir(tmp):
                shutil.rmtree(tmp, ignore_errors=True)

    def _point_latest(self, name: str):
        ptr = os.path.join(self.dir, "LATEST")
        tmp = ptr + ".tmp"
        with open(tmp, "w") as f:
            f.write(name)
        os.replace(tmp, ptr)                           # atomic

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        ptr = os.path.join(self.dir, "LATEST")
        if not os.path.exists(ptr):
            return None
        with open(ptr) as f:
            return int(f.read().strip().split("_")[1])

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, Dict]:
        """Restore into the structure of ``template``. If ``shardings`` is a
        matching pytree of jax.sharding.Sharding, arrays are placed sharded
        (elastic restart onto any mesh)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        name = f"step_{step:09d}"
        with open(os.path.join(self.dir, name, "MANIFEST.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(self.dir, name, "arrays.npz"))
        flat = {k: data[k] for k in data.files}
        tree = _unflatten(flat, _treespec(template))
        # dtype fidelity: cast back to the template leaf dtypes
        tree = jax.tree.map(
            lambda t, a: np.asarray(a).astype(t.dtype)
            if hasattr(t, "dtype") else a, template, tree)
        if shardings is not None:
            tree = jax.tree.map(lambda a, s: jax.device_put(a, s),
                                tree, shardings)
        else:
            tree = jax.tree.map(lambda a: jax.device_put(a), tree)
        return tree, manifest["extra"]
