"""recurrentgemma-2b — RG-LRU + local attn, 1:2 [arXiv:2402.19427; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("rec", "rec", "attn"),
    lru_width=2560,
    attn_window=2048,
    act="gelu",
    tie_embeddings=True,
    source="[arXiv:2402.19427; hf]",
)
