"""hubert-xlarge — encoder-only audio backbone [arXiv:2106.07447; unverified].

The conv waveform frontend is a STUB per the assignment: ``input_specs()``
feeds precomputed frame embeddings (B, S, D) directly to the transformer.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    act="gelu",
    norm_eps=1e-5,
    gated_mlp=False,
    source="[arXiv:2106.07447; unverified]",
)
