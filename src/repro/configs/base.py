"""Config system: architecture + input-shape configs and the cell matrix.

Every assigned architecture is an ``ArchConfig`` (frozen dataclass) registered
in ``ARCH_REGISTRY`` by its public id (``--arch <id>``).  Input shapes are
``ShapeConfig`` entries in ``SHAPES``.  ``cells()`` enumerates the assigned
(arch x shape) matrix minus the skips documented in DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
import importlib
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    """A single architecture from the assigned pool (exact public config)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- attention options -------------------------------------------------
    qk_norm: bool = False          # RMSNorm on q/k per-head (qwen3)
    qkv_bias: bool = False         # bias on qkv projections (qwen2.5 family)
    attn_window: int = 0           # 0 = full; >0 = sliding local window
    rope_theta: float = 1e6
    mrope: bool = False            # multimodal section-wise rotary (qwen2-vl)
    mrope_sections: Tuple[int, ...] = (16, 24, 24)  # t,h,w splits of head_dim/2
    causal: bool = True            # False => encoder-only (hubert)

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0              # per-expert hidden dim
    capacity_factor: float = 1.25
    serving_capacity_factor: float = 2.0
    router_aux_coef: float = 0.01

    # --- SSM (mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0             # N
    ssm_expand: int = 2
    ssm_head_dim: int = 64         # P
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- hybrid (recurrentgemma) --------------------------------------------
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    lru_width: int = 0

    # --- misc ----------------------------------------------------------------
    act: str = "silu"
    gated_mlp: bool = True
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    source: str = ""               # public provenance [source; tier]

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads:
            return self.d_model // self.n_heads
        return 0

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 (Megatron-style) so the vocab
        dim shards cleanly over any mesh axis we use (<=256-way)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can serve 500k+ contexts (SSM / windowed / hybrid)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.attn_window > 0

    @property
    def has_decode(self) -> bool:
        """Encoder-only archs have no autoregressive decode step."""
        return self.causal

    def layer_kinds(self) -> List[str]:
        """Per-layer block kind, resolving the hybrid pattern."""
        if self.family == "hybrid" and self.block_pattern:
            pat = self.block_pattern
            return [pat[i % len(pat)] for i in range(self.n_layers)]
        if self.family == "ssm":
            return ["ssm"] * self.n_layers
        if self.family == "moe":
            return ["moe"] * self.n_layers
        return ["attn"] * self.n_layers

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        D, V = self.d_model, self.padded_vocab
        hd = self.resolved_head_dim
        n = V * D  # embedding
        if not self.tie_embeddings:
            n += V * D  # lm head
        n += D  # final norm
        kinds = self.layer_kinds()
        for kind in kinds:
            n += 2 * D  # the two pre-norms (single for ssm, counted anyway)
            if kind == "attn":
                q = D * self.n_heads * hd + (self.n_heads * hd if self.qkv_bias else 0)
                kv = 2 * (D * self.n_kv_heads * hd + (self.n_kv_heads * hd if self.qkv_bias else 0))
                o = self.n_heads * hd * D
                n += q + kv + o
                if self.qk_norm:
                    n += 2 * hd
                n += (3 if self.gated_mlp else 2) * D * self.d_ff
            elif kind == "moe":
                q = D * self.n_heads * hd
                kv = 2 * D * self.n_kv_heads * hd
                o = self.n_heads * hd * D
                n += q + kv + o
                n += D * self.n_experts  # router
                n += self.n_experts * 3 * D * self.moe_d_ff
                n += self.n_shared_experts * 3 * D * self.moe_d_ff
            elif kind == "ssm":
                di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
                # in_proj: z, x, B, C, dt
                n += D * (2 * di + 2 * N + H)
                n += (di + 2 * N) * self.ssm_conv  # conv1d
                n += 2 * H + di  # A_log, dt_bias, D skip (di)
                n += di * D  # out_proj
            elif kind == "rec":
                w = self.lru_width or D
                n += 2 * D * w      # gate branch + x branch
                n += w * self.ssm_conv
                n += 2 * w * w // 1 if False else 0
                n += 2 * w          # input gate, recurrence gate (diagonal blocks approximated dense below)
                n += 2 * w * w // 16  # block-diagonal gates (16 blocks) approx
                n += w              # Lambda
                n += w * D          # out proj
                n += 3 * D * self.d_ff  # the mlp in a recurrent block
        return int(n)

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        D = self.d_model
        dense = self.param_count()
        all_exp = self.n_layers * self.n_experts * 3 * D * self.moe_d_ff
        act_exp = self.n_layers * self.top_k * 3 * D * self.moe_d_ff
        return int(dense - all_exp + act_exp)

    def reduced(self, **overrides) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw: Dict = dict(
            n_layers=min(self.n_layers, 2 * max(1, len(self.block_pattern) or 1)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=128,
            vocab_size=257,
            head_dim=16,
        )
        if self.family == "moe":
            kw.update(n_experts=4, top_k=min(self.top_k, 2), moe_d_ff=32,
                      n_shared_experts=min(self.n_shared_experts, 1),
                      capacity_factor=8.0)  # dropless at test scale
        if self.family == "ssm":
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
        if self.mrope:
            kw.update(mrope_sections=(2, 3, 3))  # sums to head_dim(16)//2
        if self.family == "hybrid":
            kw.update(lru_width=64, attn_window=min(self.attn_window or 0, 32) or 32)
        elif self.attn_window:
            kw.update(attn_window=32)
        kw.update(dtype="float32")
        kw.update(overrides)
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input-shape configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS: List[str] = [
    "mamba2-780m",
    "qwen3-1.7b",
    "deepseek-coder-33b",
    "granite-3-8b",
    "qwen2.5-14b",
    "hubert-xlarge",
    "qwen2-vl-72b",
    "qwen2-moe-a2.7b",
    "phi3.5-moe-42b-a6.6b",
    "recurrentgemma-2b",
    # the paper's own evaluation family (OPT-1.3B-like) used by benchmarks
    "pipeboost-opt-1.3b",
]

_MODULE_FOR: Dict[str, str] = {
    "mamba2-780m": "mamba2_780m",
    "qwen3-1.7b": "qwen3_1_7b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "granite-3-8b": "granite_3_8b",
    "qwen2.5-14b": "qwen2_5_14b",
    "hubert-xlarge": "hubert_xlarge",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "pipeboost-opt-1.3b": "pipeboost_opt_1_3b",
}


def get_arch(name: str) -> ArchConfig:
    if name not in _MODULE_FOR:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[name]}")
    return mod.CONFIG


def cell_is_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) for one (arch x shape) cell."""
    if shape.kind == "decode" and not arch.has_decode:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "pure full-attention arch cannot serve 524k context"
    return True, ""


def cells(include_skipped: bool = False):
    """Enumerate the assigned (arch x shape) matrix (DESIGN.md §5)."""
    out = []
    for aid in ARCH_IDS:
        if aid == "pipeboost-opt-1.3b":
            continue  # paper's own model: benchmarks only, not an assigned cell
        arch = get_arch(aid)
        for shape in SHAPES.values():
            ok, reason = cell_is_applicable(arch, shape)
            if ok or include_skipped:
                out.append((aid, shape.name, ok, reason))
    return out
