"""qwen2-vl-72b — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only; the vision patch frontend is a STUB (``input_specs()``
provides precomputed patch/text embeddings and 3-section M-RoPE position
ids (B, S, 3)).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    source="[arXiv:2409.12191; hf]",
)
