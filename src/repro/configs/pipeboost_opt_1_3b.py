"""OPT-1.3B-like config — the paper's own benchmark family [arXiv:2205.01068].

Used by the paper-table benchmarks (TTFT / recovery); not an assigned cell.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pipeboost-opt-1.3b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=50272,
    act="gelu",
    rope_theta=1e4,
    gated_mlp=False,
    tie_embeddings=True,
    source="[arXiv:2205.01068; hf]",
)
