"""``python -m repro.analysis`` — the pbcheck static-analysis CLI."""
import sys

from repro.analysis.cli import main

sys.exit(main())
