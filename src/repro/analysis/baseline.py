"""Checked-in baseline: accepted findings with written justifications.

The baseline lets ``pbcheck`` land with a clean bill even when a rule
has known, *deliberate* violations — but every entry must carry a
justification, and CI fails on any finding that is neither suppressed
inline nor baselined.  Workflow:

* a new finding appears  -> fix it, suppress it inline with a reason,
  or add it here with ``--write-baseline`` and then EDIT the generated
  ``justification`` (entries still reading ``TODO`` fail the run);
* a baselined finding disappears -> the run reports the stale entry so
  it can be pruned (stale entries warn, they don't fail).

Format (version 1)::

    {"version": 1, "entries": [
        {"fingerprint": "R2|src/...|Cls.fn|call:np.asarray",
         "rule": "R2", "justification": "the one designed transfer"}]}
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Sequence

from repro.analysis.findings import Finding

TODO = "TODO: justify or fix"


@dataclass
class Baseline:
    """Accepted-findings ledger keyed by fingerprint."""
    entries: Dict[str, dict] = field(default_factory=dict)

    def matches(self, finding: Finding) -> bool:
        """True when ``finding`` is an accepted (baselined) finding."""
        return finding.fingerprint in self.entries

    def unjustified(self) -> List[dict]:
        """Entries whose justification is missing or still the TODO."""
        return [e for e in self.entries.values()
                if not str(e.get("justification", "")).strip()
                or e.get("justification") == TODO]

    def stale(self, findings: Sequence[Finding]) -> List[str]:
        """Baselined fingerprints no finding matched this run."""
        seen = {f.fingerprint for f in findings}
        return sorted(fp for fp in self.entries if fp not in seen)


def load_baseline(path: str) -> Baseline:
    """Read a baseline file; a missing file is an empty baseline."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        return Baseline()
    if doc.get("version") != 1:
        raise SystemExit(
            f"{path}: unknown baseline version {doc.get('version')!r}")
    entries = {}
    for e in doc.get("entries", []):
        fp = e.get("fingerprint")
        if not fp:
            raise SystemExit(f"{path}: baseline entry without fingerprint")
        entries[fp] = e
    return Baseline(entries)


def write_baseline(path: str, findings: Sequence[Finding],
                   old: Baseline) -> None:
    """Serialize ``findings`` as the new baseline, carrying existing
    justifications over and stamping ``TODO`` on new entries (which
    must be edited before the baseline passes)."""
    entries = []
    seen = set()
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
        if f.fingerprint in seen:
            continue
        seen.add(f.fingerprint)
        prev = old.entries.get(f.fingerprint, {})
        entries.append({
            "fingerprint": f.fingerprint,
            "rule": f.rule,
            "justification": prev.get("justification", TODO),
        })
    with open(path, "w") as fh:
        json.dump({"version": 1, "entries": entries}, fh, indent=1)
        fh.write("\n")
