"""Schema validator for the ``BENCH_*.json`` trajectory files.

The benchmark suite (``benchmarks/run.py``) appends one keyed entry per
(commit, config) to the checked-in ``BENCH_*.json`` files; the schemas
here mirror the per-file field tables in ``docs/BENCHMARKS.md``.  CI
runs this in the fast lane so a bench refactor that silently renames or
drops a metric field fails the build instead of corrupting the
trajectory (plots and regression checks key on these names).

Rules per entry:

* ``ts`` (epoch seconds) is always required;
* ``commit`` + ``config`` are required on EVERY entry — they are the
  trajectory key ``append_keyed_entry`` replaces on (the one pre-PR-6
  unkeyed row was backfilled with ``commit: "unknown"``);
* required metric fields must be present with the right type (bools
  are not numbers);
* unknown extra fields are reported as warnings, not errors, so new
  metrics can land before the schema table catches up.

Usage::

    PYTHONPATH=src python -m repro.analysis.bench_schema          # repo root
    PYTHONPATH=src python -m repro.analysis.bench_schema BENCH_fleet.json
"""
from __future__ import annotations

import glob
import json
import os
import sys
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

NUM = "number"          # int or float (bool excluded)
INT = "int"
STR = "str"
DICT = "dict"
BOOL = "bool"

_TYPES = {
    NUM: lambda v: isinstance(v, (int, float)) and not isinstance(v, bool),
    INT: lambda v: isinstance(v, int) and not isinstance(v, bool),
    STR: lambda v: isinstance(v, str),
    DICT: lambda v: isinstance(v, dict),
    BOOL: lambda v: isinstance(v, bool),
}


@dataclass
class EntrySchema:
    """Field table for one entry shape (see docs/BENCHMARKS.md)."""
    required: Dict[str, str]
    optional: Dict[str, str] = field(default_factory=dict)


# BENCH_fleet.json holds two entry shapes: the dispatch-policy
# comparison (bench_fleet) and the full-day Azure replay rows
# (bench_azure_day), discriminated by config["bench"].
_FLEET_DISPATCH = EntrySchema(required={
    "n_requests": INT,
    "least_loaded_ttft_p99_s": NUM, "least_loaded_ttft_mean_s": NUM,
    "slo_aware_ttft_p99_s": NUM, "slo_aware_ttft_mean_s": NUM,
    "adapter_affine_ttft_p99_s": NUM, "adapter_affine_ttft_mean_s": NUM,
    "slo_p99_cut_vs_least_loaded": NUM,
})
_FLEET_AZURE_DAY = EntrySchema(
    required={
        "n_requests": INT, "n_completed": INT, "wall_s": NUM,
        "slo_attainment": NUM, "slo_n": INT, "gpu_seconds": NUM,
        "ttft_p50": NUM, "ttft_p90": NUM, "ttft_p95": NUM,
        "ttft_p99": NUM, "ttft_p99.9": NUM,
    },
    # tick_wall_s/event_speedup only exist where both engines were run
    optional={"tick_wall_s": NUM, "event_speedup": NUM})

SCHEMAS: Dict[str, EntrySchema] = {
    "BENCH_coldstart.json": EntrySchema(required={
        "overlapped_ttft_s": NUM, "load_then_serve_ttft_s": NUM,
        "speedup": NUM, "time_to_ready_wall_s": NUM,
        "time_to_fully_loaded_wall_s": NUM, "loaded_bytes": INT,
        "total_bytes": INT, "decode_compiles": INT,
        "tokens_identical": BOOL,
    }),
    "BENCH_decode_hotpath.json": EntrySchema(required={
        "fused_steps_per_s": NUM, "legacy_steps_per_s": NUM,
        "speedup": NUM, "tokens_per_s": NUM, "n_buckets": INT,
        "decode_compiles": INT, "prefill_compiles": INT,
    }),
    "BENCH_recovery.json": EntrySchema(
        required={
            "migrate_post_crash_ttft_s": NUM,
            "reprefill_post_crash_ttft_s": NUM, "speedup": NUM,
            "migrated_reqs": INT, "migrated_tokens": INT,
            "reprefill_tokens_baseline": INT,
        },
        # partial-crash + snapshot-transfer extensions (PR 4/PR 7)
        optional={
            "partial_reconstruct": DICT,
            "snapshot_payload_bytes": INT, "snapshot_rows_bytes": INT,
            "snapshot_xfer_nvlink_s": NUM, "snapshot_xfer_pcie_s": NUM,
        }),
    "BENCH_chaos.json": EntrySchema(required={
        "repartition_post_crash_ttft_s": NUM,
        "full_migration_post_crash_ttft_s": NUM, "speedup": NUM,
        "lost_layers": INT, "reprefill_tokens": INT,
        "relay": DICT, "sim_replay": DICT, "real_replay": DICT,
    }),
    "BENCH_multicast.json": EntrySchema(required={
        "n_spawn": INT,
        "mc_ttft_mean_s": NUM, "host_ttft_mean_s": NUM, "ttft_speedup": NUM,
        "mc_fill_makespan_s": NUM, "host_fill_makespan_s": NUM,
        "mc_host_bytes": NUM, "host_only_host_bytes": NUM,
        "host_read_ratio": NUM, "crash": DICT,
    }),
    "BENCH_prefix.json": EntrySchema(required={
        "prefill_tokens_nocache": INT, "prefill_tokens_cache": INT,
        "prefill_token_ratio": NUM, "tokens_identical": BOOL,
        "prefix_hits": INT, "prefix_hit_tokens": INT,
        "decode_compiles": INT, "prefill_compiles": INT,
        "cold_ttft_s": NUM, "resurrect_ttft_s": NUM,
        "resurrect_speedup": NUM, "bundle_bytes": INT,
        "modeled_pull_s": NUM, "fleet": DICT,
    }),
    "BENCH_fleet.json": _FLEET_DISPATCH,   # shape picked per entry below
}

_COMMON = {"ts": NUM, "commit": STR, "config": DICT}


def _schema_for(fname: str, entry: dict) -> EntrySchema:
    """Pick the entry schema (fleet discriminates on config.bench)."""
    if fname == "BENCH_fleet.json" \
            and entry.get("config", {}).get("bench") == "azure_day":
        return _FLEET_AZURE_DAY
    return SCHEMAS[fname]


def validate_file(path: str) -> Tuple[List[str], List[str]]:
    """Validate one BENCH file -> (errors, warnings)."""
    fname = os.path.basename(path)
    errors: List[str] = []
    warnings: List[str] = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{fname}: unreadable ({e})"], []
    if not isinstance(doc, dict) or not isinstance(doc.get("entries"), list):
        return [f"{fname}: top level must be {{\"entries\": [...]}}"], []
    for i, entry in enumerate(doc["entries"]):
        where = f"{fname}[{i}]"
        if not isinstance(entry, dict):
            errors.append(f"{where}: entry is not an object")
            continue
        if "ts" not in entry or not _TYPES[NUM](entry["ts"]):
            errors.append(f"{where}: missing/invalid `ts` (epoch seconds)")
        for k in ("commit", "config"):
            if k not in entry or not _TYPES[_COMMON[k]](entry[k]):
                errors.append(
                    f"{where}: `{k}` missing or mistyped (every entry "
                    f"must carry the (commit, config) trajectory key)")
        schema = _schema_for(fname, entry)
        for k, t in schema.required.items():
            if k not in entry:
                errors.append(f"{where}: missing required `{k}` ({t})")
            elif not _TYPES[t](entry[k]):
                errors.append(
                    f"{where}: `{k}` should be {t}, "
                    f"got {type(entry[k]).__name__}")
        # optional fields may be null (e.g. tick_wall_s when only the
        # event engine ran) — only a present, non-null wrong type errors
        for k, t in schema.optional.items():
            if k in entry and entry[k] is not None \
                    and not _TYPES[t](entry[k]):
                errors.append(
                    f"{where}: `{k}` should be {t}, "
                    f"got {type(entry[k]).__name__}")
        known = set(_COMMON) | set(schema.required) | set(schema.optional)
        for k in sorted(set(entry) - known):
            warnings.append(
                f"{where}: unknown field `{k}` (add it to the schema "
                f"table in docs/BENCHMARKS.md + bench_schema.py)")
    return errors, warnings


def main(argv=None) -> int:
    """Validate the given files (default: every known BENCH_*.json in
    the current directory); exit 1 on any schema error."""
    argv = list(sys.argv[1:] if argv is None else argv)
    paths = argv or sorted(
        p for p in glob.glob("BENCH_*.json")
        if os.path.basename(p) in SCHEMAS)
    if not paths:
        print("bench_schema: no BENCH_*.json files found")
        return 1
    n_err = 0
    for p in paths:
        if os.path.basename(p) not in SCHEMAS:
            print(f"bench_schema: {p}: no schema for this file name")
            n_err += 1
            continue
        errors, warnings = validate_file(p)
        for w in warnings:
            print(f"WARN {w}")
        for e in errors:
            print(f"ERROR {e}")
        n_err += len(errors)
        if not errors:
            print(f"bench_schema: {p}: OK")
    if n_err:
        print(f"bench_schema: FAIL ({n_err} errors)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
