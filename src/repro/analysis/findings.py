"""Finding record + stable fingerprints (the baseline currency).

A fingerprint intentionally omits line numbers: baselined findings must
survive unrelated edits above them, so identity is
``rule | path | enclosing symbol | rule-specific detail`` — the same
scheme ``ruff``/``pylint`` baselines use.  Two findings with the same
fingerprint are the same *kind* of violation at the same place; a
baseline entry matches all of them.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""
    rule: str        # "R1".."R6"
    path: str        # repo-relative posix path
    line: int        # 1-based
    col: int         # 0-based
    symbol: str      # enclosing qualname ("" at module level)
    detail: str      # stable, line-free identity token (e.g. "attr:rounds")
    message: str     # human-readable explanation

    @property
    def fingerprint(self) -> str:
        """Line-number-free identity used for baseline matching."""
        return f"{self.rule}|{self.path}|{self.symbol}|{self.detail}"

    def render(self) -> str:
        """One-line ``path:line:col: RULE [symbol] message`` report row."""
        sym = f" [{self.symbol}]" if self.symbol else ""
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule}{sym} {self.message}")
