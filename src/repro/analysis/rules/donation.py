"""R1 — donation safety.

``jax.jit(..., donate_argnums=...)`` invalidates the donated operand:
after the call its buffer may already be aliased by the output, so any
later read sees garbage (or raises under JAX's deleted-buffer check —
but only at runtime, and only on backends that enforce donation).
PipeBoost's whole decode hot path rides donated caches, so this is the
invariant most likely to be silently broken by a refactor.

The rule: at every call site of a binding the module assigned from a
donated ``jax.jit``, take the argument expressions at the donated
positions; if such an argument is a plain name or ``self.attr``, any
*lexically later* read of it inside the same function — before a
rebinding (assignment) of that same name — is flagged.  The idiomatic
pattern ``out, self.cache = self._fused(..., self.cache)`` is clean:
the donated binding is re-assigned by the very statement that donates
it.  The analysis is straight-line by design (branch-aware dataflow
isn't worth the false-negative risk it trades for); loops that donate
and re-bind per iteration are handled because the rebinding statement
sits at the call's own line.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.context import Module, binding_str
from repro.analysis.findings import Finding

MUTATORS = ()   # R1 cares about reads; writes rebind and clear taint


def _store_lines(fn: ast.AST, key: str) -> List[int]:
    """Lines where ``key`` is (re)bound inside ``fn``."""
    out = []
    for node in ast.walk(fn):
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign,
                               ast.NamedExpr)):
            targets = [node.target]
        elif isinstance(node, ast.For):
            targets = [node.target]
        elif isinstance(node, ast.withitem) and node.optional_vars:
            targets = [node.optional_vars]
        for t in targets:
            for part in ast.walk(t):
                if binding_str(part) == key:
                    out.append(part.lineno)
    return out


def _load_lines(fn: ast.AST, key: str) -> List[int]:
    """Lines where ``key`` is read inside ``fn``."""
    out = []
    for node in ast.walk(fn):
        if binding_str(node) == key \
                and isinstance(getattr(node, "ctx", None), ast.Load):
            out.append(node.lineno)
    return out


def check(module: Module, config) -> List[Finding]:
    """Flag reads of donated arguments after the donating call."""
    findings: List[Finding] = []
    donated = {k: v for k, v in module.jits.items() if v}
    if not donated:
        return findings
    fns = [n for n in ast.walk(module.tree)
           if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for fn in fns:
        calls = []
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                key = binding_str(node.func)
                if key in donated:
                    calls.append((node, donated[key], key))
        for call, argnums, fname in calls:
            for p in argnums:
                if p >= len(call.args):
                    continue
                key = binding_str(call.args[p])
                if key is None:
                    continue
                stores = [ln for ln in _store_lines(fn, key)
                          if ln >= call.lineno]
                horizon = min(stores) if stores else 10 ** 9
                # loads inside the (possibly multi-line) call itself are
                # the donation, not a use-after-donate
                call_end = getattr(call, "end_lineno", call.lineno)
                for ln in _load_lines(fn, key):
                    if call_end < ln <= horizon \
                            and ln not in stores:
                        findings.append(Finding(
                            "R1", module.path, ln, 0, module.qualname(call),
                            f"use-after-donate:{key}",
                            f"`{key}` was donated to `{fname}` on line "
                            f"{call.lineno} and read again here without "
                            f"rebinding — the buffer may already be "
                            f"aliased/deleted"))
                        break       # one finding per donated arg per call
    return findings
