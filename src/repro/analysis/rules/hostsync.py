"""R2 — host syncs inside the decode/prefill hot-path modules.

The steady-state contract (PR 2) is ONE device->host transfer per
decode step — the sampled ``(B,)`` tokens — and one per admission.
Anything else that forces a sync (``.item()``, ``float()``/``int()``
on a device array, ``np.asarray`` of a jit result,
``block_until_ready``, ``jax.device_get``, ``.tolist()``) stalls the
dispatch pipeline and shows up as a throughput cliff that no test
catches at CPU scale.

Scope: only modules matching ``config.hot_paths`` (the serving engine,
``models/``, ``kernels/``).  To keep the rule quiet on legitimate host
work (numpy batch assembly at admission), ``np.asarray``/``np.array``/
``float``/``int``/``.tolist()`` are flagged only when their operand is
*device-origin*: a name most recently assigned (lexically) from a call
to a private ``self._*`` callable or a ``jnp.*``/jit-registry call in
the same function.  ``.item()``, ``.block_until_ready()`` and
``jax.device_get`` are flagged unconditionally — there is no host-side
reading of those.  The two designed transfer points in the serving
engine carry inline suppressions naming themselves as such, which
doubles as documentation of where the hot path touches the host.
"""
from __future__ import annotations

import ast
from typing import Dict, List

from repro.analysis.context import Module, binding_str
from repro.analysis.findings import Finding

_DEVICE_ORIGIN_MODULES = ("jnp", "jax", "lax")


def _is_device_call(node: ast.AST, module: Module) -> bool:
    """Heuristic: does this expression produce a device array?"""
    if isinstance(node, ast.Call):
        f = node.func
        key = binding_str(f)
        if key in module.jits:
            return True
        if isinstance(f, ast.Attribute):
            base = f.value
            # self._fused(...) / self._sampler(...): private jit wrappers
            if isinstance(base, ast.Name) and base.id == "self" \
                    and f.attr.startswith("_"):
                return True
            # jnp.foo(...), jax.foo(...), and chains like X(...).astype()
            if isinstance(base, ast.Name) \
                    and base.id in _DEVICE_ORIGIN_MODULES:
                return True
            if isinstance(base, ast.Call):
                return _is_device_call(base, module)
    return False


def _device_names_at(fn: ast.AST, module: Module) -> Dict[str, List[int]]:
    """name -> sorted lines where it is assigned a device-origin value."""
    dev: Dict[str, List[int]] = {}
    host: Dict[str, List[int]] = {}
    for node in ast.walk(fn):
        if not isinstance(node, ast.Assign):
            continue
        is_dev = _is_device_call(node.value, module)
        for t in node.targets:
            names = [t] if isinstance(t, ast.Name) else [
                e for e in ast.walk(t)
                if isinstance(e, ast.Name) and e.id != "self"]
            for n in names:
                (dev if is_dev else host).setdefault(
                    n.id, []).append(n.lineno)
    return {"dev": dev, "host": host}   # type: ignore[return-value]


def _origin_is_device(name: str, line: int, table) -> bool:
    """Was ``name``'s most recent (lexical) assignment device-origin?"""
    last_dev = max([ln for ln in table["dev"].get(name, []) if ln <= line],
                   default=None)
    if last_dev is None:
        return False
    last_host = max([ln for ln in table["host"].get(name, [])
                     if ln <= line], default=-1)
    return last_dev > last_host


def _base_name(node: ast.AST):
    """Peel subscripts/attributes down to the underlying Name."""
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def check(module: Module, config) -> List[Finding]:
    """Flag device->host synchronization points in hot-path modules."""
    if not module.matches(config.hot_paths):
        return []
    findings: List[Finding] = []

    def flag(node, detail, msg):
        findings.append(Finding("R2", module.path, node.lineno,
                                node.col_offset, module.qualname(node),
                                detail, msg))

    fns = [n for n in ast.walk(module.tree)
           if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    covered = set()
    for fn in fns:
        table = _device_names_at(fn, module)
        for node in ast.walk(fn):
            covered.add(id(node))
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            # unconditional syncs
            if isinstance(f, ast.Attribute) and f.attr == "item" \
                    and not node.args:
                flag(node, "call:item", "`.item()` forces a device->host "
                     "sync of a scalar — batch it with the step's one "
                     "designed transfer")
            elif isinstance(f, ast.Attribute) \
                    and f.attr == "block_until_ready":
                flag(node, "call:block_until_ready",
                     "`.block_until_ready()` stalls dispatch — only "
                     "benchmarks may sync the stream")
            elif isinstance(f, ast.Attribute) and f.attr == "device_get" \
                    and isinstance(f.value, ast.Name) \
                    and f.value.id == "jax":
                flag(node, "call:device_get", "`jax.device_get` is a "
                     "full host transfer — not in the hot path")
            # origin-gated syncs: np.asarray/np.array/float/int/.tolist
            # applied to a device-origin value
            elif _sync_wrapper(f) and node.args:
                arg = node.args[0]
                name = _base_name(arg)
                if _is_device_call(arg, module) or (
                        name is not None
                        and _origin_is_device(name, node.lineno, table)):
                    what = _sync_wrapper(f)
                    flag(node, f"call:{what}",
                         f"`{what}(...)` of a jit-produced value is a "
                         "device->host sync — keep it on device or fold "
                         "it into the one designed transfer per step")
            elif isinstance(f, ast.Attribute) and f.attr == "tolist":
                name = _base_name(f.value)
                if _is_device_call(f.value, module) or (
                        name is not None
                        and _origin_is_device(name, node.lineno, table)):
                    flag(node, "call:tolist", "`.tolist()` of a "
                         "jit-produced value syncs and boxes every "
                         "element — transfer once with np.asarray "
                         "outside the hot loop")
    return findings


def _sync_wrapper(f: ast.AST):
    """Name of a host-materializing wrapper call, or None."""
    if isinstance(f, ast.Attribute) and f.attr in ("asarray", "array") \
            and isinstance(f.value, ast.Name) and f.value.id == "np":
        return f"np.{f.attr}"
    if isinstance(f, ast.Name) and f.id in ("float", "int"):
        return f.id
    return None
