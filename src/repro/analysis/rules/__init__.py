"""Rule registry: maps rule ids to their check entry points.

Per-module rules expose ``check(module, config) -> List[Finding]``;
project rules (R5, which reasons across files) expose
``check_project(modules, config)``.  The walker dispatches on which
attribute a rule module defines.
"""
from repro.analysis.rules import (chaos, docstrings, donation, hostsync,
                                  locks, retrace)

RULES = {
    "R1": donation,
    "R2": hostsync,
    "R3": locks,
    "R4": retrace,
    "R5": chaos,
    "R6": docstrings,
}

DESCRIPTIONS = {
    "R1": "donation safety: donated buffers are dead after the call",
    "R2": "host-sync-in-hot-path: no device->host syncs in hot modules",
    "R3": "lock discipline: fill-thread-shared state under _load_lock",
    "R4": "retrace hazards at jitted call sites",
    "R5": "chaos kind / recovery mode exhaustiveness",
    "R6": "docstring coverage in the documented layers",
}
