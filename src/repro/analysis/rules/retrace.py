"""R4 — retrace hazards at jitted call sites.

The zero-retrace contract (decode compiles exactly once for an
engine's lifetime; prefill once per length bucket) is enforced at
runtime by ``benchmarks/compile_guard.py`` — but only on the paths the
guard exercises.  This rule catches the textual patterns that create
fresh traces wholesale:

* **immediately-invoked jit** — ``jax.jit(f)(x)`` builds a brand-new
  jit wrapper (and compile cache) per call; nothing is ever reused;
* **jit constructed inside a loop** — same failure, amortized over
  iterations (caching ``jax.jit`` results in a dict keyed by the trace
  signature, like ``PipeBoostEngine._pipe_fns``, is the sanctioned
  pattern and is not flagged because the call sits under an ``if key
  not in cache`` guard, not a loop);
* **f-string / lambda arguments to a jitted callable** — strings must
  be static (a fresh string per call = a fresh trace per call), and a
  fresh lambda is unhashable-by-identity, so either it errors or it
  retraces every time.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.context import Module, binding_str, is_call_to
from repro.analysis.findings import Finding


def _loop_bodies(tree: ast.AST):
    for node in ast.walk(tree):
        if isinstance(node, (ast.For, ast.While, ast.AsyncFor)):
            yield node


def check(module: Module, config) -> List[Finding]:
    """Flag call patterns that defeat jit compile-cache reuse."""
    findings: List[Finding] = []

    for node in ast.walk(module.tree):
        # jax.jit(f)(args): a throwaway wrapper, retraces every call
        if isinstance(node, ast.Call) and is_call_to(node.func, "jax",
                                                     "jit"):
            findings.append(Finding(
                "R4", module.path, node.lineno, node.col_offset,
                module.qualname(node), "iife-jit",
                "immediately-invoked jax.jit: the wrapper (and its "
                "compile cache) is discarded after this call — bind the "
                "jit once and reuse it"))

    # jax.jit(...) constructed inside a loop body
    for loop in _loop_bodies(module.tree):
        for stmt in loop.body + getattr(loop, "orelse", []):
            for node in ast.walk(stmt):
                if is_call_to(node, "jax", "jit"):
                    findings.append(Finding(
                        "R4", module.path, node.lineno, node.col_offset,
                        module.qualname(node), "jit-in-loop",
                        "jax.jit constructed inside a loop: every "
                        "iteration pays a fresh trace+compile — hoist "
                        "it (or cache by signature like _pipe_fns)"))

    # f-string / lambda arguments at known-jitted call sites
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call):
            continue
        fname = binding_str(node.func)
        if fname not in module.jits:
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.JoinedStr):
                findings.append(Finding(
                    "R4", module.path, arg.lineno, arg.col_offset,
                    module.qualname(node), f"fstring-arg:{fname}",
                    f"f-string passed to jitted `{fname}`: strings are "
                    "static in a trace, so each distinct value compiles "
                    "a fresh executable"))
            elif isinstance(arg, ast.Lambda):
                findings.append(Finding(
                    "R4", module.path, arg.lineno, arg.col_offset,
                    module.qualname(node), f"lambda-arg:{fname}",
                    f"fresh lambda passed to jitted `{fname}`: a new "
                    "function object per call can never hit the compile "
                    "cache — hoist it to a module-level def"))
    return findings
