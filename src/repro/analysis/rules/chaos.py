"""R5 — chaos-kind / recovery-mode exhaustiveness.

The seeded chaos harness (PR 7) promises that a fault script replays
identically under the tick and event engines.  That only holds if
every ``ChaosEvent`` kind the schedule can carry is actually handled
by the engines' shared dispatch — and vice versa: a handler branch for
a kind the schema doesn't define is dead code hiding a typo.  Same
shape for recovery modes: ``ClusterMetrics.on_recovery`` asserts its
mode vocabulary at runtime, but a misspelled literal at a call site
only explodes when that recovery path actually fires (i.e. during an
outage — the worst possible time).

This is a *project* rule: it reasons across every scanned module.

* **kinds**: the ``CHAOS_KINDS`` tuple is the schema; a module is a
  handler when it compares literals against an ``.kind`` attribute
  *and* at least one of those literals is a defined chaos kind (other
  layers use ``.kind`` for unrelated vocabularies — layer kinds like
  ``"prefill"``/``"decode"`` — and are out of scope).  Each handler
  must compare every defined kind (else: unhandled), and must not
  compare undefined literals (else: dead branch / typo).
* **modes**: the ``assert mode in (...)`` inside ``def on_recovery``
  is the schema; every ``*.on_recovery("<literal>", ...)`` call site
  must use a member of it.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.context import Module
from repro.analysis.findings import Finding


def _literal_strs(node: ast.AST) -> Optional[Set[str]]:
    """Extract the string set of a Constant / Tuple-of-Constant node."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        out = set()
        for e in node.elts:
            if not (isinstance(e, ast.Constant)
                    and isinstance(e.value, str)):
                return None
            out.add(e.value)
        return out
    return None


def _find_kind_schema(modules) -> Optional[Tuple[str, Set[str]]]:
    """Locate ``CHAOS_KINDS = (...)`` -> (path, defined kinds)."""
    for m in modules:
        for node in ast.walk(m.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and node.targets[0].id == "CHAOS_KINDS":
                lits = _literal_strs(node.value)
                if lits:
                    return m.path, lits
    return None


def _kind_comparisons(module: Module) -> List[Tuple[ast.Compare, Set[str]]]:
    """Comparisons of an ``X.kind`` attribute against string literals."""
    out = []
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Compare) or len(node.comparators) != 1:
            continue
        sides = [node.left, node.comparators[0]]
        attr = next((s for s in sides if isinstance(s, ast.Attribute)
                     and s.attr == "kind"), None)
        lit = next((ls for s in sides
                    if (ls := _literal_strs(s)) is not None), None)
        if attr is not None and lit is not None:
            out.append((node, lit))
    return out


def _find_mode_schema(modules) -> Optional[Tuple[str, Set[str]]]:
    """``assert mode in (...)`` inside ``def on_recovery`` is the mode
    vocabulary."""
    for m in modules:
        for node in ast.walk(m.tree):
            if not (isinstance(node, ast.FunctionDef)
                    and node.name == "on_recovery"):
                continue
            for sub in ast.walk(node):
                if isinstance(sub, ast.Assert) \
                        and isinstance(sub.test, ast.Compare) \
                        and isinstance(sub.test.ops[0], ast.In):
                    lits = _literal_strs(sub.test.comparators[0])
                    if lits:
                        return m.path, lits
    return None


def check_project(modules, config) -> List[Finding]:
    """Cross-module exhaustiveness findings (see module docstring)."""
    findings: List[Finding] = []

    kinds = _find_kind_schema(modules)
    if kinds is not None:
        schema_path, defined = kinds
        for m in modules:
            comps = _kind_comparisons(m)
            # Handler modules are those whose `.kind` literals overlap
            # the chaos vocabulary; `.kind` is also a layer-kind field
            # elsewhere ("prefill"/"decode"/...), which R5 must ignore.
            if not comps or not (
                    set().union(*(lits for _, lits in comps)) & defined):
                continue
            handled: Set[str] = set()
            first = comps[0][0]
            for node, lits in comps:
                handled |= lits
                unknown = lits - defined
                for u in sorted(unknown):
                    findings.append(Finding(
                        "R5", m.path, node.lineno, node.col_offset,
                        m.qualname(node), f"unknown-kind:{u}",
                        f"`.kind` compared against {u!r}, which is not "
                        f"in CHAOS_KINDS ({schema_path}) — dead branch "
                        f"or typo"))
            for missing in sorted(defined - handled):
                findings.append(Finding(
                    "R5", m.path, first.lineno, first.col_offset,
                    m.qualname(first), f"unhandled-kind:{missing}",
                    f"this module dispatches on `.kind` but never "
                    f"handles {missing!r} (defined in CHAOS_KINDS, "
                    f"{schema_path}) — tick/event replay would "
                    f"silently diverge on it"))

    modes = _find_mode_schema(modules)
    if modes is not None:
        schema_path, allowed = modes
        for m in modules:
            for node in ast.walk(m.tree):
                if not (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr == "on_recovery"
                        and node.args):
                    continue
                a0 = node.args[0]
                if isinstance(a0, ast.Constant) \
                        and isinstance(a0.value, str) \
                        and a0.value not in allowed:
                    findings.append(Finding(
                        "R5", m.path, node.lineno, node.col_offset,
                        m.qualname(node), f"unknown-mode:{a0.value}",
                        f"on_recovery mode {a0.value!r} is not in the "
                        f"vocabulary asserted by on_recovery "
                        f"({schema_path}) — it would raise only when "
                        f"this recovery path fires"))
    return findings
