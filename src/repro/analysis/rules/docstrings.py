"""R6 — docstring coverage for the documented layers.

Successor of the retired ``benchmarks/docstring_gate.py`` (the PR 6
stdlib ``interrogate`` stand-in), folded into the single ``pbcheck``
lane: within the scoped paths
(``config.docstring_paths`` — by default the cluster layer the gate
already covered, plus this analysis package), every public module,
class, and function/method must carry a docstring, reported per item
instead of as a coverage percentage so each miss is fixable,
suppressible, or baselinable like any other finding.

Exclusions mirror interrogate's defaults (and the old gate's): dunders
(``__init__`` is documented by its class), ``@property`` accessors
(the name is the doc), functions nested inside functions, and anything
under a private scope.
"""
from __future__ import annotations

import ast
from typing import List, Tuple

from repro.analysis.context import Module
from repro.analysis.findings import Finding


def _is_public(name: str) -> bool:
    return not name.startswith("_")


def _is_property(node: ast.AST) -> bool:
    for dec in getattr(node, "decorator_list", []):
        if isinstance(dec, ast.Name) and dec.id == "property":
            return True
        if isinstance(dec, ast.Attribute) and dec.attr in ("getter",
                                                           "setter",
                                                           "deleter"):
            return True
    return False


def iter_defs(tree: ast.Module):
    """Yield ``(node, qualname, kind, has_docstring)`` per checkable
    definition — the module itself, public classes, and public
    functions/methods (same walk as the legacy docstring gate)."""
    yield tree, "<module>", "module", ast.get_docstring(tree) is not None
    stack: List[Tuple[ast.AST, str]] = [(tree, "")]
    while stack:
        node, prefix = stack.pop()
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                qual = f"{prefix}{child.name}"
                if _is_public(child.name) and not _is_property(child):
                    kind = ("class" if isinstance(child, ast.ClassDef)
                            else "function")
                    yield (child, qual, kind,
                           ast.get_docstring(child) is not None)
                if isinstance(child, ast.ClassDef) \
                        and _is_public(child.name):
                    stack.append((child, f"{qual}."))


def check(module: Module, config) -> List[Finding]:
    """Flag each missing public docstring inside the scoped paths."""
    if not module.matches(config.docstring_paths):
        return []
    findings: List[Finding] = []
    for node, qual, kind, has_doc in iter_defs(module.tree):
        if has_doc:
            continue
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        findings.append(Finding(
            "R6", module.path, line, col, qual,
            f"missing-doc:{kind}:{qual}",
            f"public {kind} `{qual}` has no docstring (the documented "
            f"layers keep 100% public-API coverage)"))
    return findings
