"""R3 — lock discipline for fill-thread-shared engine state.

``PipeBoostEngine.start_fill`` runs ``load_round`` on a daemon thread
concurrently with serving calls on the main thread (the PR 4 overlap —
the paper's core latency win).  Every attribute that thread touches is
therefore shared mutable state, and the engine's contract is that ALL
access to it goes through ``with self._load_lock`` — PR 7's
crash-races-fill accounting bug was exactly a violation of this found
late, at runtime, by a bench.

The model, recovered statically per class:

1. **Locks**: ``self.X = threading.Lock()/RLock()`` attributes.
2. **Thread entry points**: functions passed as ``target=`` to
   ``threading.Thread`` (including closures), plus the transitive
   closure of ``self.method`` calls/reads they make within the class
   (property reads traverse too — ``self.ready`` runs code).
3. **Shared set G**: plain data attributes the thread closure touches,
   minus the locks themselves and ``threading`` primitives (Events and
   Threads are internally synchronized), minus attributes never
   written outside ``__init__`` (immutable config can be read racily).
4. **Violation**: any read or write of an attribute in G, anywhere in
   the class outside ``__init__``, that is not lexically inside a
   ``with self.<lock>`` block.

Writes include mutating calls (``self.rounds.append(...)``) and
subscript/augmented assignment, not just rebinding.  Classes with no
lock or no thread entry points are skipped entirely, so the rule stays
silent on the (single-threaded) serving and cluster layers.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.context import Module
from repro.analysis.findings import Finding

_MUTATORS = ("append", "add", "extend", "update", "pop", "remove",
             "discard", "clear", "insert", "setdefault", "popitem")
_THREADING_SAFE = ("Event", "Thread", "Condition", "Semaphore",
                   "BoundedSemaphore", "Barrier")
_LOCK_TYPES = ("Lock", "RLock")


def _threading_ctor(node: ast.AST, names: tuple) -> bool:
    """Is ``node`` a call of ``threading.X()`` / bare ``X()``, X in names."""
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
            and f.value.id == "threading" and f.attr in names:
        return True
    return isinstance(f, ast.Name) and f.id in names


def _self_attr(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return node.attr
    return None


class _ClassModel:
    """Thread/lock model of one class (see module docstring)."""

    def __init__(self, cls: ast.ClassDef):
        self.cls = cls
        self.methods: Dict[str, ast.FunctionDef] = {
            n.name: n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        self.locks: Set[str] = set()
        self.safe_attrs: Set[str] = set()
        for node in ast.walk(cls):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                attr = _self_attr(node.targets[0])
                if attr is None:
                    continue
                if _threading_ctor(node.value, _LOCK_TYPES):
                    self.locks.add(attr)
                elif _threading_ctor(node.value, _THREADING_SAFE):
                    self.safe_attrs.add(attr)
        self.entries = self._thread_entries()
        self.shared = self._shared_attrs() if self.entries else set()

    # -- step 2: thread entry closure -----------------------------------
    def _thread_entries(self) -> List[ast.FunctionDef]:
        roots: List[ast.FunctionDef] = []
        for node in ast.walk(self.cls):
            if not (isinstance(node, ast.Call)
                    and _threading_ctor(node, ("Thread",))):
                continue
            for kw in node.keywords:
                if kw.arg != "target":
                    continue
                attr = _self_attr(kw.value)
                if attr is not None and attr in self.methods:
                    roots.append(self.methods[attr])
                elif isinstance(kw.value, ast.Name):
                    # a closure defined in some enclosing method
                    for fn in ast.walk(self.cls):
                        if isinstance(fn, ast.FunctionDef) \
                                and fn.name == kw.value.id:
                            roots.append(fn)
        # transitive closure over self.<method> references
        seen = {id(r) for r in roots}
        work = list(roots)
        while work:
            fn = work.pop()
            for node in ast.walk(fn):
                attr = _self_attr(node)
                if attr in self.methods \
                        and id(self.methods[attr]) not in seen:
                    seen.add(id(self.methods[attr]))
                    roots.append(self.methods[attr])
                    work.append(self.methods[attr])
        return roots

    # -- step 3: the shared attribute set G -----------------------------
    def _shared_attrs(self) -> Set[str]:
        touched: Set[str] = set()
        for fn in self.entries:
            for node in ast.walk(fn):
                attr = _self_attr(node)
                if attr is None or attr in self.methods \
                        or attr in self.locks or attr in self.safe_attrs:
                    continue
                touched.add(attr)
        # attrs never written outside __init__ are effectively frozen
        written: Set[str] = set()
        for name, fn in self.methods.items():
            if name == "__init__":
                continue
            written |= self._writes_in(fn)
        return touched & written

    def _writes_in(self, fn: ast.AST) -> Set[str]:
        out: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    out |= self._write_targets(t)
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                out |= self._write_targets(node.target)
            elif isinstance(node, ast.Call):
                f = node.func
                if isinstance(f, ast.Attribute) and f.attr in _MUTATORS:
                    attr = _self_attr(f.value)
                    if attr is not None:
                        out.add(attr)
        return out

    def _write_targets(self, t: ast.AST) -> Set[str]:
        out: Set[str] = set()
        attr = _self_attr(t)
        if attr is not None:
            out.add(attr)
        if isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                out |= self._write_targets(e)
        if isinstance(t, ast.Subscript):
            attr = _self_attr(t.value)
            if attr is not None:
                out.add(attr)
        return out


def _is_lock_with(item: ast.withitem, locks: Set[str]) -> bool:
    attr = _self_attr(item.context_expr)
    if attr in locks:
        return True
    # with self._load_lock.acquire_timeout(...) style wrappers
    ce = item.context_expr
    if isinstance(ce, ast.Call) and isinstance(ce.func, ast.Attribute):
        return _self_attr(ce.func.value) in locks
    return False


def _check_function(model: _ClassModel, fn: ast.FunctionDef,
                    module: Module, findings: List[Finding]) -> None:
    """Flag unguarded accesses to shared attrs inside one method."""

    def visit(node: ast.AST, locked: bool) -> None:
        if isinstance(node, ast.With):
            inner = locked or any(_is_lock_with(i, model.locks)
                                  for i in node.items)
            for item in node.items:
                visit(item, locked)
            for child in node.body:
                visit(child, inner)
            return
        attr = _self_attr(node)
        if attr is not None and attr in model.shared and not locked:
            findings.append(Finding(
                "R3", module.path, node.lineno, node.col_offset,
                module.qualname(node), f"attr:{attr}",
                f"`self.{attr}` is shared with the background fill "
                f"thread but accessed here outside `with self."
                f"{sorted(model.locks)[0]}`"))
            return          # one finding per access expression
        for child in ast.iter_child_nodes(node):
            visit(child, locked)

    for stmt in fn.body:
        visit(stmt, False)


def check(module: Module, config) -> List[Finding]:
    """Flag lock-discipline violations in thread-spawning classes."""
    findings: List[Finding] = []
    for cls in ast.walk(module.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        model = _ClassModel(cls)
        if not model.locks or not model.entries or not model.shared:
            continue
        for name, fn in model.methods.items():
            if name == "__init__":
                continue
            _check_function(model, fn, module, findings)
    return findings
