"""``pbcheck``: stdlib-``ast`` static analysis enforcing the PipeBoost
invariants that only fail at runtime — and usually late.

The latency wins live or die on properties nothing type-checks: the
fused decode path must never retrace, donated buffers must never be
read after the jit call that consumed them, and the background-fill
thread must touch shared engine state only under ``_load_lock`` (the
PR 7 crash-races-fill fix was exactly such a bug found late).  This
package mechanizes those invariants the way ``compile_guard``
mechanized compile counts at runtime:

==== =======================================================
rule invariant
==== =======================================================
R1   donated buffers are dead after the donating call
R2   no host syncs inside the decode/prefill hot-path modules
R3   fill-thread-shared engine state accessed under the lock
R4   no retrace hazards at jitted call sites
R5   chaos kinds / recovery modes handled exhaustively
R6   public APIs in the documented layers carry docstrings
==== =======================================================

Run it as ``python -m repro.analysis`` (or ``tools/pbcheck.py``);
findings can be silenced inline with ``# pbcheck: disable=R3 (reason)``
or accepted into a checked-in baseline file.  CI fails on any NEW
finding.  See ``docs/ANALYSIS.md`` for the rule catalogue and workflow.
"""
from repro.analysis.findings import Finding
from repro.analysis.cli import main, run_check

__all__ = ["Finding", "main", "run_check"]
