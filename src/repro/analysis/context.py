"""Shared per-module analysis context: parse tree, enclosing-symbol
map, suppressions, and the module's ``jax.jit`` registry.

Every rule consumes a :class:`Module`; cross-module rules (R5) get the
whole list.  The jit registry is the load-bearing piece: R1 needs to
know which *bindings* name donated jits (``self._decode_fused`` ->
donated argnums ``(3,)``) and R4 which bindings name any jit at all, so
call sites can be matched without type inference — a binding string is
``"name"`` for locals/globals and ``"self.name"`` for instance
attributes, collected from every ``X = jax.jit(...)`` assignment in
the module regardless of scope.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.analysis.suppress import Suppressions, parse_suppressions


def binding_str(node: ast.AST) -> Optional[str]:
    """``Name`` -> ``"x"``; ``self.x`` -> ``"self.x"``; else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute) \
            and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return f"self.{node.attr}"
    return None


def is_call_to(node: ast.AST, module: str, name: str) -> bool:
    """True for ``module.name(...)`` / bare ``name(...)`` call nodes."""
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    if isinstance(f, ast.Attribute) and f.attr == name \
            and isinstance(f.value, ast.Name) and f.value.id == module:
        return True
    return isinstance(f, ast.Name) and f.id == name


def _donate_argnums(call: ast.Call) -> Tuple[int, ...]:
    """Extract a literal ``donate_argnums=`` tuple/int from a jit call."""
    for kw in call.keywords:
        if kw.arg in ("donate_argnums", "donate_argnames"):
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                out = []
                for e in v.elts:
                    if isinstance(e, ast.Constant) \
                            and isinstance(e.value, int):
                        out.append(e.value)
                return tuple(out)
    return ()


@dataclass
class Module:
    """One parsed source file plus the lookups rules share."""
    path: str                      # repo-relative posix path
    source: str
    tree: ast.Module
    suppressions: Suppressions
    # binding ("self._decode_fused" / "step") -> donated argnums ()=none
    jits: Dict[str, Tuple[int, ...]] = field(default_factory=dict)
    # ast node id -> enclosing qualname ("Cls.method")
    _qualnames: Dict[int, str] = field(default_factory=dict)

    def qualname(self, node: ast.AST) -> str:
        """Enclosing class/function qualname for a node ("" = module)."""
        return self._qualnames.get(id(node), "")

    def matches(self, patterns) -> bool:
        """True if any pattern is a substring of this module's path."""
        return any(p in self.path for p in patterns)


def _index_qualnames(tree: ast.Module) -> Dict[int, str]:
    out: Dict[int, str] = {}

    def visit(node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                q = f"{prefix}.{child.name}" if prefix else child.name
                for sub in ast.walk(child):
                    out.setdefault(id(sub), q)
                visit(child, q)
            else:
                visit(child, prefix)

    visit(tree, "")
    return out


def _collect_jits(tree: ast.Module) -> Dict[str, Tuple[int, ...]]:
    jits: Dict[str, Tuple[int, ...]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and is_call_to(node.value, "jax", "jit"):
            key = binding_str(node.targets[0])
            if key is not None:
                jits[key] = _donate_argnums(node.value)
    return jits


def load_module(path: str, root: str = ".") -> Module:
    """Parse ``path`` into a :class:`Module` (raises SystemExit on a
    syntax error — an unparseable file IS a finding-worthy failure)."""
    with open(path, encoding="utf-8") as f:
        source = f.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        raise SystemExit(f"{path}: not parseable: {e}")
    rel = os.path.relpath(path, root).replace(os.sep, "/")
    return Module(path=rel, source=source, tree=tree,
                  suppressions=parse_suppressions(source),
                  jits=_collect_jits(tree),
                  _qualnames=_index_qualnames(tree))


def iter_python_files(roots: List[str]) -> List[str]:
    """Deterministic .py file discovery under files/directories."""
    out: List[str] = []
    for root in roots:
        if os.path.isfile(root):
            out.append(root)
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__", ".git"))
            out.extend(os.path.join(dirpath, fn)
                       for fn in sorted(filenames) if fn.endswith(".py"))
    return out
