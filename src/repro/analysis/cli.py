"""``pbcheck`` CLI: run the rule suite, apply suppressions and the
baseline, report, and gate.

Exit codes: 0 = clean (every finding fixed, suppressed-with-reason, or
baselined-with-justification), 1 = new findings / invalid suppressions
/ unjustified baseline entries.  ``--report`` writes the full findings
JSON (including what was suppressed and why) for the CI artifact.

Usage::

    PYTHONPATH=src python -m repro.analysis src/repro \\
        --baseline tools/pbcheck_baseline.json \\
        --report pbcheck_report.json
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from repro.analysis.baseline import Baseline, load_baseline, write_baseline
from repro.analysis.context import Module, iter_python_files, load_module
from repro.analysis.findings import Finding
from repro.analysis.rules import DESCRIPTIONS, RULES

DEFAULT_HOT_PATHS = ("serving/engine.py", "models/", "kernels/")
DEFAULT_DOCSTRING_PATHS = ("repro/cluster/", "repro/analysis/")


@dataclass
class CheckConfig:
    """Knobs the rules read (path scoping + rule selection)."""
    rules: Tuple[str, ...] = tuple(sorted(RULES))
    hot_paths: Tuple[str, ...] = DEFAULT_HOT_PATHS
    docstring_paths: Tuple[str, ...] = DEFAULT_DOCSTRING_PATHS


@dataclass
class CheckResult:
    """Everything one run produced, pre-gating."""
    findings: List[Finding] = field(default_factory=list)   # new (gate)
    suppressed: List[Tuple[Finding, str]] = field(default_factory=list)
    baselined: List[Finding] = field(default_factory=list)
    invalid_suppressions: List[Tuple[str, int, str]] = \
        field(default_factory=list)
    stale_baseline: List[str] = field(default_factory=list)
    n_files: int = 0

    @property
    def ok(self) -> bool:
        return not self.findings and not self.invalid_suppressions


def collect_findings(modules: Sequence[Module],
                     config: CheckConfig) -> List[Finding]:
    """Run every selected rule over ``modules`` (no gating applied)."""
    out: List[Finding] = []
    for rule_id in config.rules:
        rule = RULES[rule_id]
        if hasattr(rule, "check"):
            for m in modules:
                out.extend(rule.check(m, config))
        if hasattr(rule, "check_project"):
            out.extend(rule.check_project(modules, config))
    return sorted(out, key=lambda f: (f.path, f.line, f.rule, f.detail))


def run_check(paths: Sequence[str], config: Optional[CheckConfig] = None,
              baseline: Optional[Baseline] = None,
              root: str = ".") -> CheckResult:
    """Scan ``paths``, returning raw/suppressed/baselined findings.

    This is the library entry the tests drive; ``main`` wraps it with
    argument parsing, reporting, and exit-code policy.
    """
    config = config or CheckConfig()
    baseline = baseline or Baseline()
    modules = [load_module(p, root) for p in iter_python_files(list(paths))]
    result = CheckResult(n_files=len(modules))
    all_findings = collect_findings(modules, config)
    by_path = {m.path: m for m in modules}
    for f in all_findings:
        sup = by_path[f.path].suppressions
        if sup.active(f.line, f.rule):
            result.suppressed.append(
                (f, sup.reasons.get((f.line, f.rule), "")))
        elif baseline.matches(f):
            result.baselined.append(f)
        else:
            result.findings.append(f)
    for m in modules:
        for line, msg in m.suppressions.invalid:
            result.invalid_suppressions.append((m.path, line, msg))
    result.stale_baseline = baseline.stale(all_findings)
    return result


def _write_report(path: str, result: CheckResult,
                  config: CheckConfig) -> None:
    doc = {
        "version": 1,
        "rules": {r: DESCRIPTIONS[r] for r in config.rules},
        "n_files": result.n_files,
        "findings": [vars(f) | {"fingerprint": f.fingerprint}
                     for f in result.findings],
        "baselined": [vars(f) | {"fingerprint": f.fingerprint}
                      for f in result.baselined],
        "suppressed": [vars(f) | {"reason": reason}
                       for f, reason in result.suppressed],
        "invalid_suppressions": [
            {"path": p, "line": ln, "message": msg}
            for p, ln, msg in result.invalid_suppressions],
        "stale_baseline": result.stale_baseline,
    }
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1)
        fh.write("\n")


def main(argv=None) -> int:
    """Argparse entry point (see module docstring for the contract)."""
    ap = argparse.ArgumentParser(
        prog="pbcheck",
        description="PipeBoost static-analysis suite (rules R1-R6)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to scan (default: src/repro)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset (default: all)")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON of accepted findings")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite --baseline from current findings "
                         "(new entries get a TODO justification that "
                         "must be edited before the run passes)")
    ap.add_argument("--report", default=None,
                    help="write the findings report JSON here")
    ap.add_argument("--hot-paths", default=",".join(DEFAULT_HOT_PATHS),
                    help="comma-separated path substrings R2 treats as "
                         "hot-path modules")
    ap.add_argument("--docstring-paths",
                    default=",".join(DEFAULT_DOCSTRING_PATHS),
                    help="comma-separated path substrings R6 scopes to")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="also list suppressed and baselined findings")
    args = ap.parse_args(argv)

    rules = tuple(sorted(RULES))
    if args.rules:
        rules = tuple(sorted(r.strip().upper()
                             for r in args.rules.split(",") if r.strip()))
        unknown = [r for r in rules if r not in RULES]
        if unknown:
            raise SystemExit(f"unknown rules {unknown}; "
                             f"available: {sorted(RULES)}")
    config = CheckConfig(
        rules=rules,
        hot_paths=tuple(p for p in args.hot_paths.split(",") if p),
        docstring_paths=tuple(p for p in args.docstring_paths.split(",")
                              if p))
    baseline = load_baseline(args.baseline) if args.baseline \
        else Baseline()
    paths = args.paths or ["src/repro"]
    result = run_check(paths, config, baseline)

    if args.write_baseline:
        if not args.baseline:
            raise SystemExit("--write-baseline requires --baseline PATH")
        write_baseline(args.baseline,
                       result.findings + result.baselined, baseline)
        print(f"pbcheck: wrote {len(result.findings + result.baselined)} "
              f"entries to {args.baseline} (edit any TODO justifications)")
        return 0

    for f in result.findings:
        print(f.render())
    for path, line, msg in result.invalid_suppressions:
        print(f"{path}:{line}:0: SUP invalid suppression: {msg}")
    if args.verbose:
        for f, reason in result.suppressed:
            print(f"# suppressed: {f.render()}  ({reason})")
        for f in result.baselined:
            print(f"# baselined: {f.render()}")
    for fp in result.stale_baseline:
        print(f"# stale baseline entry (no longer found): {fp}")
    bad_baseline = baseline.unjustified()
    for e in bad_baseline:
        print(f"BASELINE {e['fingerprint']}: justification missing/TODO")
    if args.report:
        _write_report(args.report, result, config)

    n_new = len(result.findings)
    print(f"pbcheck: {result.n_files} files, rules {','.join(rules)}: "
          f"{n_new} new, {len(result.suppressed)} suppressed, "
          f"{len(result.baselined)} baselined"
          + (f", {len(result.invalid_suppressions)} invalid suppressions"
             if result.invalid_suppressions else ""))
    if n_new or result.invalid_suppressions or bad_baseline:
        print("FAIL")
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
