"""Inline suppression comments: ``# pbcheck: disable=R3 (reason)``.

A suppression silences the named rule(s) for findings on the same
source line, or — when the comment stands on its own line — the next
code line below it.  The parenthesized reason is REQUIRED: a
suppression without one does not suppress anything and is itself
reported, so "shut it up" can never masquerade as "thought about it".
Multiple rules: ``disable=R2,R3``.
"""
from __future__ import annotations

import io
import re
import tokenize
from dataclasses import dataclass, field
from typing import Dict, List, Set, Tuple

_PAT = re.compile(
    r"#\s*pbcheck:\s*disable=(?P<rules>[A-Za-z0-9,\s]+?)"
    r"\s*(?:\((?P<reason>[^)]*)\))?\s*$")


@dataclass
class Suppressions:
    """Parsed suppressions for one module."""
    # code line -> set of rule ids silenced on that line
    by_line: Dict[int, Set[str]] = field(default_factory=dict)
    # reasons keyed by (line, rule) — kept for the findings report
    reasons: Dict[Tuple[int, str], str] = field(default_factory=dict)
    # malformed suppressions (no reason / no rules): (line, message)
    invalid: List[Tuple[int, str]] = field(default_factory=list)
    # (line, rule) pairs that actually silenced a finding
    used: Set[Tuple[int, str]] = field(default_factory=set)

    def active(self, line: int, rule: str) -> bool:
        """True (and mark used) if ``rule`` is silenced on ``line``."""
        if rule in self.by_line.get(line, ()):
            self.used.add((line, rule))
            return True
        return False


def parse_suppressions(source: str) -> Suppressions:
    """Extract every pbcheck suppression comment from ``source``.

    Own-line comments attach to the next non-comment, non-blank line
    (the statement they annotate); trailing comments attach to their
    own line.
    """
    sup = Suppressions()
    comments: List[Tuple[int, int, str]] = []   # (line, col, text)
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type == tokenize.COMMENT:
                comments.append((tok.start[0], tok.start[1], tok.string))
    except tokenize.TokenError:
        return sup          # unparseable tail: no suppressions there
    code_lines = _code_line_set(source)
    for line, col, text in comments:
        m = _PAT.search(text)
        if m is None:
            if "pbcheck:" in text:
                sup.invalid.append(
                    (line, f"unrecognized pbcheck comment {text!r}"))
            continue
        rules = {r.strip().upper() for r in m.group("rules").split(",")
                 if r.strip()}
        reason = (m.group("reason") or "").strip()
        if not rules:
            sup.invalid.append((line, "suppression names no rules"))
            continue
        if not reason:
            sup.invalid.append(
                (line, "suppression without a (reason) is ignored: "
                       f"{text.strip()!r}"))
            continue
        own_line = col == 0 or line not in code_lines
        target = _next_code_line(code_lines, line) if own_line else line
        sup.by_line.setdefault(target, set()).update(rules)
        for r in rules:
            sup.reasons[(target, r)] = reason
    return sup


def _code_line_set(source: str) -> Set[int]:
    """Lines carrying code (not blank, not comment-only)."""
    out: Set[int] = set()
    for i, raw in enumerate(source.splitlines(), start=1):
        s = raw.strip()
        if s and not s.startswith("#"):
            out.add(i)
    return out


def _next_code_line(code_lines: Set[int], after: int) -> int:
    """First code line strictly below ``after`` (or ``after`` itself
    when the file ends in comments — the suppression then dangles
    harmlessly)."""
    later = [ln for ln in code_lines if ln > after]
    return min(later) if later else after
