from repro.lora.adapters import (LoRAAdapter, init_lora, lora_bytes,
                                 merge_lora, unmerge_lora)

__all__ = ["LoRAAdapter", "init_lora", "merge_lora", "unmerge_lora",
           "lora_bytes"]
