"""LoRA adapters over the stacked-parameter model zoo.

Adapters target the attention projections (wq, wk, wv, wo) of every
attention-bearing layer, matching the paper's merged-LoRA serving path
(§4.3.2): ``W' = W + (alpha/r) * A @ B``.  Merging/unmerging are exact
inverses (up to fp accumulation), enabling the engine's epoch-based adapter
switching.  The Pallas kernel ``repro.kernels.lora_merge`` performs the same
update as a fused VMEM-tiled pass on TPU; this module is the jnp path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init

TARGETS = ("wq", "wk", "wv", "wo")


@dataclass
class LoRAAdapter:
    name: str
    rank: int
    alpha: float
    # blocks[kind][target] = {"A": (L, d_in, r), "B": (L, r, d_out)}
    blocks: Dict[str, Dict[str, Dict[str, jnp.ndarray]]]

    @property
    def scale(self) -> float:
        return self.alpha / self.rank


def _attn_dims(cfg: ArchConfig) -> Dict[str, Tuple[int, int]]:
    D, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "wq": (D, cfg.n_heads * hd),
        "wk": (D, cfg.n_kv_heads * hd),
        "wv": (D, cfg.n_kv_heads * hd),
        "wo": (cfg.n_heads * hd, D),
    }


def init_lora(key, cfg: ArchConfig, rank: int, *, alpha: float = None,
              name: str = "adapter", dtype=jnp.float32) -> LoRAAdapter:
    alpha = alpha if alpha is not None else 2.0 * rank
    dims = _attn_dims(cfg)
    kinds = {}
    counts: Dict[str, int] = {}
    for k in cfg.layer_kinds():
        counts[k] = counts.get(k, 0) + 1
    blocks: Dict[str, Any] = {}
    for kind in ("attn", "moe"):
        if kind not in counts:
            continue
        L = counts[kind]
        tgt = {}
        for t, (din, dout) in dims.items():
            ka, kb = jax.random.split(jax.random.fold_in(key, hash((kind, t)) % 2**31))
            tgt[t] = {
                # A ~ N(0, 1/din), B = 0 (standard LoRA init)
                "A": jax.vmap(lambda k_: dense_init(k_, (din, rank), dtype))(
                    jax.random.split(ka, L)),
                "B": jnp.zeros((L, rank, dout), dtype),
            }
        blocks[kind] = tgt
    return LoRAAdapter(name, rank, alpha, blocks)


def randomize_lora(key, adapter: LoRAAdapter) -> LoRAAdapter:
    """Give B non-zero values (tests / distinct-adapter simulations)."""
    new_blocks = {}
    for kind, tgts in adapter.blocks.items():
        new_blocks[kind] = {}
        for t, ab in tgts.items():
            kb = jax.random.fold_in(key, hash((kind, t, "B")) % 2**31)
            new_blocks[kind][t] = {
                "A": ab["A"],
                "B": jax.random.normal(kb, ab["B"].shape, ab["B"].dtype) * 0.02,
            }
    return LoRAAdapter(adapter.name, adapter.rank, adapter.alpha, new_blocks)


def _apply(params, adapter: LoRAAdapter, sign: float, use_kernel: bool):
    new = jax.tree.map(lambda a: a, params)  # shallow-ish copy of structure
    for kind, tgts in adapter.blocks.items():
        blk = dict(new["blocks"][kind])
        for t, ab in tgts.items():
            if use_kernel:
                from repro.kernels import ops as kops
                blk[t] = kops.lora_merge(blk[t], ab["A"], ab["B"],
                                         sign * adapter.scale)
            else:
                delta = jnp.einsum("ldr,lro->ldo", ab["A"], ab["B"])
                blk[t] = (blk[t].astype(jnp.float32)
                          + sign * adapter.scale * delta.astype(jnp.float32)
                          ).astype(blk[t].dtype)
        new["blocks"] = dict(new["blocks"])
        new["blocks"][kind] = blk
    return new


def merge_lora(params, adapter: LoRAAdapter, use_kernel: bool = False):
    """W' = W + scale * A@B on every target projection."""
    return _apply(params, adapter, +1.0, use_kernel)


def unmerge_lora(params, adapter: LoRAAdapter, use_kernel: bool = False):
    return _apply(params, adapter, -1.0, use_kernel)


def lora_bytes(cfg: ArchConfig, rank: int, dtype_bytes: int = 2) -> int:
    dims = _attn_dims(cfg)
    n_attn = sum(1 for k in cfg.layer_kinds() if k in ("attn", "moe"))
    n = sum(rank * (din + dout) for din, dout in dims.values())
    return n * n_attn * dtype_bytes
