"""Model assembly: stacked-layer decoder/encoder covering all assigned
architecture families (dense GQA, MoE, Mamba2 SSD, RG-LRU hybrid, encoder).

Parameters are stored *stacked by layer kind* (leading axis = layer index
within that kind) so the whole stack runs under one ``lax.scan`` — compile
time and HLO size stay flat in depth, and a stacked leading axis reshapes
cleanly into pipeline stages (core/pipeline.py) and planner segments
(core/planner.py).

Three entry points (pure functions of (cfg, params, batch)):
  * ``forward(..., mode="train")``   -> (logits (B,S,V), aux)
  * ``forward(..., mode="prefill")`` -> (last-token logits (B,V), cache)
  * ``decode_step(...)``             -> (logits (B,V), cache)

See ``docs/ARCHITECTURE.md`` § "Models and kernels".
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.context import constrain
from repro.models import attention as attn_lib
from repro.models import mamba2, moe, rglru
from repro.models.layers import (_ACTS, apply_mrope, apply_rope, dense_init,
                                 embed_init, init_mlp, layer_norm, mlp,
                                 rms_norm)

Params = Dict[str, Any]
Cache = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_attn_layer(key, cfg: ArchConfig, dtype) -> Params:
    D, hd = cfg.d_model, cfg.resolved_head_dim
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 8)
    p: Params = {
        "ln1": _norm_init(cfg, D, dtype),
        "wq": dense_init(ks[0], (D, Hq * hd), dtype),
        "wk": dense_init(ks[1], (D, Hkv * hd), dtype),
        "wv": dense_init(ks[2], (D, Hkv * hd), dtype),
        "wo": dense_init(ks[3], (Hq * hd, D), dtype),
        "ln2": _norm_init(cfg, D, dtype),
        "mlp": _init_mlp_for(cfg, ks[4], dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq * hd,), dtype)
        p["bk"] = jnp.zeros((Hkv * hd,), dtype)
        p["bv"] = jnp.zeros((Hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _norm_init(cfg, D, dtype):
    if cfg.family == "audio":
        return {"scale": jnp.ones((D,), dtype), "bias": jnp.zeros((D,), dtype)}
    return jnp.ones((D,), dtype)


def _apply_norm(cfg, w, x):
    if cfg.family == "audio":
        return layer_norm(w, x, cfg.norm_eps)
    return rms_norm(w, x, cfg.norm_eps)


def _init_mlp_for(cfg, key, dtype) -> Params:
    if cfg.gated_mlp:
        return init_mlp(key, cfg.d_model, cfg.d_ff, dtype)
    k1, k2 = jax.random.split(key)
    return {"w_up": dense_init(k1, (cfg.d_model, cfg.d_ff), dtype),
            "w_down": dense_init(k2, (cfg.d_ff, cfg.d_model), dtype)}


def _apply_mlp(cfg, p, x):
    if cfg.gated_mlp:
        h = constrain(_ACTS[cfg.act](x @ p["w_gate"]) * (x @ p["w_up"]), "ffh")
        return h @ p["w_down"]
    h = constrain(_ACTS[cfg.act](x @ p["w_up"]), "ffh")
    return h @ p["w_down"]


def _init_moe_layer(key, cfg, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    p = _init_attn_layer(k1, cfg, dtype)
    p["mlp"] = moe.init_moe_mlp(k2, cfg, dtype)
    return p


def _init_rec_layer(key, cfg, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": _norm_init(cfg, cfg.d_model, dtype),
        "rec": rglru.init_rec_block(k1, cfg, dtype),
        "ln2": _norm_init(cfg, cfg.d_model, dtype),
        "mlp": _init_mlp_for(cfg, k2, dtype),
    }


_LAYER_INIT = {
    "attn": _init_attn_layer,
    "moe": _init_moe_layer,
    "ssm": lambda k, c, d: mamba2.init_ssm_block(k, c, d),
    "rec": _init_rec_layer,
}


def kind_counts(cfg: ArchConfig) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for k in cfg.layer_kinds():
        counts[k] = counts.get(k, 0) + 1
    return counts


def init_params(cfg: ArchConfig, key, dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.dtype)
    V, D = cfg.padded_vocab, cfg.d_model
    k_embed, k_head, k_blocks = jax.random.split(key, 3)
    params: Params = {"embed": embed_init(k_embed, (V, D), dtype)}
    blocks: Params = {}
    for kind, n in kind_counts(cfg).items():
        keys = jax.random.split(jax.random.fold_in(k_blocks, hash(kind) % 2**31), n)
        blocks[kind] = jax.vmap(
            lambda kk: _LAYER_INIT[kind](kk, cfg, dtype))(keys)
    params["blocks"] = blocks
    params["final_norm"] = _norm_init(cfg, D, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (D, V), dtype)
    return params


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

def attn_cache_capacity(cfg: ArchConfig, max_len: int) -> int:
    """Ring-buffer capacity: the window for local attention, else max_len."""
    if cfg.attn_window > 0:
        return min(max_len, cfg.attn_window)
    return max_len


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> Cache:
    dtype = dtype or jnp.dtype(cfg.dtype)
    counts = kind_counts(cfg)
    cache: Cache = {"pos": jnp.zeros((), jnp.int32)}
    hd = cfg.resolved_head_dim
    n_attnlike = counts.get("attn", 0) + counts.get("moe", 0)
    if n_attnlike:
        C = attn_cache_capacity(cfg, max_len)
        cache["attn"] = {
            "k": jnp.zeros((n_attnlike, batch, C, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((n_attnlike, batch, C, cfg.n_kv_heads, hd), dtype),
        }
    if "ssm" in counts:
        L = counts["ssm"]
        ch = cfg.d_inner + 2 * cfg.ssm_state
        cache["ssm"] = {
            "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, ch), dtype),
            "state": jnp.zeros((L, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                                cfg.ssm_state), jnp.float32),
        }
    if "rec" in counts:
        L = counts["rec"]
        W = cfg.lru_width or cfg.d_model
        cache["rec"] = {
            "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, W), dtype),
            "h": jnp.zeros((L, batch, W), jnp.float32),
        }
    return cache


# ---------------------------------------------------------------------------
# Per-layer forwards
# ---------------------------------------------------------------------------

def _project_qkv(cfg, p, h):
    B, S, _ = h.shape
    hd = cfg.resolved_head_dim
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)
    return q, k, v


def _rope(cfg, x, positions):
    """positions: (B, S) int or (B, S, 3) for mrope."""
    if cfg.mrope:
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return apply_rope(x, positions, cfg.rope_theta)


def attn_layer_fwd(cfg, p, x, positions, *, kv_write: Optional[int] = None):
    """Full-sequence attention layer. Returns (x, (k, v)) — roped k/v for the
    cache when prefilling (kv_write = capacity) else (None, None)."""
    h = _apply_norm(cfg, p["ln1"], x)
    q, k, v = _project_qkv(cfg, p, h)
    q = constrain(_rope(cfg, q, positions), "heads")
    k = constrain(_rope(cfg, k, positions), "heads")
    v = constrain(v, "heads")
    o = attn_lib.attention(q, k, v, causal=cfg.causal, window=cfg.attn_window)
    o = o.reshape(*x.shape[:2], -1) @ p["wo"]
    x = constrain(x + o, "act")
    h2 = _apply_norm(cfg, p["ln2"], x)
    if "router" in p["mlp"]:
        y, aux = moe.moe_mlp(cfg, p["mlp"], h2, _ACTS[cfg.act])
    else:
        y = _apply_mlp(cfg, p["mlp"], h2)
        aux = jnp.zeros((), jnp.float32)
    x = constrain(x + y, "act")
    kv = None
    if kv_write is not None:
        S = k.shape[1]
        if kv_write >= S:
            pad = kv_write - S
            kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        else:
            # Ring buffer smaller than the prompt: keep the tail, placed so
            # slot j holds position p with p % cap == j (decode writes at
            # pos % cap, so the oldest entry is always the one overwritten).
            shift = (S - kv_write) % kv_write
            kc = jnp.roll(k[:, S - kv_write:], shift, axis=1)
            vc = jnp.roll(v[:, S - kv_write:], shift, axis=1)
        kv = (kc, vc)
    return x, kv, aux


def attn_layer_step(cfg, p, x, position, k_cache, v_cache, cache_len, *,
                    zero_copy: bool = False):
    """Single-token step. x: (B, 1, D); caches (B, C, kv, hd);
    cache_len: (B,) per-slot valid lengths (continuous batching).

    ``zero_copy=False`` (legacy copy path): the current token's K/V are
    written into the cache here and the updated cache-sized arrays are
    returned — the classic copy-per-layer loop.

    ``zero_copy=True``: the cache is only *read*; the current token is
    merged into the softmax as an online partial
    (``decode_attention_merged``) and only its (B, kv, hd) K/V row is
    returned.  The caller performs one scatter of all layers' rows into
    the donated cache after the layer scan — decode stops rewriting
    cache-sized buffers every layer.  Ring-buffered (windowed) caches ride
    the same path: eviction becomes a per-slot mask on the read (the slot
    the new row will land in holds the evicted, out-of-window entry), and
    the post-scan scatter at ``pos % C`` performs the overwrite.
    """
    h = _apply_norm(cfg, p["ln1"], x)
    q, k, v = _project_qkv(cfg, p, h)
    pos2d = position if position.ndim >= 2 else position[:, None]
    q = _rope(cfg, q, pos2d if not cfg.mrope else position)
    k = _rope(cfg, k, pos2d if not cfg.mrope else position)
    B, C = k_cache.shape[:2]
    if zero_copy:
        valid_old = jnp.minimum(cache_len, C)
        slot_mask = None
        if cfg.attn_window > 0:
            # ring invariant: slot j holds the latest position p < pos with
            # p % C == j.  Once the ring is full the slot the new token
            # overwrites (pos % C) holds position pos - C — exactly one
            # step outside the window — so it must not be attended.
            j = jnp.arange(C)[None, :]
            p_len = cache_len[:, None]
            slot_mask = (j < p_len) & ((p_len < C) | (j != jnp.mod(p_len, C)))
        o = attn_lib.decode_attention_merged(q, k_cache, v_cache, valid_old,
                                             k, v, kv_slot_mask=slot_mask)
        kv_out = (k[:, 0], v[:, 0])
    else:
        slot = jnp.mod(cache_len, C)      # == cache_len when C >= max_len
        bidx = jnp.arange(B)
        k_cache = k_cache.at[bidx, slot].set(k[:, 0])
        v_cache = v_cache.at[bidx, slot].set(v[:, 0])
        valid = jnp.minimum(cache_len + 1, C)
        o = attn_lib.decode_attention(q, k_cache, v_cache, valid)
        kv_out = (k_cache, v_cache)
    o = o.reshape(x.shape[0], 1, -1) @ p["wo"]
    x = x + o
    h2 = _apply_norm(cfg, p["ln2"], x)
    if "router" in p["mlp"]:
        y, _ = moe.moe_mlp(cfg, p["mlp"], h2, _ACTS[cfg.act], dropless=True)
    else:
        y = _apply_mlp(cfg, p["mlp"], h2)
    return x + y, kv_out[0], kv_out[1]


def rec_layer_fwd(cfg, p, x, *, conv_state=None, h0=None, want_state=False):
    h = _apply_norm(cfg, p["ln1"], x)
    y, (conv_s, h_last) = rglru.rec_block_fwd(cfg, p["rec"], h,
                                              conv_state=conv_state, h0=h0)
    x = constrain(x + y, "act")
    h2 = _apply_norm(cfg, p["ln2"], x)
    x = constrain(x + _apply_mlp(cfg, p["mlp"], h2), "act")
    return x, (conv_s, h_last) if want_state else None


def rec_layer_step(cfg, p, x, conv_state, h):
    hin = _apply_norm(cfg, p["ln1"], x)
    y, (conv_s, h_new) = rglru.rec_block_step(cfg, p["rec"], hin[:, 0, :],
                                              conv_state, h)
    x = x + y[:, None, :]
    h2 = _apply_norm(cfg, p["ln2"], x)
    x = x + _apply_mlp(cfg, p["mlp"], h2)
    return x, conv_s, h_new


# ---------------------------------------------------------------------------
# Full-model forward
# ---------------------------------------------------------------------------

def embed_tokens(cfg, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x (B,S,D), positions)."""
    if "embeds" in batch:
        x = batch["embeds"]
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    if "positions" in batch:
        positions = batch["positions"]
    else:
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    return constrain(x, "act"), positions


def unembed(cfg, params, x) -> jnp.ndarray:
    import os
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if os.environ.get("REPRO_BF16_LOGITS"):
        # halve CE-section wire/HBM traffic; logsumexp still runs f32
        lg = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
        return constrain(lg, "logits")
    return constrain(jnp.einsum("bsd,dv->bsv", x, head,
                                preferred_element_type=jnp.float32), "logits")


def forward(cfg: ArchConfig, params: Params, batch: Dict, *,
            mode: str = "train", max_len: Optional[int] = None,
            remat: bool = False, unroll: int = 1,
            last_index=None) -> Tuple[jnp.ndarray, Any]:
    """Full-sequence forward.

    mode="train":   returns (logits (B,S,V) f32, aux_loss scalar)
    mode="prefill": returns (last logits (B,V) f32, cache)

    ``last_index`` (B,) int32, prefill only: per-row index of the true last
    prompt token for right-padded (bucketed) prompts.  Logits are gathered
    there and ``cache["pos"]`` is set to ``last_index + 1`` so decode
    attention masks the pad K/V.  Only valid for models whose per-token
    state is causal and batch-row-independent (pure attention with a
    full-length cache); SSM/recurrent running states would integrate the
    pad tokens — callers gate on that (see serving.engine).
    """
    assert mode in ("train", "prefill")
    x, positions = embed_tokens(cfg, params, batch)
    B, S = x.shape[:2]
    kinds = cfg.layer_kinds()
    want_cache = mode == "prefill"
    max_len = max_len or S
    cap = attn_cache_capacity(cfg, max_len) if want_cache else None

    aux_total = jnp.zeros((), jnp.float32)
    kv_stack = {"k": [], "v": []}
    ssm_states: Dict[str, list] = {"conv": [], "state": []}
    rec_states: Dict[str, list] = {"conv": [], "h": []}

    def attn_body(x, p_l):
        x, kv, aux = attn_layer_fwd(cfg, p_l, x, positions,
                                    kv_write=cap if want_cache else None)
        outs = (kv if kv is not None else (), aux)
        return x, outs

    def ssm_body(x, p_l):
        x, (conv_s, state) = mamba2.ssm_block_fwd(cfg, p_l, x)
        return x, ((conv_s, state) if want_cache else ())

    def rec_body(x, p_l):
        x, st = rec_layer_fwd(cfg, p_l, x, want_state=True)
        return x, (st if want_cache else ())

    bodies = {"attn": attn_body, "moe": attn_body, "ssm": ssm_body,
              "rec": rec_body}

    # Group maximal runs of the same kind and scan each run over its stacked
    # params (hybrid patterns become several short scans over slices).
    runs = _kind_runs(kinds)
    kind_cursor: Dict[str, int] = {}
    for kind, count in runs:
        start = kind_cursor.get(kind, 0)
        kind_cursor[kind] = start + count
        stacked = jax.tree.map(lambda a: a[start:start + count],
                               params["blocks"][kind])
        body = bodies[kind]
        if remat:
            body = jax.checkpoint(body)
        x, outs = jax.lax.scan(body, x, stacked, unroll=unroll)
        if kind in ("attn", "moe"):
            if want_cache:
                kv, aux = outs
                kv_stack["k"].append(kv[0])
                kv_stack["v"].append(kv[1])
            else:
                _, aux = outs
            aux_total = aux_total + jnp.sum(aux)
        elif kind == "ssm" and want_cache:
            conv_s, state = outs
            ssm_states["conv"].append(conv_s)
            ssm_states["state"].append(state)
        elif kind == "rec" and want_cache:
            conv_s, h_last = outs
            rec_states["conv"].append(conv_s)
            rec_states["h"].append(h_last)

    x = _apply_norm(cfg, params["final_norm"], x)

    if mode == "train":
        return unembed(cfg, params, x), aux_total

    if last_index is not None:
        li = jnp.asarray(last_index, jnp.int32)
        x_last = x[jnp.arange(B), li]                 # (B, D)
        logits = unembed(cfg, params, x_last[:, None, :])[:, 0, :]
        cache: Cache = {"pos": li + 1}
    else:
        logits = unembed(cfg, params, x[:, -1:, :])[:, 0, :]
        cache = {"pos": jnp.full((B,), S, jnp.int32)}
    if kv_stack["k"]:
        cache["attn"] = {"k": jnp.concatenate(kv_stack["k"], axis=0),
                         "v": jnp.concatenate(kv_stack["v"], axis=0)}
    if ssm_states["conv"]:
        cache["ssm"] = {"conv": jnp.concatenate(ssm_states["conv"], axis=0),
                        "state": jnp.concatenate(ssm_states["state"], axis=0)}
    if rec_states["conv"]:
        cache["rec"] = {"conv": jnp.concatenate(rec_states["conv"], axis=0),
                        "h": jnp.concatenate(rec_states["h"], axis=0)}
    return logits, cache


def _kind_runs(kinds):
    runs = []
    for k in kinds:
        if runs and runs[-1][0] == k:
            runs[-1][1] += 1
        else:
            runs.append([k, 1])
    return [(k, n) for k, n in runs]


def decode_step(cfg: ArchConfig, params: Params, batch: Dict,
                cache: Cache, *, unroll: int = 1) -> Tuple[jnp.ndarray, Cache]:
    """One autoregressive step.

    batch: {"tokens": (B,) int32} or {"embeds": (B, 1, D)}
           (+ "positions": (B, 1) or (B, 1, 3) for mrope).
    Returns (logits (B, V) f32, new cache).
    """
    assert cfg.has_decode, f"{cfg.name} is encoder-only"
    pos = cache["pos"]  # (B,) per-slot positions
    if "embeds" in batch:
        x = batch["embeds"]
        B = x.shape[0]
    else:
        toks = batch["tokens"].reshape(-1)
        x = jnp.take(params["embed"], toks[:, None], axis=0)
        B = toks.shape[0]
    if pos.ndim == 0:
        pos = jnp.full((B,), pos, jnp.int32)
    if "positions" in batch:
        positions = batch["positions"]
    else:
        positions = pos[:, None]

    kinds = cfg.layer_kinds()
    runs = _kind_runs(kinds)
    kind_cursor: Dict[str, int] = {}
    new_cache: Cache = {"pos": pos + 1}
    # collect per-kind outputs across runs, then reassemble stacks
    collected: Dict[str, list] = {k: [] for k in ("attn_k", "attn_v",
                                                  "ssm_conv", "ssm_state",
                                                  "rec_conv", "rec_h")}
    # attn/moe share the "attn" cache stack; track separate cursor
    attnlike_cursor = 0

    for kind, count in runs:
        start = kind_cursor.get(kind, 0)
        kind_cursor[kind] = start + count
        stacked = jax.tree.map(lambda a: a[start:start + count],
                               params["blocks"][kind])
        if kind in ("attn", "moe"):
            a0 = attnlike_cursor
            attnlike_cursor += count
            kc = cache["attn"]["k"][a0:a0 + count]
            vc = cache["attn"]["v"][a0:a0 + count]
            # Zero-copy hot path: the scan only READS the cache and emits
            # each layer's new (B, kv, hd) row; one scatter after the scan
            # writes all rows — with a donated cache that's an in-place
            # O(L*B)-row update instead of an O(cache-size) rewrite per
            # layer.  Ring-buffer (windowed) caches use the same path:
            # the merged partial masks out the slot being evicted
            # (attn_layer_step builds the per-slot mask) and the post-scan
            # scatter at pos % C is the eviction write itself.

            def body(x, per):
                p_l, k_l, v_l = per
                x, k_l, v_l = attn_layer_step(cfg, p_l, x, positions, k_l,
                                              v_l, pos, zero_copy=True)
                return x, (k_l, v_l)

            x, (kn, vn) = jax.lax.scan(body, x, (stacked, kc, vc),
                                       unroll=unroll)
            C = kc.shape[2]
            slot = jnp.mod(pos, C)
            bidx = jnp.arange(B)
            kc = kc.at[:, bidx, slot].set(kn)        # (count, B, kv, hd) rows
            vc = vc.at[:, bidx, slot].set(vn)
            collected["attn_k"].append(kc)
            collected["attn_v"].append(vc)
        elif kind == "ssm":
            cv = cache["ssm"]["conv"][start:start + count]
            st = cache["ssm"]["state"][start:start + count]

            def body(x, per):
                p_l, cv_l, st_l = per
                y, (cv_l, st_l) = mamba2.ssm_block_step(cfg, p_l, x[:, 0, :],
                                                        cv_l, st_l)
                return y[:, None, :], (cv_l, st_l)

            x, (cv, st) = jax.lax.scan(body, x, (stacked, cv, st), unroll=unroll)
            collected["ssm_conv"].append(cv)
            collected["ssm_state"].append(st)
        elif kind == "rec":
            cv = cache["rec"]["conv"][start:start + count]
            hh = cache["rec"]["h"][start:start + count]

            def body(x, per):
                p_l, cv_l, h_l = per
                x, cv_l, h_l = rec_layer_step(cfg, p_l, x, cv_l, h_l)
                return x, (cv_l, h_l)

            x, (cv, hh) = jax.lax.scan(body, x, (stacked, cv, hh), unroll=unroll)
            collected["rec_conv"].append(cv)
            collected["rec_h"].append(hh)

    x = _apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params, x)[:, 0, :]

    if collected["attn_k"]:
        new_cache["attn"] = {"k": jnp.concatenate(collected["attn_k"], 0),
                             "v": jnp.concatenate(collected["attn_v"], 0)}
    if collected["ssm_conv"]:
        new_cache["ssm"] = {"conv": jnp.concatenate(collected["ssm_conv"], 0),
                            "state": jnp.concatenate(collected["ssm_state"], 0)}
    if collected["rec_conv"]:
        new_cache["rec"] = {"conv": jnp.concatenate(collected["rec_conv"], 0),
                            "h": jnp.concatenate(collected["rec_h"], 0)}
    return logits, new_cache
