"""Mamba-2 SSD (state-space duality) block — pure JAX [arXiv:2405.21060].

Chunked dual form: within a chunk the token-mixing is a (masked) quadratic
form in VMEM-friendly tiles; across chunks a tiny (H, P, N) state is carried
by an associative scan.  This is the TPU-native shape of the algorithm (the
Pallas kernel ``repro.kernels.ssd_scan`` implements the same math with
explicit VMEM tiling; this module is the XLA-lowered path and the oracle).

Decode is the O(1)-per-token recurrent form — the reason mamba2 runs the
``long_500k`` cell with a constant-size cache.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models.layers import causal_conv1d, causal_conv1d_step, dense_init, rms_norm


def init_ssm_block(key, cfg, dtype) -> Dict:
    D = cfg.d_model
    di = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.ssm_heads
    K = cfg.ssm_conv
    ks = jax.random.split(key, 6)
    conv_ch = di + 2 * N
    return {
        "norm": jnp.ones((D,), dtype),
        "in_proj": dense_init(ks[0], (D, 2 * di + 2 * N + H), dtype),
        "conv_w": dense_init(ks[1], (K, conv_ch), dtype, scale=0.5),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "gate_norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], (di, D), dtype),
    }


def _split_in_proj(cfg, zxbcdt):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di:2 * di]
    B = zxbcdt[..., 2 * di:2 * di + N]
    C = zxbcdt[..., 2 * di + N:2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N:]
    return z, x, B, C, dt


def ssd_chunked(x, dt, A, B, C, chunk: int,
                initial_state: Optional[jnp.ndarray] = None):
    """Chunked SSD.

    x: (Bt, S, H, P); dt: (Bt, S, H) (already softplus'ed, >0);
    A: (H,) negative; B, C: (Bt, S, N) [single group broadcast to heads].
    Returns (y: (Bt, S, H, P), final_state: (Bt, H, P, N)).
    """
    Bt, S0, H, P = x.shape
    N = B.shape[-1]
    # pad to a chunk multiple: padded steps get dt=0 => decay exp(0)=1 and
    # zero state contribution, so they are exact no-ops on the recurrence.
    pad = (-S0) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    S = S0 + pad
    nc = S // chunk

    f32 = jnp.float32
    xc = x.reshape(Bt, nc, chunk, H, P).astype(f32)
    dtc = dt.reshape(Bt, nc, chunk, H).astype(f32)
    Bc = B.reshape(Bt, nc, chunk, N).astype(f32)
    Cc = C.reshape(Bt, nc, chunk, N).astype(f32)

    dA = dtc * A.astype(f32)                       # (Bt,nc,Q,H) negative
    cum = jnp.cumsum(dA, axis=2)                   # within-chunk cumulative
    # --- intra-chunk (quadratic within chunk) ---
    # L[q, k] = exp(cum_q - cum_k) for q >= k
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (Bt,nc,Q,Q,H)
    q_idx = jnp.arange(chunk)
    causal = (q_idx[:, None] >= q_idx[None, :])
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)         # (Bt,nc,Q,Q)
    G = scores[..., None] * L * dtc[:, :, None, :, :]      # (Bt,nc,Q,K,H)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", G, xc)

    # --- chunk states ---
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # (Bt,nc,Q,H)
    S_chunk = jnp.einsum("bckh,bckn,bckhp->bchnp",
                         decay_to_end * dtc, Bc, xc)       # (Bt,nc,H,N,P)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # (Bt,nc,H)

    # --- inter-chunk recurrence: s_c = d_c * s_{c-1} + S_c (associative) ---
    if initial_state is not None:
        # fold the initial state in as a virtual chunk 0
        s0 = jnp.swapaxes(initial_state.astype(f32), -1, -2)[:, None]  # (Bt,1,H,N,P)
        S_chunk = jnp.concatenate([s0, S_chunk], axis=1)
        chunk_decay = jnp.concatenate(
            [jnp.ones((Bt, 1, H), f32), chunk_decay], axis=1)

    def combine(a, b):
        da, sa = a
        db, sb = b
        return da * db, sa * db[..., None, None] + sb

    d_sc, s_sc = jax.lax.associative_scan(
        combine, (chunk_decay, S_chunk), axis=1)
    # state entering chunk c = scanned state of chunk c-1
    if initial_state is not None:
        states_in = s_sc[:, :-1] if nc > 0 else s_sc[:, :0]
        states_in = states_in[:, -nc:] if nc > 0 else states_in
        final_state = s_sc[:, -1]
    else:
        zero = jnp.zeros_like(S_chunk[:, :1])
        states_in = jnp.concatenate([zero, s_sc[:, :-1]], axis=1)
        final_state = s_sc[:, -1]

    # --- inter-chunk output: y += (C_q . state_in) * exp(cum_q) ---
    decay_from_start = jnp.exp(cum)                        # (Bt,nc,Q,H)
    y_inter = jnp.einsum("bcqn,bchnp->bcqhp", Cc, states_in)
    y_inter = y_inter * decay_from_start[..., None]
    y = (y_intra + y_inter).reshape(Bt, S, H, P)[:, :S0]
    return y.astype(x.dtype), jnp.swapaxes(final_state, -1, -2)  # (Bt,H,P,N)


def ssm_block_fwd(cfg, p, x, *, conv_state=None, ssm_state=None):
    """Full-sequence forward. x: (B, S, D). Returns (y, (conv_state, ssm_state))."""
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = rms_norm(p["norm"], x, cfg.norm_eps)
    zxbcdt = constrain(h @ p["in_proj"], "ffh")
    z, xs, B, C, dt = _split_in_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xs, B, C], axis=-1)
    conv_out, new_conv_state = causal_conv1d(p["conv_w"], conv_in, conv_state)
    conv_out = constrain(jax.nn.silu(conv_out), "ffh")
    xs = conv_out[..., :di].reshape(*x.shape[:2], H, P)
    B = conv_out[..., di:di + N]
    C = conv_out[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, new_ssm_state = ssd_chunked(xs, dt, A, B, C, cfg.ssm_chunk,
                                   initial_state=ssm_state)
    y = y + p["D_skip"].astype(y.dtype)[:, None] * xs
    y = y.reshape(*x.shape[:2], di)
    y = rms_norm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"]
    return constrain(x + out, "act"), (new_conv_state, new_ssm_state)


def ssm_block_step(cfg, p, x_t, conv_state, ssm_state):
    """Single-token decode. x_t: (B, D); states from prefill."""
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = rms_norm(p["norm"], x_t, cfg.norm_eps)
    zxbcdt = h @ p["in_proj"]
    z, xs, B, C, dt = _split_in_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xs, B, C], axis=-1)
    conv_out, new_conv_state = causal_conv1d_step(p["conv_w"], conv_in, conv_state)
    conv_out = jax.nn.silu(conv_out)
    xs = conv_out[..., :di].reshape(-1, H, P)
    B = conv_out[..., di:di + N].astype(jnp.float32)
    C = conv_out[..., di + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                          # (B,H)
    # h_new = dA * h + dt * B (outer) x
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, B, xs.astype(jnp.float32))
    new_state = ssm_state * dA[..., None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", C, new_state).astype(x_t.dtype)
    y = y + p["D_skip"].astype(y.dtype)[:, None] * xs
    y = y.reshape(-1, di)
    y = rms_norm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return x_t + y @ p["out_proj"], (new_conv_state, new_state)
