"""RG-LRU recurrent block (Griffin / RecurrentGemma) — pure JAX
[arXiv:2402.19427].

Recurrence:  a_t = exp(-c * softplus(Lambda) * r_t),
             h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
with r_t, i_t sigmoid gates.  Full-sequence form uses a log-space
associative scan (TPU-native: log-depth, no serial loop); decode is O(1).

The surrounding residual block is Griffin's: conv1d front, gated output
branch, then a GeGLU MLP (built in transformer.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models.layers import causal_conv1d, causal_conv1d_step, dense_init

C_CONST = 8.0


def init_rec_block(key, cfg, dtype) -> Dict:
    D = cfg.d_model
    W = cfg.lru_width or D
    ks = jax.random.split(key, 6)
    return {
        "w_gate_branch": dense_init(ks[0], (D, W), dtype),
        "w_x_branch": dense_init(ks[1], (D, W), dtype),
        "conv_w": dense_init(ks[2], (cfg.ssm_conv, W), dtype, scale=0.5),
        "w_rec_gate": dense_init(ks[3], (W, W), dtype),
        "w_in_gate": dense_init(ks[4], (W, W), dtype),
        # Lambda init so that a ~ Uniform(0.9, 0.999) at r=1 (Griffin A.2)
        "Lambda": jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, W)) / C_CONST)).astype(jnp.float32),
        "w_out": dense_init(ks[5], (W, D), dtype),
    }


def _gates(p, x):
    """log(a_t) and gated input. x: (..., W) conv output (f32)."""
    r = jax.nn.sigmoid(x @ p["w_rec_gate"].astype(jnp.float32))
    i = jax.nn.sigmoid(x @ p["w_in_gate"].astype(jnp.float32))
    log_a = -C_CONST * jax.nn.softplus(p["Lambda"]) * r       # (..., W) <= 0
    a2 = jnp.exp(2.0 * log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * x)
    return log_a, gated_x


def rglru_scan(log_a, bx, h0: Optional[jnp.ndarray] = None):
    """h_t = exp(log_a_t) * h_{t-1} + bx_t via associative scan over axis 1.

    log_a, bx: (B, S, W) float32. h0: (B, W) or None.
    Returns (h_seq: (B, S, W), h_last: (B, W)).
    """
    if h0 is not None:
        # fold h0 in as a virtual step with a=1
        log_a = jnp.concatenate([jnp.zeros_like(log_a[:, :1]), log_a], axis=1)
        bx = jnp.concatenate([h0[:, None, :], bx], axis=1)

    def combine(c1, c2):
        la1, b1 = c1
        la2, b2 = c2
        return la1 + la2, b1 * jnp.exp(la2) + b2

    _, h = jax.lax.associative_scan(combine, (log_a, bx), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h, h[:, -1]


def rec_block_fwd(cfg, p, x, *, conv_state=None, h0=None):
    """Temporal-mixing branch of a Griffin recurrent block.

    x: (B, S, D) (already layer-normed by the caller).
    Returns (y: (B, S, D), (conv_state, h_last)).
    """
    gate = constrain(jax.nn.gelu(x @ p["w_gate_branch"]), "ffh")
    u = constrain(x @ p["w_x_branch"], "ffh")
    u, new_conv_state = causal_conv1d(p["conv_w"], u, conv_state)
    uf = u.astype(jnp.float32)
    log_a, bx = _gates(p, uf)
    h, h_last = rglru_scan(log_a, bx, h0)
    h = constrain(h, "ffh")
    y = (h.astype(x.dtype) * gate) @ p["w_out"]
    return y, (new_conv_state, h_last)


def rec_block_step(cfg, p, x_t, conv_state, h):
    """Single-token decode. x_t: (B, D); h: (B, W) f32."""
    gate = jax.nn.gelu(x_t @ p["w_gate_branch"])
    u = x_t @ p["w_x_branch"]
    u, new_conv_state = causal_conv1d_step(p["conv_w"], u, conv_state)
    uf = u.astype(jnp.float32)
    log_a, bx = _gates(p, uf)
    h_new = jnp.exp(log_a) * h + bx
    y = (h_new.astype(x_t.dtype) * gate) @ p["w_out"]
    return y, (new_conv_state, h_new)
