"""Core pure-JAX layers: norms, rotary embeddings (incl. M-RoPE), MLPs.

All parameters are plain pytrees (nested dicts of jnp arrays); every layer is
a pure function ``f(params, x, ...)``.  Initializers take an explicit key.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (what most public LMs ship with)."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(w, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(dt)


def layer_norm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE / M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Standard RoPE. x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                     # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections: Tuple[int, ...]) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL): the rotary half-dims are split into
    (t, h, w) sections, each rotated by its own position stream.

    x: (B, S, H, hd); positions: (B, S, 3) int32 — per-token (t, h, w) ids
    produced by the (stubbed) vision frontend; text tokens carry t=h=w.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    inv = rope_freqs(hd, theta)                     # (hd/2,)
    # section id for every rotary frequency slot
    sec_id = jnp.concatenate([jnp.full((s,), i, dtype=jnp.int32)
                              for i, s in enumerate(sections)])
    pos = positions.astype(jnp.float32)             # (B, S, 3)
    # gather the per-slot position stream: (B, S, hd/2)
    pos_per_slot = jnp.take(pos, sec_id, axis=-1)
    ang = pos_per_slot * inv                        # (B, S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                # (B, S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
}


def init_mlp(key, d_model: int, d_ff: int, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def mlp(params, x, act: str = "silu"):
    """Gated (SwiGLU-family) MLP: down( act(x@gate) * (x@up) )."""
    a = _ACTS[act]
    h = a(x @ params["w_gate"]) * (x @ params["w_up"])
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (mamba2 / RG-LRU front conv)
# ---------------------------------------------------------------------------

def causal_conv1d(w, x, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv along time.

    w: (K, C); x: (B, S, C); state: (B, K-1, C) carry of previous inputs.
    Returns (y, new_state) with y: (B, S, C), new_state: (B, K-1, C).
    """
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)        # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    new_state = xp[:, x.shape[1]:, :] if K > 1 else state
    return y, new_state


def causal_conv1d_step(w, x_t, state):
    """Single decode step. x_t: (B, C); state: (B, K-1, C)."""
    K = w.shape[0]
    window = jnp.concatenate([state, x_t[:, None, :]], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", window, w)
    return y, window[:, 1:, :]
