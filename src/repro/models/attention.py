"""Memory-efficient pure-JAX attention (the XLA-lowered path).

This is the implementation the distributed steps lower through.  It never
materializes the full (Sq, Sk) score matrix: queries are processed in blocks
and keys are scanned in blocks with online-softmax rescaling (flash-style),
so compiled HBM use stays O(S * d) even at 32k/524k sequence lengths.

The Pallas kernels in ``repro.kernels`` implement the same math as explicit
VMEM-tiled TPU kernels; ``repro.kernels.*.ref`` oracles cross-check both.

Partial-attention form (acc, m, l) is exposed so ring attention
(context-parallel prefill) and sequence-parallel decode can merge partials
across devices.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# Pipeline-mode stages see the full (unsharded) sequence and opt into
# sequential q-block chunking to bound peak memory; the standard
# sequence-sharded path keeps q un-chunked (reshape would fight SPMD).
DEFAULT_BLOCK_Q = [0]


class default_block_q:
    def __init__(self, n: int):
        self.n = n

    def __enter__(self):
        self.prev = DEFAULT_BLOCK_Q[0]
        DEFAULT_BLOCK_Q[0] = self.n

    def __exit__(self, *exc):
        DEFAULT_BLOCK_Q[0] = self.prev


class AttnPartial(NamedTuple):
    acc: jnp.ndarray  # (B, Sq, Hq, hd) un-normalized weighted values (f32)
    m: jnp.ndarray    # (B, Sq, Hq) running max of logits (f32)
    l: jnp.ndarray    # (B, Sq, Hq) running sum of exp(logit - m) (f32)


def merge_partials(a: AttnPartial, b: AttnPartial) -> AttnPartial:
    """Associative merge of two online-softmax partial results."""
    m = jnp.maximum(a.m, b.m)
    ea = jnp.exp(a.m - m)
    eb = jnp.exp(b.m - m)
    acc = a.acc * ea[..., None] + b.acc * eb[..., None]
    l = a.l * ea + b.l * eb
    return AttnPartial(acc, m, l)


def finalize_partial(p: AttnPartial, dtype) -> jnp.ndarray:
    l = jnp.where(p.l == 0.0, 1.0, p.l)
    return (p.acc / l[..., None]).astype(dtype)


def _block_mask(q_pos, k_pos, *, causal: bool, window: int):
    """(Bq, Bk) bool mask: True = attend."""
    dq = q_pos[:, None]
    dk = k_pos[None, :]
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok = ok & (dk <= dq)
    if window > 0:
        ok = ok & (dk > dq - window)
    return ok


def attention_partial(
    q: jnp.ndarray,            # (B, Sq, Hq, hd)
    k: jnp.ndarray,            # (B, Sk, Hkv, hd)
    v: jnp.ndarray,            # (B, Sk, Hkv, hd)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset=0,                # global position of q[0] (int or traced scalar)
    k_offset=0,                # global position of k[0]
    kv_valid_len=None,         # mask k positions >= this (ragged caches)
    kv_slot_mask=None,         # (B, Sk) bool per-slot mask (ring buffers:
                               # validity is per slot, not a prefix length)
    block_k: int = 1024,
    block_q: int = 0,          # opt-in (pipeline full-seq stages): 0 = off —
                               # reshaping a sequence-sharded q breaks SPMD
    scale: Optional[float] = None,
) -> AttnPartial:
    """Blocked online-softmax attention returning mergeable partials.

    GQA: Hq must be a multiple of Hkv; query heads are grouped onto kv heads.
    Long query runs are additionally chunked over ``block_q`` (sequentially,
    via lax.map) so peak memory stays O(block_q * block_k) per head.
    """
    B, Sq, Hq, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = scale if scale is not None else hd ** -0.5

    if block_q and Sq > 2 * block_q and Sq % block_q == 0:
        nq = Sq // block_q
        qb = jnp.moveaxis(q.reshape(B, nq, block_q, Hq, hd), 1, 0)

        def one(args):
            qblk, i = args
            return attention_partial(
                qblk, k, v, causal=causal, window=window,
                q_offset=q_offset + i * block_q, k_offset=k_offset,
                kv_valid_len=kv_valid_len, kv_slot_mask=kv_slot_mask,
                block_k=block_k, block_q=0, scale=scale)

        parts = jax.lax.map(one, (qb, jnp.arange(nq)))
        acc = jnp.moveaxis(parts.acc, 0, 1).reshape(B, Sq, Hq, hd)
        m = jnp.moveaxis(parts.m, 0, 1).reshape(B, Sq, Hq)
        l = jnp.moveaxis(parts.l, 0, 1).reshape(B, Sq, Hq)
        return AttnPartial(acc, m, l)

    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, G, hd)
    q_pos = q_offset + jnp.arange(Sq)

    nk = max(1, (Sk + block_k - 1) // block_k)
    block_k = (Sk + nk - 1) // nk
    pad_k = nk * block_k - Sk

    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    kb = kp.reshape(B, nk, block_k, Hkv, hd)
    vb = vp.reshape(B, nk, block_k, Hkv, hd)
    if kv_slot_mask is not None:
        smp = jnp.pad(jnp.asarray(kv_slot_mask, bool), ((0, 0), (0, pad_k)))
        smb = jnp.moveaxis(smp.reshape(B, nk, block_k), 1, 0)  # (nk, B, bk)

    def step(carry, blk):
        acc, m, l = carry
        if kv_slot_mask is not None:
            kblk, vblk, kidx, sblk = blk            # ... + (B, bk) slot mask
        else:
            kblk, vblk, kidx = blk                  # (B,bk,Hkv,hd) x2, ()
        k_pos = k_offset + kidx * block_k + jnp.arange(block_k)
        # logits: (B, Sq, Hkv, G, bk)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qf, kblk.astype(jnp.float32))
        mask = _block_mask(q_pos, k_pos, causal=causal, window=window)
        mask = mask & (k_pos < (Sk + k_offset))[None, :]  # kill pad keys
        mask = mask[None, :, None, None, :]               # (1,Sq,1,1,bk)
        if kv_valid_len is not None:
            vl = jnp.asarray(kv_valid_len)
            if vl.ndim == 0:
                mask = mask & (k_pos < vl)[None, None, None, None, :]
            else:  # per-batch valid lengths (continuous batching)
                mask = mask & (k_pos[None, :] < vl[:, None]
                               )[:, None, None, None, :]
        if kv_slot_mask is not None:
            mask = mask & sblk[:, None, None, None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)                 # (B,Sq,Hkv,G)
        m_new = jnp.maximum(m, m_blk)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgc,bckd->bqkgd", p, vblk.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Sq, Hkv, G, hd), jnp.float32)
    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    kb_t = jnp.moveaxis(kb, 1, 0)                   # (nk, B, bk, Hkv, hd)
    vb_t = jnp.moveaxis(vb, 1, 0)
    xs = (kb_t, vb_t, jnp.arange(nk))
    if kv_slot_mask is not None:
        xs = xs + (smb,)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), xs)
    return AttnPartial(acc.reshape(B, Sq, Hq, hd),
                       m.reshape(B, Sq, Hq), l.reshape(B, Sq, Hq))


def attention(q, k, v, *, causal=True, window=0, q_offset=0, k_offset=0,
              kv_valid_len=None, block_k: int = 1024,
              block_q: Optional[int] = None,
              scale: Optional[float] = None) -> jnp.ndarray:
    """Full attention = finalize(partial). Shapes as attention_partial.

    Long query runs finalize per q-block inside the sequential map, so the
    live intermediates are one block's f32 partials — not the whole
    sequence's (peak-memory critical for the full-seq pipeline stages)."""
    B, Sq, Hq, hd = q.shape
    if block_q is None:
        block_q = DEFAULT_BLOCK_Q[0]
    if block_q and Sq > 2 * block_q and Sq % block_q == 0:
        nq = Sq // block_q
        qb = jnp.moveaxis(q.reshape(B, nq, block_q, Hq, hd), 1, 0)

        def one(args):
            qblk, i = args
            p = attention_partial(qblk, k, v, causal=causal, window=window,
                                  q_offset=q_offset + i * block_q,
                                  k_offset=k_offset,
                                  kv_valid_len=kv_valid_len,
                                  block_k=block_k, block_q=0, scale=scale)
            return finalize_partial(p, q.dtype)

        out = jax.lax.map(one, (qb, jnp.arange(nq)))
        return jnp.moveaxis(out, 0, 1).reshape(B, Sq, Hq, hd)
    p = attention_partial(q, k, v, causal=causal, window=window,
                          q_offset=q_offset, k_offset=k_offset,
                          kv_valid_len=kv_valid_len, block_k=block_k,
                          block_q=0, scale=scale)
    return finalize_partial(p, q.dtype)


def attention_reference(q, k, v, *, causal=True, window=0, q_offset=0,
                        k_offset=0, kv_valid_len=None,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """O(S^2)-memory oracle used only by tests (small shapes)."""
    B, Sq, Hq, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else hd ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, hd) * scale
    s = jnp.einsum("bqkgd,bckd->bqkgc", qf, k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = k_offset + jnp.arange(Sk)
    mask = _block_mask(q_pos, k_pos, causal=causal, window=window)
    mask = mask[None, :, None, None, :]
    if kv_valid_len is not None:
        vl = jnp.asarray(kv_valid_len)
        if vl.ndim == 0:
            mask = mask & (k_pos < vl)[None, None, None, None, :]
        else:
            mask = mask & (k_pos[None, :] < vl[:, None])[:, None, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)
    o = jnp.einsum("bqkgc,bckd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, hd).astype(q.dtype)


# Decode-attention backend: "auto" routes to the Pallas flash-decode kernel
# (kernels/decode_attention.py, split-K over the cache with per-slot lens
# prefetched as scalars) on TPU and to the XLA online-softmax path
# elsewhere; kernels/ref.py is the shared oracle for both.  The choice is
# made at trace time, so tests forcing an impl must trace inside the
# context manager (plain eager calls do).
DECODE_ATTN_IMPL = ["auto"]      # "auto" | "pallas" | "xla"


class decode_attn_impl:
    """Context manager pinning the decode-attention backend (tests/bench)."""

    def __init__(self, impl: str):
        assert impl in ("auto", "pallas", "xla"), impl
        self.impl = impl

    def __enter__(self):
        self.prev = DECODE_ATTN_IMPL[0]
        DECODE_ATTN_IMPL[0] = self.impl

    def __exit__(self, *exc):
        DECODE_ATTN_IMPL[0] = self.prev


def _use_pallas_decode() -> bool:
    impl = DECODE_ATTN_IMPL[0]
    if impl == "pallas":
        return True
    if impl == "xla":
        return False
    return jax.default_backend() == "tpu"


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     scale: Optional[float] = None) -> jnp.ndarray:
    """One-token attention against a (possibly ring-buffered) KV cache.

    q: (B, 1, Hq, hd); k/v_cache: (B, C, Hkv, hd); cache_len: () or (B,)
    int32 — valid entries.  With ``window`` > 0 the cache is a ring buffer
    of capacity C == window (positions are irrelevant: softmax is
    permutation-invariant and RoPE was applied before caching).
    """
    if scale is None and _use_pallas_decode():
        from repro.kernels import ops
        B = q.shape[0]
        lens = jnp.broadcast_to(
            jnp.asarray(cache_len, jnp.int32).reshape(-1), (B,))
        return ops.decode_attention(q, k_cache, v_cache, lens)
    p = attention_partial(q, k_cache, v_cache, causal=False, window=0,
                          kv_valid_len=cache_len, block_k=k_cache.shape[1],
                          scale=scale)
    return finalize_partial(p, q.dtype)


def decode_attention_merged(q, k_cache, v_cache, cache_len, k_new, v_new, *,
                            kv_slot_mask=None,
                            scale: Optional[float] = None) -> jnp.ndarray:
    """Zero-copy decode attention: the current token's K/V are merged as an
    online-softmax partial instead of being written into the cache first.

    q: (B, 1, Hq, hd); k/v_cache: (B, C, Hkv, hd) — *without* the current
    token; cache_len: () or (B,) valid old entries; k/v_new: (B, 1, Hkv, hd)
    the current token.  Equivalent to writing k/v_new at position
    ``cache_len`` and attending over ``cache_len + 1`` entries, but the
    cache is only read — the single-row write happens once, outside the
    layer scan, on the donated cache (see transformer.decode_step).

    ``kv_slot_mask`` (B, C) bool extends the zero-copy trick to ring-
    buffered (windowed) caches: slot validity there is not a prefix length
    (the slot the new token will overwrite holds the evicted, out-of-window
    entry and must not be attended).  The mask rides the Pallas kernel's
    split-K blocking too, so the windowed path no longer pins to the XLA
    lowering.
    """
    if scale is None and _use_pallas_decode():
        from repro.kernels import ops
        B = q.shape[0]
        lens = jnp.broadcast_to(
            jnp.asarray(cache_len, jnp.int32).reshape(-1), (B,))
        return ops.decode_attention(q, k_cache, v_cache, lens,
                                    k_new=k_new, v_new=v_new,
                                    slot_mask=kv_slot_mask)
    p_old = attention_partial(q, k_cache, v_cache, causal=False, window=0,
                              kv_valid_len=cache_len,
                              kv_slot_mask=kv_slot_mask,
                              block_k=k_cache.shape[1], scale=scale)
    p_new = attention_partial(q, k_new, v_new, causal=False, window=0,
                              block_k=1, scale=scale)
    return finalize_partial(merge_partials(p_old, p_new), q.dtype)
