"""Mixture-of-Experts FFN — shared + routed top-k, capacity-based dense
dispatch (GShard-style), pure JAX.

TPU adaptation (DESIGN.md §2): dispatch/combine are dense einsums over a
capacity-bounded (T, E, C) tensor — MXU-friendly, no data-dependent shapes —
instead of a GPU-style scatter/grouped-GEMM.  Expert weights are stacked
(E, ...) so they shard like any other tensor; expert-parallel all-to-all is
an optional optimization lever (see EXPERIMENTS.md §Perf), not a
correctness requirement.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models.layers import dense_init


def init_moe_mlp(key, cfg, dtype) -> Dict:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 8)
    p = {
        "router": dense_init(ks[0], (D, E), dtype, scale=0.02),
        "w_gate": dense_init(ks[1], (E, D, F), dtype),
        "w_up": dense_init(ks[2], (E, D, F), dtype),
        "w_down": dense_init(ks[3], (E, F, D), dtype),
    }
    if cfg.n_shared_experts:
        S = cfg.n_shared_experts
        p["shared"] = {
            "w_gate": dense_init(ks[4], (S, D, F), dtype),
            "w_up": dense_init(ks[5], (S, D, F), dtype),
            "w_down": dense_init(ks[6], (S, F, D), dtype),
            "gate": dense_init(ks[7], (D, 1), dtype, scale=0.02),
        }
    return p


def moe_capacity(n_tokens: int, cfg) -> int:
    cap = int(math.ceil(cfg.capacity_factor * n_tokens * cfg.top_k
                        / cfg.n_experts))
    return max(8, ((cap + 7) // 8) * 8)  # pad to VPU-friendly multiple


def _token_groups(T: int) -> int:
    """Token groups = dp x seq shards (from the active sharding policy), so
    routing, capacity and dispatch stay device-local at scale.  1 (global
    routing) when undistributed."""
    from repro.distributed.context import get_policy
    pol = get_policy()
    if pol is None:
        return 1
    g = pol.token_groups
    return g if (g > 1 and T % g == 0) else 1


def moe_mlp(cfg, p, x, act, *, dropless: bool = False,
            capacity_factor: float = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (y: (B, S, D), aux_loss: scalar).

    Routing: softmax -> top-k -> renormalize (Qwen/Mixtral convention).
    Tokens are routed within per-device groups (GShard per-group capacity);
    tokens over an expert's local capacity are dropped (their routed
    contribution is zero; shared experts and the residual still serve them).

    ``dropless=True`` (decode path): every expert runs on every token and
    the top-k mask selects — exact, and nearly free at decode because the
    step is bound by reading the expert weights regardless.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    if dropless:
        return _moe_dropless(cfg, p, x, act)
    G = _token_groups(T)
    Tg = T // G
    import dataclasses as _dc
    cfg_cap = cfg if capacity_factor is None else         _dc.replace(cfg, capacity_factor=capacity_factor)
    C = moe_capacity(Tg, cfg_cap)
    xt = constrain(x.reshape(T, D), "tok")
    xg = xt.reshape(G, Tg, D)                                # dim0 sharded

    logits = (xg @ p["router"]).astype(jnp.float32)          # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                   # (G, Tg, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # --- per-group capacity assignment: position of each (token, choice)
    # within its expert's local queue, in token order ----------------------
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)     # (G, Tg, K, E)
    flat = onehot.reshape(G, Tg * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                    # (G, Tg*K, E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(G, Tg, K)
    keep = (pos < C)
    pos = jnp.where(keep, pos, 0).astype(jnp.int32)

    # dispatch: (G, Tg, K, E, C) -> reduce K -> (G, Tg, E, C)
    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32)       # (G, Tg, K, C)
    disp = jnp.einsum("gtke,gtkc->gtec",
                      onehot * keep[..., None], pos_oh)
    comb = jnp.einsum("gtke,gtkc->gtec",
                      onehot * (top_p * keep)[..., None], pos_oh)

    xin = jnp.einsum("gtec,gtd->gecd", disp.astype(x.dtype), xg)
    h = act(jnp.einsum("gecd,edf->gecf", xin, p["w_gate"])) * \
        jnp.einsum("gecd,edf->gecf", xin, p["w_up"])
    eout = jnp.einsum("gecf,efd->gecd", h, p["w_down"])      # (G, E, C, D)
    y = jnp.einsum("gtec,gecd->gtd", comb.astype(x.dtype), eout)
    y = constrain(y.reshape(T, D), "tok")

    # --- shared experts (always-on) ---------------------------------------
    if "shared" in p:
        sp = p["shared"]
        hs = act(jnp.einsum("td,sdf->tsf", xt, sp["w_gate"])) * \
             jnp.einsum("td,sdf->tsf", xt, sp["w_up"])
        ys = jnp.einsum("tsf,sfd->td", hs, sp["w_down"])
        sg = jax.nn.sigmoid((xt @ sp["gate"]).astype(jnp.float32))
        y = y + ys * sg.astype(y.dtype)

    # --- load-balance aux loss (Switch-style) ------------------------------
    frac_tokens = jnp.mean(onehot.sum(2), axis=(0, 1))       # (E,)
    frac_probs = jnp.mean(probs, axis=(0, 1))                # (E,)
    aux = E * jnp.sum(frac_tokens * frac_probs) / K
    return y.reshape(B, S, D), aux.astype(jnp.float32)


def _moe_dropless(cfg, p, x, act) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact top-k MoE: all experts on all tokens, masked combine."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, D)
    logits = (xt @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    w = jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32)
                * top_p[..., None], axis=1)               # (T, E)
    h = act(jnp.einsum("td,edf->tef", xt, p["w_gate"])) *         jnp.einsum("td,edf->tef", xt, p["w_up"])
    eout = jnp.einsum("tef,efd->ted", h, p["w_down"])     # (T, E, D)
    y = jnp.einsum("te,ted->td", w.astype(x.dtype), eout)
    if "shared" in p:
        sp = p["shared"]
        hs = act(jnp.einsum("td,sdf->tsf", xt, sp["w_gate"])) *              jnp.einsum("td,sdf->tsf", xt, sp["w_up"])
        ys = jnp.einsum("tsf,sfd->td", hs, sp["w_down"])
        sg = jax.nn.sigmoid((xt @ sp["gate"]).astype(jnp.float32))
        y = y + ys * sg.astype(y.dtype)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_e, E, dtype=jnp.float32).sum(1), axis=0)
    aux = E * jnp.sum(frac_tokens * jnp.mean(probs, axis=0)) / K
    return y.reshape(B, S, D), aux.astype(jnp.float32)
