"""Pipeline-parallel model-loading planner (paper §4.2, §4.4.1).

The planner is pure algorithm — no JAX — and is the heart of PipeBoost:

* ``make_segments``       — partition L layers into N contiguous segments with
                            balanced byte sizes (homogeneous devices).
* ``rotated_load_order``  — device *i* loads segments ``i, i+1, …, i-1`` so
                            the union of first-loads covers the model after
                            each device transfers only 1/N of the bytes
                            (paper Fig. 2c).
* ``reassign``            — failure recovery: re-partition the segment ring
                            over survivors obeying the paper's two principles
                            (Load Balance, Layer Contiguity), reusing what is
                            already on each device (paper §4.4.2, Fig. 7a).
* ``viable_chain``        — find a pipeline chain over the currently loaded
                            segments (used to decide whether inference can
                            continue after a crash without re-loading).

See ``docs/ARCHITECTURE.md`` § "Core: the PipeBoost engine".
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class Segment:
    """A contiguous run of model layers (plus optional head/tail extras)."""
    idx: int
    layer_start: int
    layer_end: int           # exclusive
    bytes: int

    @property
    def n_layers(self) -> int:
        return self.layer_end - self.layer_start


@dataclass
class LoadPlan:
    """Per-device ordered segment loading schedule."""
    segments: List[Segment]
    order: Dict[int, List[int]]          # device -> segment idx order
    serve_assignment: Dict[int, List[int]]  # device -> segments it serves in
                                            # the initial pipeline chain

    @property
    def n_devices(self) -> int:
        return len(self.order)


def make_segments(layer_bytes: Sequence[int], n_segments: int) -> List[Segment]:
    """Balanced contiguous partition of layers into segments.

    Greedy sweep targeting equal cumulative bytes; always yields exactly
    ``n_segments`` non-empty segments (requires L >= n_segments).
    """
    L = len(layer_bytes)
    if L < n_segments:
        raise ValueError(f"{L} layers < {n_segments} segments")
    total = sum(layer_bytes)
    segments: List[Segment] = []
    start = 0
    acc = 0
    for s in range(n_segments):
        remaining_segs = n_segments - s
        remaining_layers = L - start
        target = (total - acc) / remaining_segs
        end = start
        seg_bytes = 0
        # must leave at least 1 layer per remaining segment
        max_end = L - (remaining_segs - 1)
        while end < max_end:
            nxt = seg_bytes + layer_bytes[end]
            # take the layer if we are under target or taking it is closer
            if seg_bytes > 0 and abs(nxt - target) > abs(seg_bytes - target):
                break
            seg_bytes = nxt
            end += 1
        if end == start:  # always take at least one layer
            seg_bytes = layer_bytes[start]
            end = start + 1
        segments.append(Segment(s, start, end, seg_bytes))
        acc += seg_bytes
        start = end
    assert start == L
    return segments


def rotated_load_order(n_devices: int, n_segments: Optional[int] = None
                       ) -> Dict[int, List[int]]:
    """Device i loads segments [i, i+1, ..., i-1] (mod N) — paper Fig. 2c."""
    n_segments = n_segments or n_devices
    assert n_segments % n_devices == 0, (n_segments, n_devices)
    per = n_segments // n_devices
    out = {}
    for d in range(n_devices):
        first = d * per
        out[d] = [(first + j) % n_segments for j in range(n_segments)]
    return out


def make_plan(layer_bytes: Sequence[int], n_devices: int,
              n_segments: Optional[int] = None) -> LoadPlan:
    n_segments = n_segments or n_devices
    segs = make_segments(layer_bytes, n_segments)
    order = rotated_load_order(n_devices, n_segments)
    per = n_segments // n_devices
    serve = {d: list(range(d * per, (d + 1) * per)) for d in range(n_devices)}
    return LoadPlan(segs, order, serve)


# ---------------------------------------------------------------------------
# Recovery (paper §4.4.2)
# ---------------------------------------------------------------------------

def _contiguous_spans(n_segments: int, n_parts: int) -> List[List[int]]:
    """Split segment ids 0..n-1 into n_parts contiguous spans, sizes
    differing by at most 1 (Load Balance + Layer Contiguity)."""
    base = n_segments // n_parts
    rem = n_segments % n_parts
    spans = []
    start = 0
    for p in range(n_parts):
        size = base + (1 if p < rem else 0)
        spans.append(list(range(start, start + size)))
        start += size
    return spans


def reassign(plan: LoadPlan, loaded: Dict[int, Sequence[int]],
             survivors: Sequence[int]) -> LoadPlan:
    """Re-plan after failures.

    ``loaded``: device -> segment ids already resident (survivors only are
    consulted).  Survivors (sorted by device id) receive contiguous spans of
    the segment ring; each survivor's new load order puts its still-missing
    span segments first (in pipeline order), then the remaining segments
    (background fill), preserving already-loaded work.

    Matches the paper's example: devices {0,1,2,3}, crash {1,2} during
    loading with loaded = {0:[0], 3:[3]} -> spans [0,1] / [2,3];
    device 0 keeps order [0,1,...], device 3 loads 2 next (already has 3).
    """
    surv = sorted(survivors)
    n_seg = len(plan.segments)
    spans = _contiguous_spans(n_seg, len(surv))
    # assign spans to survivors maximizing reuse of already-loaded segments:
    # survivors are in ring order, spans are in ring order — try all ring
    # rotations of the span assignment and keep the one with max overlap.
    best = None
    for rot in range(len(surv)):
        overlap = 0
        for j, d in enumerate(surv):
            span = spans[(j + rot) % len(surv)]
            overlap += len(set(span) & set(loaded.get(d, ())))
        if best is None or overlap > best[0]:
            best = (overlap, rot)
    rot = best[1]

    order: Dict[int, List[int]] = {}
    serve: Dict[int, List[int]] = {}
    for j, d in enumerate(surv):
        span = spans[(j + rot) % len(surv)]
        serve[d] = span
        have = set(loaded.get(d, ()))
        missing_span = [s for s in span if s not in have]
        rest = [s for s in range(n_seg)
                if s not in have and s not in missing_span]
        # background fill continues the ring from the end of the span
        tail = span[-1] if span else 0
        rest.sort(key=lambda s: (s - tail) % n_seg)
        order[d] = missing_span + rest
    return LoadPlan(plan.segments, order, serve)


def viable_chain(plan: LoadPlan, loaded: Dict[int, Sequence[int]],
                 survivors: Sequence[int]) -> Optional[List[Tuple[int, int]]]:
    """Find a pipeline chain [(device, segment), ...] covering segments
    0..n-1 in order using only loaded segments on survivors; prefers staying
    on the same device for consecutive segments (Layer Contiguity).
    Returns None if some segment is not loaded anywhere. (paper §4.4.2:
    'scans the GPUs to assess the distribution of loaded model layers and
    identifies a viable chain')."""
    surv = sorted(survivors)
    have: Dict[int, set] = {d: set(loaded.get(d, ())) for d in surv}
    chain: List[Tuple[int, int]] = []
    prev_d: Optional[int] = None
    for s in range(len(plan.segments)):
        owners = [d for d in surv if s in have[d]]
        if not owners:
            return None
        if prev_d in owners:
            d = prev_d  # stay: no inter-device hop
        else:
            # fewest future hops heuristic: owner that also has s+1
            nxt = [d for d in owners if s + 1 in have[d]]
            d = (nxt or owners)[0]
        chain.append((d, s))
        prev_d = d
    return chain


def critical_path_bytes(plan: LoadPlan) -> Dict[int, int]:
    """Bytes each device must transfer before the initial chain is ready."""
    out = {}
    for d, segs in plan.serve_assignment.items():
        out[d] = sum(plan.segments[s].bytes for s in segs)
    return out
