"""KV-cache / recurrent-state reconstruction after a crash (paper §4.4.2).

Given the merged token sequence processed so far (prompt + generated) and a
per-layer "has state" mask, rebuild the missing per-layer caches:

  * attention layers WITH KV: recompute only Q over the full sequence and
    attend against the surviving cache (K/V projections skipped) — exact,
    because cached K/V equal what a recompute would produce;
  * attention layers WITH KV but a *wrapped* ring buffer (windowed cache,
    sequence longer than the window): positions older than the ring were
    evicted, so Q-only reuse can't reproduce their outputs — the layer's
    activations are recomputed in full while the surviving ring is kept;
  * attention layers WITHOUT KV: full prefill for that layer, cache stored;
  * SSM / RG-LRU layers WITHOUT state: full re-scan (there is no per-position
    memo to reuse — see DESIGN.md §5 mamba2 note); layers WITH state above
    the deepest missing layer are left untouched (their state is still valid).

Reconstruction stops at the deepest missing layer: everything above it kept
its state, so the decode queue can resume immediately after
(paper Fig. 7b: decode requests detour through the prefill queue and return).

See ``docs/ARCHITECTURE.md`` § "Core: the PipeBoost engine".
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import mamba2, transformer
from repro.models.transformer import (_apply_mlp, _apply_norm, _project_qkv,
                                      _rope, attn_cache_capacity,
                                      rec_layer_fwd)
from repro.models import moe as moe_lib
from repro.models.layers import _ACTS


def _layer_params(params, kind: str, idx: int):
    return jax.tree.map(lambda a: a[idx], params["blocks"][kind])


def _kind_indices(cfg) -> List[Tuple[str, int, int]]:
    """[(kind, index_within_kind, index_within_attnlike_cache), ...] in
    global layer order.  attn and moe share the 'attn' cache stack."""
    out = []
    per_kind: Dict[str, int] = {}
    attnlike = 0
    for kind in cfg.layer_kinds():
        i = per_kind.get(kind, 0)
        per_kind[kind] = i + 1
        if kind in ("attn", "moe"):
            out.append((kind, i, attnlike))
            attnlike += 1
        else:
            out.append((kind, i, -1))
    return out


def reconstruct_cache(cfg: ArchConfig, params, batch: Dict,
                      cache: Dict, has_state: Sequence[bool],
                      max_len: Optional[int] = None) -> Tuple[Dict, Dict[str, int]]:
    """Rebuild missing per-layer state. ``has_state[i]`` is per *global*
    layer.  Returns (new_cache, stats) where stats counts the work done.

    ``batch`` carries the merged sequence ({"tokens": (B, S)} or embeds).
    The returned cache is exactly equal (up to fp) to a fresh prefill cache.
    """
    x, positions = transformer.embed_tokens(cfg, params, batch)
    B, S = x.shape[:2]
    max_len = max_len or S
    cap = attn_cache_capacity(cfg, max_len)
    kinds = _kind_indices(cfg)
    assert len(has_state) == len(kinds)
    deepest_missing = max((i for i, h in enumerate(has_state) if not h),
                          default=-1)
    stats = {"layers_recomputed": 0, "kv_reused": 0, "full_prefill": 0,
             "window_recompute": 0, "layers_skipped": 0,
             # token-granular work counts (surface in cluster metrics):
             # q_only_tokens  — positions whose K/V were reused (Q recomputed)
             # prefill_tokens — positions run through a full layer forward
             #                  (missing layers AND wrapped-ring recomputes)
             "q_only_tokens": 0, "prefill_tokens": 0}

    new_cache = {k: (dict(v) if isinstance(v, dict) else v)
                 for k, v in cache.items()}
    new_cache["pos"] = jnp.full((B,), S, jnp.int32)

    for gi, (kind, ki, ai) in enumerate(kinds):
        if gi > deepest_missing:
            stats["layers_skipped"] += len(kinds) - gi
            break
        p_l = _layer_params(params, kind, ki)
        if kind in ("attn", "moe"):
            if has_state[gi] and cfg.attn_window > 0 and S > cap:
                # Wrapped ring: positions older than S - cap were evicted,
                # so Q-only reuse cannot reproduce their outputs (a query's
                # window would attend keys that no longer exist).  The
                # surviving ring stays as-is (it IS still exact for
                # decode); the layer's *activations* are recomputed in
                # full so deeper rebuilds see correct inputs.
                x, _, _ = transformer.attn_layer_fwd(cfg, p_l, x, positions)
                stats["window_recompute"] += 1
                stats["prefill_tokens"] += S
            elif has_state[gi]:
                # Q-only recompute against the surviving cache (exact reuse)
                h = _apply_norm(cfg, p_l["ln1"], x)
                q, _, _ = _project_qkv(cfg, p_l, h)
                q = _rope(cfg, q, positions)
                kc = cache["attn"]["k"][ai]
                vc = cache["attn"]["v"][ai]
                if cfg.attn_window > 0:
                    o = _windowed_ring_attention(cfg, q, kc, vc, S)
                else:
                    p = attn_lib.attention_partial(
                        q, kc[:, :S], vc[:, :S], causal=True, window=0)
                    o = attn_lib.finalize_partial(p, q.dtype)
                o = o.reshape(B, S, -1) @ p_l["wo"]
                x = x + o
                h2 = _apply_norm(cfg, p_l["ln2"], x)
                if "router" in p_l["mlp"]:
                    y, _ = moe_lib.moe_mlp(cfg, p_l["mlp"], h2, _ACTS[cfg.act])
                else:
                    y = _apply_mlp(cfg, p_l["mlp"], h2)
                x = x + y
                stats["kv_reused"] += 1
                stats["q_only_tokens"] += S
            else:
                x, kv, _ = transformer.attn_layer_fwd(cfg, p_l, x, positions,
                                                      kv_write=cap)
                new_cache["attn"]["k"] = new_cache["attn"]["k"].at[ai].set(kv[0])
                new_cache["attn"]["v"] = new_cache["attn"]["v"].at[ai].set(kv[1])
                stats["full_prefill"] += 1
                stats["prefill_tokens"] += S
        elif kind == "ssm":
            x, (conv_s, state) = mamba2.ssm_block_fwd(cfg, p_l, x)
            if not has_state[gi]:
                new_cache["ssm"]["conv"] = new_cache["ssm"]["conv"].at[ki].set(conv_s)
                new_cache["ssm"]["state"] = new_cache["ssm"]["state"].at[ki].set(state)
                stats["full_prefill"] += 1
                stats["prefill_tokens"] += S
        elif kind == "rec":
            x, st = rec_layer_fwd(cfg, p_l, x, want_state=True)
            if not has_state[gi]:
                new_cache["rec"]["conv"] = new_cache["rec"]["conv"].at[ki].set(st[0])
                new_cache["rec"]["h"] = new_cache["rec"]["h"].at[ki].set(st[1])
                stats["full_prefill"] += 1
                stats["prefill_tokens"] += S
        stats["layers_recomputed"] += 1
    return new_cache, stats


def _windowed_ring_attention(cfg, q, kc, vc, S):
    """Attention of full-sequence Q against a ring-buffered local cache.

    The ring holds the last ``cap`` (roped) keys in rotated order.  Query at
    global position t may attend to keys with position in (t-window, t].
    We reconstruct each ring slot's global position from S and the slot
    index, then mask per-query.
    """
    B, _, Hq, hd = q.shape
    cap = kc.shape[1]
    S = q.shape[1]
    ring_positions = _ring_slot_positions(S, cap)
    qf = (q.astype(jnp.float32) * hd ** -0.5)
    Hkv = kc.shape[2]
    G = Hq // Hkv
    qf = qf.reshape(B, S, Hkv, G, hd)
    s = jnp.einsum("bqkgd,bckd->bqkgc", qf, kc.astype(jnp.float32))
    q_pos = jnp.arange(S)
    ok = (ring_positions[None, :] <= q_pos[:, None]) & \
         (ring_positions[None, :] > q_pos[:, None] - cfg.attn_window) & \
         (ring_positions[None, :] >= 0)
    s = jnp.where(ok[None, :, None, None, :], s, attn_lib.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(ok[None, :, None, None, :], p, 0.0)
    o = jnp.einsum("bqkgc,bckd->bqkgd", p, vc.astype(jnp.float32))
    return o.reshape(B, S, Hq, hd).astype(q.dtype)


def _ring_slot_positions(S: int, cap: int) -> jnp.ndarray:
    """Global position held by each ring slot after S writes (-1 if empty)."""
    slots = jnp.arange(cap)
    if S >= cap:
        # slot j holds the largest p < S with p % cap == j
        return S - 1 - jnp.mod(jnp.asarray(S - 1) - slots, cap)
    return jnp.where(slots < S, slots, -1)
