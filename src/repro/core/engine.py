"""PipeBoost engine: functional multi-device orchestration of
pipeline-parallel loading, inference during loading, strategy switching,
crash injection and recovery (paper §4.1–§4.4).

This engine executes REAL models (repro.models) over *logical devices* — on
this CPU container the devices are bookkeeping entities (what is loaded
where, whose KV lives where) while compute runs on the host; on a real TPU
slice the same state machine drives per-device `jax.device_put` of segment
shards and the shard_map pipeline in repro/distributed/pipeline.py.  Timing
comes from core/simulator.py; this module owns *correctness*:

  * a request admitted before full load produces EXACTLY the same tokens as
    a fully-loaded model (pipeline math is the same math);
  * a crash + recovery produces the same KV/state as a fresh prefill.

See ``docs/ARCHITECTURE.md`` § "Core: the PipeBoost engine".
"""
from __future__ import annotations

import dataclasses
import threading
import time
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterator, List, Optional, Sequence,
                    Set, Tuple)

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import analytic
from repro.core.kv_reconstruct import reconstruct_cache
from repro.core.planner import (LoadPlan, make_plan, reassign, viable_chain)
from repro.lora.adapters import LoRAAdapter, merge_lora, unmerge_lora
from repro.models import transformer


class EngineError(RuntimeError):
    pass


@dataclass
class DeviceState:
    idx: int
    alive: bool = True
    loaded: Set[int] = field(default_factory=set)      # fully-loaded segments
    kv_segments: Set[int] = field(default_factory=set)  # segments whose KV
                                                         # this device owns


@dataclass
class LoadRound:
    """Accounting for one background-fill round (overlapped cold start)."""
    idx: int
    t_start: float                       # seconds since engine construction
    wall_s: float                        # wall-clock spent inside the round
    bytes: int                           # segment bytes transferred this round
    segments: List[Tuple[int, int]]      # (device, segment) loads
    source: str = "host"                 # "host" fill round or "peer"
                                         # multicast delivery


@dataclass
class EngineStatus:
    ready: bool
    fully_loaded: bool
    strategy: str
    alive: List[int]
    loaded: Dict[int, List[int]]
    chain: Optional[List[Tuple[int, int]]]
    # overlapped cold-start instrumentation (None until the event happened)
    time_to_ready: Optional[float] = None
    time_to_fully_loaded: Optional[float] = None
    loaded_bytes: int = 0
    total_bytes: int = 0
    n_rounds: int = 0


class PipeBoostEngine:
    """State machine + functional inference for one GPU-server analogue."""

    def __init__(self, cfg: ArchConfig, params, n_devices: int,
                 n_segments: Optional[int] = None, max_len: int = 256,
                 adapters: Optional[Dict[str, LoRAAdapter]] = None,
                 segments_per_round: int = 1):
        self.cfg = cfg
        self._full_params = params          # "checkpoint in DRAM"
        self.n_devices = n_devices
        self.n_segments = n_segments
        lb = analytic.layer_bytes_list(cfg)
        self.plan: LoadPlan = make_plan(lb, n_devices, n_segments)
        self.devices = [DeviceState(i) for i in range(n_devices)]
        self.max_len = max_len
        self.strategy = "pipeline"          # -> "single" after switch
        self.adapters = adapters or {}
        self.active_adapter: Optional[str] = None
        self._merged_params = params        # params w/ active adapter merged
        self._cache: Optional[Dict] = None
        self._tokens_seen: Optional[jnp.ndarray] = None
        self.events: List[Tuple[str, Any]] = []
        # overlapped cold start: loading is re-entrant (background thread or
        # generator-stepped) and accounted per round
        self.segments_per_round = max(1, segments_per_round)
        self._load_lock = threading.RLock()
        self._fill_thread: Optional[threading.Thread] = None
        self._fill_stop = threading.Event()
        # remembered so a repartition can hand the fill off to a fresh
        # thread over the new plan (same cadence and budget)
        self._fill_interval_s = 0.0
        self._fill_budget: Optional[int] = None
        self._reset_load_accounting()
        # pipeline (shard_map) prefill path — disabled until enabled
        self._pipe_enabled = False
        self._pipe_requested = False
        self._pipe_mesh = None
        self._pipe_n_stages = 0
        self._pipe_n_micro = 0
        self._pipe_fns: Dict[Tuple[int, int, int], Callable] = {}
        self.prefill_backend_used: Optional[str] = None
        self._prefill_jit = jax.jit(
            lambda p, b: transformer.forward(cfg, p, b, mode="prefill",
                                             max_len=self.max_len))
        self._decode_jit = jax.jit(
            lambda p, t, c: transformer.decode_step(cfg, p, {"tokens": t}, c))

    # ---------------- loading ------------------------------------------------

    def _record_event(self, tag: str, payload: Any) -> None:
        """Append to the event log under the load lock — the background
        fill thread appends ``load`` events concurrently, and a plain
        ``list.append`` race would drop entries."""
        with self._load_lock:
            self.events.append((tag, payload))

    def _reset_load_accounting(self) -> None:
        with self._load_lock:
            self._t0 = time.perf_counter()
            self.rounds: List[LoadRound] = []
            self.time_to_ready: Optional[float] = None
            self.time_to_fully_loaded: Optional[float] = None

    def load_next_segment(self, device: int) -> Optional[int]:
        """Advance device's rotated loading order by one segment."""
        with self._load_lock:
            d = self.devices[device]
            if not d.alive:
                raise EngineError(f"device {device} is dead")
            for s in self.plan.order[device]:
                if s not in d.loaded:
                    d.loaded.add(s)
                    self.events.append(("load", (device, s)))
                    return s
            return None

    def load_round(self, budget: Optional[int] = None) -> Optional[LoadRound]:
        """One loading round across alive devices: each device loads up to
        ``budget`` segments (default: the engine's ``segments_per_round``).
        Safe to call from a background thread concurrently with serving.
        Returns the round's accounting, or None when nothing was left to
        load (a ``LoadRound`` is truthy, so boolean callers still work)."""
        budget = budget if budget is not None else self.segments_per_round
        t0 = time.perf_counter()
        loads: List[Tuple[int, int]] = []
        round_: Optional[LoadRound] = None
        with self._load_lock:
            for d in self.devices:
                if not d.alive:
                    continue
                for _ in range(budget):
                    s = self.load_next_segment(d.idx)
                    if s is None:
                        break
                    loads.append((d.idx, s))
            if loads:
                nbytes = sum(self.plan.segments[s].bytes for _, s in loads)
                round_ = LoadRound(
                    len(self.rounds), t0 - self._t0,
                    time.perf_counter() - t0, nbytes, loads)
                self.rounds.append(round_)
            # stamp the two cold-start milestones the moment they flip
            if self.time_to_ready is None and self.ready:
                self.time_to_ready = time.perf_counter() - self._t0
            if self.time_to_fully_loaded is None and self.fully_loaded:
                self.time_to_fully_loaded = time.perf_counter() - self._t0
        return round_

    def load_segment(self, device: int, segment: int,
                     source: str = "peer") -> Optional[LoadRound]:
        """Load one *specific* segment onto one device, out of the rotated
        host-fill order — the multicast delivery path: a peer finished
        streaming this segment over ICI, so it materialises here without a
        host read.  Records a ``LoadRound`` tagged with ``source`` (peer
        deliveries account separately from host rounds) and stamps the
        ready/fully-loaded milestones exactly like ``load_round``.
        Returns None when the device already held the segment."""
        t0 = time.perf_counter()
        with self._load_lock:
            d = self.devices[device]
            if not d.alive:
                raise EngineError(f"device {device} is dead")
            round_: Optional[LoadRound] = None
            if segment not in d.loaded:
                d.loaded.add(segment)
                self.events.append(("load", (device, segment)))
                round_ = LoadRound(
                    len(self.rounds), t0 - self._t0,
                    time.perf_counter() - t0,
                    self.plan.segments[segment].bytes,
                    [(device, segment)], source)
                self.rounds.append(round_)
            if self.time_to_ready is None and self.ready:
                self.time_to_ready = time.perf_counter() - self._t0
            if self.time_to_fully_loaded is None and self.fully_loaded:
                self.time_to_fully_loaded = time.perf_counter() - self._t0
        return round_

    def peer_loaded_bytes(self) -> int:
        """Bytes that arrived via peer multicast rather than host reads."""
        with self._load_lock:
            return sum(r.bytes for r in self.rounds if r.source == "peer")

    # -- background fill driver (the overlap: loading runs concurrently
    #    with serving ticks instead of load-then-serve sequencing) ----------

    def fill_steps(self, budget: Optional[int] = None) -> Iterator[LoadRound]:
        """Generator-step fill API: yields one ``LoadRound`` of accounting
        per round until the model is fully loaded.  The caller interleaves
        ``next()`` with serving work (discrete-event overlap)."""
        while True:
            round_ = self.load_round(budget)
            if round_ is None:
                return
            yield round_

    def start_fill(self, interval_s: float = 0.0,
                   budget: Optional[int] = None) -> threading.Thread:
        """Start the asynchronous background fill: a daemon thread runs
        ``load_round`` until fully loaded (or ``stop_fill``).  Loading is
        pure host-side bookkeeping + ``device_put`` scheduling, so it
        overlaps with jitted serving steps on the main thread."""
        if self._fill_thread is not None and self._fill_thread.is_alive():
            return self._fill_thread
        self._fill_interval_s = interval_s
        self._fill_budget = budget
        self._fill_stop.clear()

        def _run():
            while not self._fill_stop.is_set():
                if not self.load_round(budget):
                    return
                if interval_s > 0:
                    self._fill_stop.wait(interval_s)

        t = threading.Thread(target=_run, name="pipeboost-fill", daemon=True)
        self._fill_thread = t
        t.start()
        return t

    def stop_fill(self, join: bool = True) -> None:
        self._fill_stop.set()
        if join and self._fill_thread is not None:
            self._fill_thread.join(timeout=30.0)
        self._fill_thread = None

    @property
    def fill_running(self) -> bool:
        return self._fill_thread is not None and self._fill_thread.is_alive()

    def loaded_map(self) -> Dict[int, List[int]]:
        with self._load_lock:
            return {d.idx: sorted(d.loaded) for d in self.devices if d.alive}

    def chain(self) -> Optional[List[Tuple[int, int]]]:
        with self._load_lock:
            return viable_chain(self.plan, self.loaded_map(),
                                [d.idx for d in self.devices if d.alive])

    @property
    def ready(self) -> bool:
        return self.chain() is not None

    def rounds_to_ready(self, budget: Optional[int] = None) -> int:
        """Predicted ``load_round`` calls until a viable chain exists
        (0 when already ready) — the cold-start-progress signal SLO-aware
        dispatch scores warming servers by.

        Pure bookkeeping: simulates the rotated load order on copies of
        the per-device loaded sets, never touching real state.  Returns a
        large sentinel if no amount of loading can complete a chain
        (e.g. every device dead)."""
        budget = budget if budget is not None else self.segments_per_round
        with self._load_lock:
            alive = [d.idx for d in self.devices if d.alive]
            loaded = {d.idx: set(d.loaded) for d in self.devices if d.alive}
            if not alive:
                return 1 << 20
            if viable_chain(self.plan, {i: sorted(s) for i, s in
                                        loaded.items()}, alive) is not None:
                return 0
            n_seg = len(self.plan.segments)
            for rounds in range(1, n_seg + 1):
                for i in alive:
                    todo = [s for s in self.plan.order[i]
                            if s not in loaded[i]][:max(1, budget)]
                    loaded[i].update(todo)
                if viable_chain(self.plan, {i: sorted(s) for i, s in
                                            loaded.items()},
                                alive) is not None:
                    return rounds
            return 1 << 20

    @property
    def fully_loaded(self) -> bool:
        with self._load_lock:
            n = len(self.plan.segments)
            return all(len(d.loaded) == n for d in self.devices if d.alive)

    def loaded_bytes(self) -> int:
        """Bytes resident across alive devices (each device transfers its
        own copy of a segment, so bytes count per device)."""
        with self._load_lock:
            return sum(self.plan.segments[s].bytes
                       for d in self.devices if d.alive for s in d.loaded)

    def total_bytes(self) -> int:
        """Bytes every alive device must eventually hold (fully_loaded)."""
        with self._load_lock:
            model = sum(s.bytes for s in self.plan.segments)
            return model * sum(1 for d in self.devices if d.alive)

    def cold_start_stats(self) -> Dict[str, Any]:
        """Flat cold-start accounting for metrics/benchmarks."""
        with self._load_lock:
            return {
                "time_to_ready": self.time_to_ready,
                "time_to_fully_loaded": self.time_to_fully_loaded,
                "loaded_bytes": self.loaded_bytes(),
                "total_bytes": self.total_bytes(),
                "n_rounds": len(self.rounds),
                "round_bytes": [r.bytes for r in self.rounds],
            }

    def status(self) -> EngineStatus:
        """One consistent snapshot (taken under the load lock, so a fill
        round can't land between the fields)."""
        with self._load_lock:
            return EngineStatus(self.ready, self.fully_loaded, self.strategy,
                                [d.idx for d in self.devices if d.alive],
                                self.loaded_map(), self.chain(),
                                self.time_to_ready,
                                self.time_to_fully_loaded,
                                self.loaded_bytes(), self.total_bytes(),
                                len(self.rounds))

    # ---------------- adapters (merged-LoRA, §4.3.2) -------------------------

    def switch_adapter(self, name: Optional[str]):
        if name == self.active_adapter:
            return
        params = self._full_params
        if name is not None:
            if name not in self.adapters:
                raise EngineError(f"unknown adapter {name!r}")
            params = merge_lora(params, self.adapters[name])
        self.active_adapter = name
        self._merged_params = params
        self._record_event("adapter_switch", name)

    # ---------------- inference ---------------------------------------------

    def _segment_layer_mask(self, segs: Set[int]) -> List[bool]:
        """Per-global-layer: is the layer inside one of ``segs``."""
        mask = [False] * self.cfg.n_layers
        with self._load_lock:        # a repartition may swap self.plan
            for s in segs:
                seg = self.plan.segments[s]
                for i in range(seg.layer_start, seg.layer_end):
                    mask[i] = True
        return mask

    def lost_state_layers(self, device_ids: Sequence[int]) -> List[bool]:
        """Per-global-layer: True if that layer's KV/recurrent state lives
        on one of ``device_ids`` under the current serving assignment.

        Ownership follows the viable pipeline chain (each chained segment's
        KV sits in its device's HBM); with no chain yet, nothing is owned.
        Must be called BEFORE ``crash`` marks the devices dead — the chain
        is computed over alive devices.  This is what lets a partial crash
        reconstruct only the layers that actually died (paper §4.4.2)
        instead of re-prefilling everything.
        """
        dead = set(device_ids)
        ch = self.chain()
        if ch is None:
            return [False] * self.cfg.n_layers
        return self._segment_layer_mask(
            {seg for dev, seg in ch if dev in dead})

    # -- pipeline (shard_map) prefill dispatch ------------------------------

    def enable_pipeline_prefill(self, mesh=None, n_micro: int = 2) -> bool:
        """Opt the TTFT-critical prefill into the shard_map pipeline
        lowering (distributed/pipeline.py): stage *i* runs the layers of
        the segments device *i* has loaded, so the first token computes on
        the partial chain while later segments keep streaming in.

        Auto-sizes the ('data', 'stage') mesh over the visible XLA devices
        when ``mesh`` is None.  Returns False (engine keeps the standard
        lowering) when the backend or architecture can't pipeline: fewer
        than 2 XLA devices, a hybrid layer stack, or an indivisible layer
        count.
        """
        kinds = set(self.cfg.layer_kinds())
        if len(kinds) != 1 or next(iter(kinds)) not in ("attn", "moe", "ssm"):
            return False
        if mesh is None:
            n_xla = len(jax.devices())
            if n_xla < 2:
                return False
            n_stages = 0
            for s in range(min(n_xla, self.cfg.n_layers), 1, -1):
                if self.cfg.n_layers % s == 0 and n_xla % s == 0:
                    n_stages = s
                    break
            if not n_stages:
                return False
            mesh = jax.make_mesh((n_xla // n_stages, n_stages),
                                 ("data", "stage"))
        else:
            n_stages = mesh.shape["stage"]
            if self.cfg.n_layers % n_stages:
                return False
        self._pipe_mesh = mesh
        self._pipe_n_stages = n_stages
        self._pipe_n_micro = max(1, n_micro)
        self._pipe_fns = {}
        self._pipe_enabled = True
        self._pipe_requested = True
        return True

    def _pipeline_fits(self, batch: Dict) -> bool:
        if not self._pipe_enabled or self._pipe_mesh is None \
                or self.strategy != "pipeline":
            return False
        tokens = batch.get("tokens", batch.get("embeds"))
        B = tokens.shape[0]
        n_data = self._pipe_mesh.shape["data"]
        if B % n_data:
            return False
        return (B // n_data) % self._pipe_n_micro == 0

    def _pipeline_prefill_fn(self, B: int, S: int) -> Callable:
        # Keyed by stage count as well as shape: a repartition that moves
        # to a stage count seen before reuses its compiles verbatim, and a
        # NEW stage count costs at most one lowering per shape — compiles
        # scale with distinct stage plans, never with crash events.
        key = (self._pipe_n_stages, B, S)
        if key not in self._pipe_fns:
            from repro.distributed.pipeline import build_pipeline_prefill
            self._pipe_fns[key] = jax.jit(build_pipeline_prefill(
                self.cfg, n_stages=self._pipe_n_stages,
                n_micro=self._pipe_n_micro, mesh=self._pipe_mesh,
                seq_len=S, max_len=self.max_len, return_cache=True))
        return self._pipe_fns[key]

    def serving_pipeline_fits(self, P: int, S: int) -> bool:
        """Shape pre-check for ``serving_pipeline_prefill`` (the batcher's
        dispatch): row count must split over the ('data', 'stage') mesh."""
        if not self._pipe_enabled or self._pipe_mesh is None:
            return False
        n_data = self._pipe_mesh.shape["data"]
        return P % n_data == 0 and (P // n_data) % self._pipe_n_micro == 0

    def serving_pipeline_prefill(self, params, batch: Dict):
        """``ContinuousBatcher.set_pipeline_prefill`` contract: lower an
        admission prefill (right-padded rows + per-row last_index) through
        the shard_map pipeline belt and hand the state back in the
        per-replica layout (committed, so the batcher's donated scatter
        and the fused decode step never retrace)."""
        tokens = batch["tokens"]
        fn = self._pipeline_prefill_fn(tokens.shape[0], tokens.shape[1])
        logits, state = fn(params, batch)
        return jax.device_put((logits, state), jax.devices()[0])

    def prefill(self, batch: Dict) -> jnp.ndarray:
        """Serve a prefill the moment a chain exists (the paper's point:
        this happens after each device loaded only ~1/N of the model).

        While the engine is in pipeline strategy on a multi-device backend
        (``enable_pipeline_prefill``), the prefill lowers through the
        shard_map belt — layers stay stage-sharded exactly like the loaded
        segments — and the returned cache feeds the SAME fused decode jit
        the single lowering uses (identical shapes: no retrace at the
        strategy switch)."""
        chain = self.chain()
        if chain is None:
            raise EngineError("no viable pipeline chain: model not ready")
        if self._pipeline_fits(batch):
            tokens = batch.get("tokens", batch.get("embeds"))
            B, S = tokens.shape[0], tokens.shape[1]
            logits, cache = self._pipeline_fn_call(B, S, batch)
            self.prefill_backend_used = "pipeline"
        else:
            logits, cache = self._prefill_jit(self._merged_params, batch)
            if self._pipe_enabled:
                # keep layouts (and committed-ness, part of the jit cache
                # key) identical to the pipeline hand-off's so alternating
                # backends never retraces the decode step
                logits, cache = jax.device_put((logits, cache),
                                               jax.devices()[0])
            self.prefill_backend_used = "single"
        self._cache = cache
        self._tokens_seen = batch.get("tokens")
        # KV ownership follows the serving chain
        with self._load_lock:
            for d in self.devices:
                d.kv_segments = set()
            for dev, seg in chain:
                self.devices[dev].kv_segments.add(seg)
            self.events.append(("prefill", chain))
            self.events.append(("prefill_backend",
                                self.prefill_backend_used))
        return logits

    def _pipeline_fn_call(self, B: int, S: int, batch: Dict):
        fn = self._pipeline_prefill_fn(B, S)
        logits, state = fn(self._merged_params, batch)
        # Strategy hand-off (§4.3.3): the pipeline leaves KV stage-sharded
        # where each segment's layers live; the per-replica fused decode
        # step owns the whole cache.  One explicit re-lay here keeps the
        # decode jit's input layouts identical to the standard lowering's —
        # the switch moves data once but NEVER retraces.
        cache: Dict[str, Any] = {"pos": jnp.full((B,), S, jnp.int32)}
        cache.update(state)
        # committed-ness is part of the jit cache key, so the whole cache
        # (pos included) must land identically to the standard lowering's
        logits, cache = jax.device_put((logits, cache), jax.devices()[0])
        return logits, cache

    def decode(self, tokens: jnp.ndarray) -> jnp.ndarray:
        if self._cache is None:
            raise EngineError("prefill first")
        if self.strategy == "pipeline" and self.chain() is None:
            raise EngineError("pipeline chain broken — recover() first")
        logits, self._cache = self._decode_jit(self._merged_params, tokens,
                                               self._cache)
        if self._tokens_seen is not None:
            self._tokens_seen = jnp.concatenate(
                [self._tokens_seen, tokens.reshape(-1, 1)], axis=1)
        return logits

    # ---------------- instrumentation ----------------------------------------

    def compile_stats(self) -> Dict[str, int]:
        """XLA compile counts of the engine's jitted paths.  The decode
        count must stay 1 across the pipeline->single strategy switch (the
        pipeline prefill's cache has the same shapes as the standard
        lowering's, so the switch never retraces)."""
        def _n(fn):
            try:
                return int(fn._cache_size())
            except Exception:       # private API moved — report -1, don't die
                return -1
        out = {"decode_compiles": _n(self._decode_jit),
               "prefill_compiles": _n(self._prefill_jit)}
        out["pipeline_prefill_compiles"] = (
            sum(max(0, _n(f)) for f in self._pipe_fns.values())
            if self._pipe_fns else 0)
        return out

    # ---------------- strategy switching (§4.3.3) ----------------------------

    def maybe_switch_strategy(self, request_rate: float,
                              crossover_rate: float = 0.0) -> bool:
        """Seamless switch to per-device independent serving once every
        device holds the full model (and the rate argues for it)."""
        if self.strategy == "single":
            return False
        if self.fully_loaded and request_rate >= crossover_rate:
            self.strategy = "single"
            self._record_event("strategy_switch", "single")
            return True
        return False

    # ---------------- failures + recovery (§4.4) -----------------------------

    def crash(self, device_ids: Sequence[int]):
        """Mark devices dead.  If the background fill thread is running it
        is stopped *cleanly*: the stop flag is raised before the devices
        are marked (a round in flight holds ``_load_lock`` and finishes
        atomically, so its ``LoadRound`` accounting lands exactly once),
        then the thread is joined OUTSIDE the lock — no leaked thread, no
        double-counted bytes, and no half-recorded round."""
        was_filling = self.fill_running
        if was_filling:
            self._fill_stop.set()
        with self._load_lock:
            for i in device_ids:
                self.devices[i].alive = False
        if was_filling:
            self.stop_fill(join=True)
        self._record_event("crash", list(device_ids))

    def restart(self, n_devices: Optional[int] = None):
        """Full server reboot (cluster rejoin path): every device comes back
        alive and empty with a fresh rotated load plan; serving state is
        dropped (in-flight requests were re-routed before the restart)."""
        self.stop_fill()
        with self._load_lock:
            if n_devices is not None:
                self.n_devices = n_devices
                self.n_segments = None  # segment override was per-dev-count
            lb = analytic.layer_bytes_list(self.cfg)
            self.plan = make_plan(lb, self.n_devices, self.n_segments)
            self.devices = [DeviceState(i) for i in range(self.n_devices)]
            self.strategy = "pipeline"
            self._cache = None
            self._tokens_seen = None
            self._reset_load_accounting()   # a rejoin is a fresh cold start
        self._record_event("restart", self.n_devices)

    def revive(self, device_ids: Sequence[int]):
        """Bring crashed devices back online with empty HBM and re-plan the
        segment ring over the enlarged alive set; the revived devices pick
        up their missing spans on subsequent ``load_round`` calls."""
        with self._load_lock:
            for i in device_ids:
                d = self.devices[i]
                if d.alive:
                    continue
                d.alive = True
                d.loaded = set()
                d.kv_segments = set()
            alive = [d.idx for d in self.devices if d.alive]
            self.plan = reassign(self.plan, self.loaded_map(), alive)
        self._record_event("revive", list(device_ids))

    def _repartition_pipeline(self) -> int:
        """Rebuild the shard_map prefill mesh for the current alive-device
        count (variable-stage mesh, FlexPipe direction).  Picks the largest
        stage count that divides the layer stack and fits the visible XLA
        devices — possibly over a SUBSET of them (``stage_mesh``), so stage
        counts that don't divide the device count still pipeline.  Falls
        back to the single lowering when no split works (decode is
        unaffected either way).  Never clears ``_pipe_fns``: entries are
        keyed by (n_stages, B, S), so a stage count seen before reuses its
        compiles and a new one costs at most one lowering per shape."""
        if not self._pipe_requested:
            return self._pipe_n_stages if self._pipe_enabled else 0
        with self._load_lock:
            n_alive = sum(1 for d in self.devices if d.alive)
        n_xla = len(jax.devices())
        n_stages = 0
        for s in range(min(n_alive, n_xla, self.cfg.n_layers), 1, -1):
            if self.cfg.n_layers % s == 0:
                n_stages = s
                break
        if not n_stages:
            self._pipe_enabled = False
            self._pipe_mesh = None
            self._pipe_n_stages = 0
            return 0
        if n_stages != self._pipe_n_stages or not self._pipe_enabled:
            from repro.distributed.pipeline import stage_mesh
            self._pipe_mesh = stage_mesh(n_stages)
            self._pipe_n_stages = n_stages
            self._pipe_enabled = True
        return n_stages

    def repartition(self, dead: Sequence[int] = (),
                    revive: Sequence[int] = ()) -> Dict[str, Any]:
        """Elastic in-flight repartition: re-split the pipeline over a
        CHANGED device set — shrink (e.g. 4→3 stages) when devices die,
        widen back when they rejoin — without draining in-flight work.

        Steps: (1) stop the background fill cleanly (remembering cadence);
        (2) apply the membership change and ``reassign`` contiguous stage
        spans over the new alive set; (3) load until a viable chain exists
        again; (4) rebuild the shard_map mesh for the new stage count
        (compiles keyed per stage count, never per crash event); (5) re-lay
        live decode state onto the new partition via ``reconstruct_cache``
        — only layers whose KV actually died are recomputed, surviving
        layers are reused verbatim, so the continued token stream is
        bit-identical and zero tokens are re-prefilled; (6) hand the fill
        back off to a fresh thread over the new plan if one was running.

        Returns a stats dict (also appended as a ``repartition`` event).
        """
        dead = [int(i) for i in dead]
        revive = [int(i) for i in revive]
        was_filling = self.fill_running
        if was_filling:
            self._fill_stop.set()
            self.stop_fill(join=True)
        with self._load_lock:
            for i in dead:
                self.devices[i].alive = False
            for i in revive:
                d = self.devices[i]
                if d.alive:
                    continue
                d.alive = True
                d.loaded = set()
                d.kv_segments = set()
            alive = [d.idx for d in self.devices if d.alive]
            if not alive:
                raise EngineError("all devices dead")
            self.plan = reassign(self.plan, self.loaded_map(), alive)
        while self.chain() is None:
            if not self.load_round():
                raise EngineError("cannot complete chain after repartition")
        n_stages = self._repartition_pipeline()
        stats: Dict[str, Any] = {
            "dead": dead, "revive": revive, "n_alive": len(alive),
            "n_stages": n_stages, "lost_layers": 0,
        }
        ch = self.chain()
        if self._cache is not None and self._tokens_seen is not None:
            surviving_kv: Set[int] = set()
            with self._load_lock:
                for d in self.devices:
                    if d.alive:
                        surviving_kv |= d.kv_segments
            has_state = self._segment_layer_mask(surviving_kv)
            stats["lost_layers"] = int(sum(1 for h in has_state if not h))
            if not all(has_state):
                # the reconstruct prefill is the expensive part — keep it
                # OUTSIDE the lock so the refill thread isn't stalled
                self._cache, rstats = reconstruct_cache(
                    self.cfg, self._merged_params,
                    {"tokens": self._tokens_seen}, self._cache, has_state,
                    max_len=self.max_len)
                stats["reconstruct"] = rstats
            # KV ownership follows the NEW chain after the re-lay
            with self._load_lock:
                for d in self.devices:
                    d.kv_segments = set()
                for dev, seg in ch:
                    self.devices[dev].kv_segments.add(seg)
        if was_filling and not self.fully_loaded:
            self.start_fill(self._fill_interval_s, self._fill_budget)
        self._record_event("repartition", stats)
        return stats

    def recover(self) -> Dict[str, Any]:
        """Pipeline-parallel recovery: layer reassignment + (if mid-decode)
        KV/state reconstruction.  Returns a stats dict."""
        stats: Dict[str, Any] = {}
        with self._load_lock:
            alive = [d.idx for d in self.devices if d.alive]
            if not alive:
                raise EngineError("all devices dead")
            ch = self.chain()
            if ch is None:
                # layer reassignment: survivors re-plan loading of missing
                # spans.  Under the lock: a fill round racing the plan swap
                # would load segments of the plan being replaced.
                self.plan = reassign(self.plan, self.loaded_map(), alive)
                stats["replanned"] = True
        if stats.get("replanned"):
            while not self.ready:
                if not self.load_round():
                    raise EngineError("cannot complete chain")
            ch = self.chain()
        stats["chain"] = ch

        # KV reconstruction for in-flight decode state (if any)
        if self._cache is not None and self._tokens_seen is not None:
            surviving_kv: Set[int] = set()
            with self._load_lock:
                for d in self.devices:
                    if d.alive:
                        surviving_kv |= d.kv_segments
            has_state = self._segment_layer_mask(surviving_kv)
            # reconstruct prefill runs OUTSIDE the lock (expensive; the
            # refill thread may keep loading while state is recomputed)
            self._cache, rstats = reconstruct_cache(
                self.cfg, self._merged_params,
                {"tokens": self._tokens_seen}, self._cache, has_state,
                max_len=self.max_len)
            stats["reconstruct"] = rstats
            with self._load_lock:
                for dev, seg in ch:
                    self.devices[dev].kv_segments.add(seg)
        self._record_event("recover", stats)
        return stats


def generate(engine: PipeBoostEngine, batch: Dict, n_tokens: int,
             crash_at: Optional[int] = None,
             crash_devices: Sequence[int] = ()) -> jnp.ndarray:
    """Greedy generation helper (tests/examples): returns (B, n_tokens)."""
    logits = engine.prefill(batch)
    outs = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    outs.append(tok)
    for i in range(1, n_tokens):
        if crash_at is not None and i == crash_at:
            engine.crash(crash_devices)
            engine.recover()
        logits = engine.decode(tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        outs.append(tok)
    return jnp.stack(outs, axis=1)
