"""PipeBoost engine: functional multi-device orchestration of
pipeline-parallel loading, inference during loading, strategy switching,
crash injection and recovery (paper §4.1–§4.4).

This engine executes REAL models (repro.models) over *logical devices* — on
this CPU container the devices are bookkeeping entities (what is loaded
where, whose KV lives where) while compute runs on the host; on a real TPU
slice the same state machine drives per-device `jax.device_put` of segment
shards and the shard_map pipeline in repro/distributed/pipeline.py.  Timing
comes from core/simulator.py; this module owns *correctness*:

  * a request admitted before full load produces EXACTLY the same tokens as
    a fully-loaded model (pipeline math is the same math);
  * a crash + recovery produces the same KV/state as a fresh prefill.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import analytic
from repro.core.kv_reconstruct import reconstruct_cache
from repro.core.planner import (LoadPlan, make_plan, reassign, viable_chain)
from repro.lora.adapters import LoRAAdapter, merge_lora, unmerge_lora
from repro.models import transformer


class EngineError(RuntimeError):
    pass


@dataclass
class DeviceState:
    idx: int
    alive: bool = True
    loaded: Set[int] = field(default_factory=set)      # fully-loaded segments
    kv_segments: Set[int] = field(default_factory=set)  # segments whose KV
                                                         # this device owns


@dataclass
class EngineStatus:
    ready: bool
    fully_loaded: bool
    strategy: str
    alive: List[int]
    loaded: Dict[int, List[int]]
    chain: Optional[List[Tuple[int, int]]]


class PipeBoostEngine:
    """State machine + functional inference for one GPU-server analogue."""

    def __init__(self, cfg: ArchConfig, params, n_devices: int,
                 n_segments: Optional[int] = None, max_len: int = 256,
                 adapters: Optional[Dict[str, LoRAAdapter]] = None):
        self.cfg = cfg
        self._full_params = params          # "checkpoint in DRAM"
        self.n_devices = n_devices
        self.n_segments = n_segments
        lb = analytic.layer_bytes_list(cfg)
        self.plan: LoadPlan = make_plan(lb, n_devices, n_segments)
        self.devices = [DeviceState(i) for i in range(n_devices)]
        self.max_len = max_len
        self.strategy = "pipeline"          # -> "single" after switch
        self.adapters = adapters or {}
        self.active_adapter: Optional[str] = None
        self._merged_params = params        # params w/ active adapter merged
        self._cache: Optional[Dict] = None
        self._tokens_seen: Optional[jnp.ndarray] = None
        self.events: List[Tuple[str, Any]] = []
        self._prefill_jit = jax.jit(
            lambda p, b: transformer.forward(cfg, p, b, mode="prefill",
                                             max_len=self.max_len))
        self._decode_jit = jax.jit(
            lambda p, t, c: transformer.decode_step(cfg, p, {"tokens": t}, c))

    # ---------------- loading ------------------------------------------------

    def load_next_segment(self, device: int) -> Optional[int]:
        """Advance device's rotated loading order by one segment."""
        d = self.devices[device]
        if not d.alive:
            raise EngineError(f"device {device} is dead")
        for s in self.plan.order[device]:
            if s not in d.loaded:
                d.loaded.add(s)
                self.events.append(("load", (device, s)))
                return s
        return None

    def load_round(self) -> bool:
        """One synchronous loading round across alive devices.  Returns True
        if anything was loaded."""
        any_loaded = False
        for d in self.devices:
            if d.alive and self.load_next_segment(d.idx) is not None:
                any_loaded = True
        return any_loaded

    def loaded_map(self) -> Dict[int, List[int]]:
        return {d.idx: sorted(d.loaded) for d in self.devices if d.alive}

    def chain(self) -> Optional[List[Tuple[int, int]]]:
        return viable_chain(self.plan, self.loaded_map(),
                            [d.idx for d in self.devices if d.alive])

    @property
    def ready(self) -> bool:
        return self.chain() is not None

    @property
    def fully_loaded(self) -> bool:
        n = len(self.plan.segments)
        return all(len(d.loaded) == n for d in self.devices if d.alive)

    def status(self) -> EngineStatus:
        return EngineStatus(self.ready, self.fully_loaded, self.strategy,
                            [d.idx for d in self.devices if d.alive],
                            self.loaded_map(), self.chain())

    # ---------------- adapters (merged-LoRA, §4.3.2) -------------------------

    def switch_adapter(self, name: Optional[str]):
        if name == self.active_adapter:
            return
        params = self._full_params
        if name is not None:
            if name not in self.adapters:
                raise EngineError(f"unknown adapter {name!r}")
            params = merge_lora(params, self.adapters[name])
        self.active_adapter = name
        self._merged_params = params
        self.events.append(("adapter_switch", name))

    # ---------------- inference ---------------------------------------------

    def _segment_layer_mask(self, segs: Set[int]) -> List[bool]:
        """Per-global-layer: is the layer inside one of ``segs``."""
        mask = [False] * self.cfg.n_layers
        for s in segs:
            seg = self.plan.segments[s]
            for i in range(seg.layer_start, seg.layer_end):
                mask[i] = True
        return mask

    def lost_state_layers(self, device_ids: Sequence[int]) -> List[bool]:
        """Per-global-layer: True if that layer's KV/recurrent state lives
        on one of ``device_ids`` under the current serving assignment.

        Ownership follows the viable pipeline chain (each chained segment's
        KV sits in its device's HBM); with no chain yet, nothing is owned.
        Must be called BEFORE ``crash`` marks the devices dead — the chain
        is computed over alive devices.  This is what lets a partial crash
        reconstruct only the layers that actually died (paper §4.4.2)
        instead of re-prefilling everything.
        """
        dead = set(device_ids)
        ch = self.chain()
        if ch is None:
            return [False] * self.cfg.n_layers
        return self._segment_layer_mask(
            {seg for dev, seg in ch if dev in dead})

    def prefill(self, batch: Dict) -> jnp.ndarray:
        """Serve a prefill the moment a chain exists (the paper's point:
        this happens after each device loaded only ~1/N of the model)."""
        chain = self.chain()
        if chain is None:
            raise EngineError("no viable pipeline chain: model not ready")
        logits, cache = self._prefill_jit(self._merged_params, batch)
        self._cache = cache
        self._tokens_seen = batch.get("tokens")
        # KV ownership follows the serving chain
        for d in self.devices:
            d.kv_segments = set()
        for dev, seg in chain:
            self.devices[dev].kv_segments.add(seg)
        self.events.append(("prefill", chain))
        return logits

    def decode(self, tokens: jnp.ndarray) -> jnp.ndarray:
        if self._cache is None:
            raise EngineError("prefill first")
        if self.strategy == "pipeline" and self.chain() is None:
            raise EngineError("pipeline chain broken — recover() first")
        logits, self._cache = self._decode_jit(self._merged_params, tokens,
                                               self._cache)
        if self._tokens_seen is not None:
            self._tokens_seen = jnp.concatenate(
                [self._tokens_seen, tokens.reshape(-1, 1)], axis=1)
        return logits

    # ---------------- strategy switching (§4.3.3) ----------------------------

    def maybe_switch_strategy(self, request_rate: float,
                              crossover_rate: float = 0.0) -> bool:
        """Seamless switch to per-device independent serving once every
        device holds the full model (and the rate argues for it)."""
        if self.strategy == "single":
            return False
        if self.fully_loaded and request_rate >= crossover_rate:
            self.strategy = "single"
            self.events.append(("strategy_switch", "single"))
            return True
        return False

    # ---------------- failures + recovery (§4.4) -----------------------------

    def crash(self, device_ids: Sequence[int]):
        for i in device_ids:
            self.devices[i].alive = False
        self.events.append(("crash", list(device_ids)))

    def restart(self, n_devices: Optional[int] = None):
        """Full server reboot (cluster rejoin path): every device comes back
        alive and empty with a fresh rotated load plan; serving state is
        dropped (in-flight requests were re-routed before the restart)."""
        if n_devices is not None:
            self.n_devices = n_devices
            self.n_segments = None   # segment override was per-device-count
        lb = analytic.layer_bytes_list(self.cfg)
        self.plan = make_plan(lb, self.n_devices, self.n_segments)
        self.devices = [DeviceState(i) for i in range(self.n_devices)]
        self.strategy = "pipeline"
        self._cache = None
        self._tokens_seen = None
        self.events.append(("restart", self.n_devices))

    def revive(self, device_ids: Sequence[int]):
        """Bring crashed devices back online with empty HBM and re-plan the
        segment ring over the enlarged alive set; the revived devices pick
        up their missing spans on subsequent ``load_round`` calls."""
        for i in device_ids:
            d = self.devices[i]
            if d.alive:
                continue
            d.alive = True
            d.loaded = set()
            d.kv_segments = set()
        alive = [d.idx for d in self.devices if d.alive]
        self.plan = reassign(self.plan, self.loaded_map(), alive)
        self.events.append(("revive", list(device_ids)))

    def recover(self) -> Dict[str, Any]:
        """Pipeline-parallel recovery: layer reassignment + (if mid-decode)
        KV/state reconstruction.  Returns a stats dict."""
        alive = [d.idx for d in self.devices if d.alive]
        if not alive:
            raise EngineError("all devices dead")
        stats: Dict[str, Any] = {}
        ch = self.chain()
        if ch is None:
            # layer reassignment: survivors re-plan loading of missing spans
            self.plan = reassign(self.plan, self.loaded_map(), alive)
            stats["replanned"] = True
            while not self.ready:
                if not self.load_round():
                    raise EngineError("cannot complete chain")
            ch = self.chain()
        stats["chain"] = ch

        # KV reconstruction for in-flight decode state (if any)
        if self._cache is not None and self._tokens_seen is not None:
            surviving_kv: Set[int] = set()
            for d in self.devices:
                if d.alive:
                    surviving_kv |= d.kv_segments
            has_state = self._segment_layer_mask(surviving_kv)
            self._cache, rstats = reconstruct_cache(
                self.cfg, self._merged_params,
                {"tokens": self._tokens_seen}, self._cache, has_state,
                max_len=self.max_len)
            stats["reconstruct"] = rstats
            for dev, seg in ch:
                self.devices[dev].kv_segments.add(seg)
        self.events.append(("recover", stats))
        return stats


def generate(engine: PipeBoostEngine, batch: Dict, n_tokens: int,
             crash_at: Optional[int] = None,
             crash_devices: Sequence[int] = ()) -> jnp.ndarray:
    """Greedy generation helper (tests/examples): returns (B, n_tokens)."""
    logits = engine.prefill(batch)
    outs = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    outs.append(tok)
    for i in range(1, n_tokens):
        if crash_at is not None and i == crash_at:
            engine.crash(crash_devices)
            engine.recover()
        logits = engine.decode(tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        outs.append(tok)
    return jnp.stack(outs, axis=1)
