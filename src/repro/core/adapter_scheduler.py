"""Epoch-based LoRA adapter switching (paper §4.3.2, Fig. 5 / Fig. 14).

Requests are classified by adapter into per-adapter FIFO queues.  The
scheduler serves batches of the *active* adapter for an epoch, then rotates
to the next non-empty queue; merged-LoRA means a switch costs one merge pass
(unmerge old + merge new).  The eager baseline switches whenever the head of
the global FIFO differs from the active adapter — paying the merge cost per
flip, which is what Fig. 14 shows blowing up at high request rates.

Implemented as a deterministic discrete-event simulation so benchmarks are
reproducible; the same policy object drives the real serving engine
(repro/serving/engine.py) through its ``next_batch`` interface.

See ``docs/ARCHITECTURE.md`` § "Core: the PipeBoost engine".
"""
from __future__ import annotations

import math
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple


@dataclass
class Request:
    rid: int
    adapter: str
    arrival: float
    service: float            # seconds of compute once scheduled
    start: float = -1.0
    finish: float = -1.0
    model: str = ""           # fleet pool the request targets (multi-model)
    deadline: float = math.inf  # absolute TTFT deadline (SLO-aware dispatch)

    @property
    def latency(self) -> float:
        return self.finish - self.arrival


@dataclass
class EpochSchedulerPolicy:
    """Groups per-adapter, serves the active adapter up to ``epoch_budget``
    requests (or until its queue drains), then rotates."""
    epoch_budget: int = 8
    max_batch: int = 8

    def make_state(self):
        return {"queues": OrderedDict(), "active": None, "served_in_epoch": 0}

    def enqueue(self, state, req: Request):
        state["queues"].setdefault(req.adapter, deque()).append(req)

    def peek_adapter(self, state) -> Optional[str]:
        """Adapter the next next_batch() would serve (no state change)."""
        queues = state["queues"]
        nonempty = [a for a, q in queues.items() if q]
        if not nonempty:
            return None
        active = state["active"]
        if (active in nonempty
                and state["served_in_epoch"] < self.epoch_budget):
            return active
        keys = list(queues.keys())
        if active in keys:
            i = keys.index(active)
            order = keys[i + 1:] + keys[:i + 1]
        else:
            order = keys
        return next(a for a in order if queues[a])

    def next_batch(self, state) -> Tuple[Optional[str], List[Request]]:
        queues: "OrderedDict[str, Deque[Request]]" = state["queues"]
        nonempty = [a for a, q in queues.items() if q]
        if not nonempty:
            return None, []
        active = state["active"]
        rotate = (active not in nonempty
                  or state["served_in_epoch"] >= self.epoch_budget)
        if rotate:
            # round-robin to the next non-empty adapter after `active`
            keys = list(queues.keys())
            if active in keys:
                i = keys.index(active)
                order = keys[i + 1:] + keys[:i + 1]
            else:
                order = keys
            active = next(a for a in order if queues[a])
            state["active"] = active
            state["served_in_epoch"] = 0
        q = queues[active]
        batch = [q.popleft() for _ in range(min(self.max_batch, len(q)))]
        state["served_in_epoch"] += len(batch)
        return active, batch

    def requeue_front(self, state, items):
        """Return unadmitted items to the head of their queues (the serving
        engine ran out of free slots mid-batch)."""
        for it in reversed(items):
            state["queues"].setdefault(it.adapter, deque()).appendleft(it)
        state["served_in_epoch"] = max(
            0, state["served_in_epoch"] - len(items))


@dataclass
class EagerPolicy:
    """Serve strictly in arrival order; switch adapters whenever the head
    request needs a different one (the paper's no-scheduling baseline)."""
    max_batch: int = 8

    def make_state(self):
        return {"fifo": deque(), "active": None}

    def enqueue(self, state, req: Request):
        state["fifo"].append(req)

    def peek_adapter(self, state) -> Optional[str]:
        fifo = state["fifo"]
        return fifo[0].adapter if fifo else None

    def next_batch(self, state) -> Tuple[Optional[str], List[Request]]:
        fifo: Deque[Request] = state["fifo"]
        if not fifo:
            return None, []
        adapter = fifo[0].adapter
        state["active"] = adapter
        batch = []
        while fifo and fifo[0].adapter == adapter and len(batch) < self.max_batch:
            batch.append(fifo.popleft())
        return adapter, batch

    def requeue_front(self, state, items):
        for it in reversed(items):
            state["fifo"].appendleft(it)


def simulate_adapter_serving(policy, *, rps: float, horizon: float,
                             n_adapters: int = 2, switch_prob: float = 0.2,
                             service_s: float = 0.05, merge_s: float = 0.15,
                             seed: int = 0) -> Dict[str, float]:
    """Deterministic DES of one serving replica under a request stream where
    consecutive requests switch adapters with ``switch_prob``.

    Returns mean/var/p99 completion latency and the number of merges.
    """
    rng_state = [seed * 2654435761 % 2**32 or 1]

    def rnd() -> float:
        rng_state[0] = (1103515245 * rng_state[0] + 12345) % 2**31
        return rng_state[0] / float(2**31)

    # arrival stream
    reqs: List[Request] = []
    t, adapter_i, rid = 0.0, 0, 0
    while True:
        t += -math.log(max(rnd(), 1e-12)) / max(rps, 1e-9)
        if t >= horizon:
            break
        if rnd() < switch_prob:
            adapter_i = (adapter_i + 1) % n_adapters
        reqs.append(Request(rid, f"lora{adapter_i}", t, service_s))
        rid += 1

    state = policy.make_state()
    clock = 0.0
    active: Optional[str] = None
    merges = 0
    done: List[Request] = []
    i = 0
    while i < len(reqs) or _pending(state):
        # admit everything that has arrived by `clock`
        while i < len(reqs) and reqs[i].arrival <= clock:
            policy.enqueue(state, reqs[i])
            i += 1
        adapter, batch = policy.next_batch(state)
        if adapter is None:
            if i < len(reqs):
                clock = max(clock, reqs[i].arrival)
                continue
            break
        if adapter != active:
            clock += merge_s          # unmerge + merge pass
            active = adapter
            merges += 1
        # continuous batching: batch completes together
        clock += batch[0].service
        for r in batch:
            r.start = clock - r.service
            r.finish = clock
            done.append(r)
    lats = [r.latency for r in done]
    if not lats:
        return {"mean": 0.0, "var": 0.0, "p99": 0.0, "merges": 0.0, "n": 0.0}
    mean = sum(lats) / len(lats)
    var = sum((x - mean) ** 2 for x in lats) / len(lats)
    p99 = sorted(lats)[min(len(lats) - 1, int(0.99 * len(lats)))]
    return {"mean": mean, "var": var, "p99": p99,
            "merges": float(merges), "n": float(len(lats))}


def _pending(state) -> bool:
    if "fifo" in state:
        return bool(state["fifo"])
    return any(q for q in state.get("queues", {}).values())
