"""Analytic FLOP / byte models per architecture (used by the cold-start
simulator, the roofline report, and EXPERIMENTS.md MODEL_FLOPS).

Conventions:
  * matmul FLOPs = 2 * m * n * k
  * MODEL_FLOPS for training = 6 * N_active * tokens (fwd 2x + bwd 4x)
  * attention FLOPs counted exactly (causal halves the score work)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ArchConfig


def param_bytes(cfg: ArchConfig, dtype_bytes: int = 2) -> int:
    return cfg.param_count() * dtype_bytes


def layer_bytes_list(cfg: ArchConfig, dtype_bytes: int = 2):
    """Per-layer parameter bytes (embedding/head excluded — they are loaded
    with the first/last segments by the loading engine)."""
    D, hd = cfg.d_model, cfg.resolved_head_dim
    out = []
    for kind in cfg.layer_kinds():
        n = 2 * D
        if kind == "attn":
            n += D * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * D
            n += (3 if cfg.gated_mlp else 2) * D * cfg.d_ff
        elif kind == "moe":
            n += D * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * D
            n += D * cfg.n_experts
            n += (cfg.n_experts + cfg.n_shared_experts) * 3 * D * cfg.moe_d_ff
        elif kind == "ssm":
            di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
            n += D * (2 * di + 2 * N + H) + (di + 2 * N) * cfg.ssm_conv
            n += 2 * H + di + di * D
        elif kind == "rec":
            W = cfg.lru_width or D
            n += 2 * D * W + W * cfg.ssm_conv + 2 * W * W + W + W * D
            n += 3 * D * cfg.d_ff
        out.append(int(n) * dtype_bytes)
    return out


def embed_bytes(cfg: ArchConfig, dtype_bytes: int = 2) -> int:
    n = cfg.padded_vocab * cfg.d_model
    if not cfg.tie_embeddings:
        n *= 2
    return n * dtype_bytes


# ---------------------------------------------------------------------------
# FLOPs
# ---------------------------------------------------------------------------

def forward_flops(cfg: ArchConfig, batch: int, seq: int, *,
                  kv_len: int = 0) -> float:
    """FLOPs of one forward pass over ``batch*seq`` tokens.

    kv_len > 0 means decode: each token attends to kv_len cached positions.
    """
    T = batch * seq
    D, hd = cfg.d_model, cfg.resolved_head_dim
    f = 0.0
    for kind in cfg.layer_kinds():
        if kind in ("attn", "moe"):
            qkv = 2 * T * D * hd * (cfg.n_heads + 2 * cfg.n_kv_heads)
            o = 2 * T * cfg.n_heads * hd * D
            if kv_len:
                ctx = min(kv_len, cfg.attn_window) if cfg.attn_window else kv_len
                att = 2 * 2 * T * cfg.n_heads * hd * ctx
            else:
                ctx = min(seq, cfg.attn_window) if cfg.attn_window else seq
                att = 2 * 2 * batch * cfg.n_heads * hd * (
                    seq * ctx / 2 if not cfg.attn_window else seq * ctx)
                if not cfg.causal:
                    att = 2 * 2 * batch * cfg.n_heads * hd * seq * seq
            f += qkv + o + att
            if kind == "attn":
                mult = 3 if cfg.gated_mlp else 2
                f += 2 * T * D * cfg.d_ff * mult
            else:
                active = cfg.top_k + cfg.n_shared_experts
                f += 2 * T * D * cfg.moe_d_ff * 3 * active
                f += 2 * T * D * cfg.n_experts  # router
        elif kind == "ssm":
            di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
            f += 2 * T * D * (2 * di + 2 * N + H)          # in_proj
            f += 2 * T * di * D                            # out_proj
            Q = cfg.ssm_chunk if not kv_len else 1
            # SSD: intra-chunk quadratic + state update + state read
            f += 2 * T * H * Q * (N + P)                   # scores + apply
            f += 2 * 2 * T * H * P * N                     # state update/read
        elif kind == "rec":
            W = cfg.lru_width or D
            f += 2 * T * D * W * 2 + 2 * T * W * W * 2 + 2 * T * W * D
            f += 2 * T * D * cfg.d_ff * 3
    # unembed
    f += 2 * T * D * cfg.padded_vocab
    return f


def train_step_flops(cfg: ArchConfig, batch: int, seq: int) -> float:
    return 3.0 * forward_flops(cfg, batch, seq)


def model_flops(cfg: ArchConfig, batch: int, seq: int, kind: str) -> float:
    """The 6·N·D convention (N_active for MoE) used in EXPERIMENTS.md."""
    tokens = batch * seq
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens


def decode_step_bytes(cfg: ArchConfig, batch: int, kv_len: int,
                      dtype_bytes: int = 2) -> float:
    """HBM bytes touched by one decode step (params + cache) — the decode
    roofline is memory-bound, this is its denominator term."""
    b = param_bytes(cfg, dtype_bytes)
    hd = cfg.resolved_head_dim
    for kind in cfg.layer_kinds():
        if kind in ("attn", "moe"):
            ctx = min(kv_len, cfg.attn_window) if cfg.attn_window else kv_len
            b += 2 * batch * ctx * cfg.n_kv_heads * hd * dtype_bytes
        elif kind == "ssm":
            b += batch * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4 * 2
        elif kind == "rec":
            b += batch * (cfg.lru_width or cfg.d_model) * 4 * 2
    return float(b)
