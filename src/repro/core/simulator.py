"""Deterministic event-driven cold-start / recovery simulator (DESIGN.md §2).

The paper's latency results are functions of byte flows over a small set of
hardware channels (SSD→DRAM, DRAM→device, inter-device hops) plus compute.
This module models those channels explicitly so every paper experiment
(Figs. 8–17, Table 1) is reproducible as a deterministic computation — and
so the same planner code that drives the real engine is what gets timed.

Two hardware presets:
  * ``GPU_PAPER``  — calibrated to the paper's A100 testbed (Table 1).
  * ``TPU_V5E``    — the repo's TPU target (197 TF bf16, 819 GB/s HBM,
                     ~50 GB/s/link ICI), used for the beyond-paper analysis.

See ``docs/ARCHITECTURE.md`` § "Core: the PipeBoost engine".
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig
from repro.core import analytic
from repro.core.planner import (LoadPlan, critical_path_bytes, make_plan,
                                reassign, viable_chain)


@dataclass(frozen=True)
class HwModel:
    name: str = "gpu-paper"
    ssd_bw: float = 13e9        # effective ckpt->DRAM stream (page-cache assisted)
    host_link_bw: float = 4.2e9 # effective DRAM->device per device
    host_agg_bw: float = 60e9   # aggregate DRAM read bandwidth cap
    transfer_fixed_s: float = 0.08  # per-device transfer setup cost
    init_meta_s: float = 0.26   # full-model metadata init (scales with share)
    chip_flops: float = 312e12  # peak (A100 fp16)
    mfu: float = 0.30           # achieved fraction during prefill
    hbm_bw: float = 1.6e12
    ici_bw: float = 25e9        # inter-device (NVLink/PCIe P2P or ICI)
    hop_latency: float = 100e-6 # per pipeline hop (kernel launch + sync)
    lora_merge_bw: float = 0.5e12  # bytes/s of W touched during merged-LoRA


GPU_PAPER = HwModel()
TPU_V5E = HwModel(name="tpu-v5e", ssd_bw=13e9, host_link_bw=8e9,
                  host_agg_bw=120e9, transfer_fixed_s=0.03,
                  init_meta_s=0.12, chip_flops=197e12, mfu=0.45,
                  hbm_bw=819e9, ici_bw=50e9, hop_latency=20e-6,
                  lora_merge_bw=0.4e12)


# ---------------------------------------------------------------------------
# Shared timing primitives
# ---------------------------------------------------------------------------

def host_bw_effective(hw: HwModel, concurrent: int) -> float:
    """Per-stream host (DRAM->device) bandwidth with ``concurrent``
    simultaneous pulls sharing the aggregate read path.

    Each stream gets at most its own link (``host_link_bw``), and the sum
    of all streams is capped by ``host_agg_bw`` — so N simultaneous
    host-only cold starts contend for the aggregate instead of each
    filling at full link rate.  This is the cost model the cluster's
    multicast scale-out (``cluster/multicast.py``) and the host-only
    bench baseline price host fills through.
    """
    return min(hw.host_link_bw, hw.host_agg_bw / max(1, concurrent))


def _link_bw(hw: HwModel, concurrent: int) -> float:
    """Backwards-compatible alias of :func:`host_bw_effective` (the
    pre-PR-9 private name, kept for in-module callers)."""
    return host_bw_effective(hw, concurrent)


def prefill_time(cfg: ArchConfig, hw: HwModel, batch: int, prompt: int,
                 n_stages: int = 1) -> float:
    f = analytic.forward_flops(cfg, batch, prompt)
    t = f / (hw.chip_flops * hw.mfu)
    if n_stages > 1:
        # one request's prefill traverses all stages sequentially; per-stage
        # compute is f/n but the total is still ~f (+ hop overheads + one
        # hidden-state transfer per boundary)
        hid = batch * prompt * cfg.d_model * 2  # bf16 hidden state
        t = t + (n_stages - 1) * (hw.hop_latency + hid / hw.ici_bw)
    return t


def decode_step_time(cfg: ArchConfig, hw: HwModel, batch: int, kv_len: int,
                     n_stages: int = 1) -> float:
    f = analytic.forward_flops(cfg, batch, 1, kv_len=kv_len)
    b = analytic.decode_step_bytes(cfg, batch, kv_len)
    t = max(f / (hw.chip_flops * hw.mfu), b / hw.hbm_bw) / n_stages
    if n_stages > 1:
        hid = batch * cfg.d_model * 2
        t += hw.hop_latency + hid / hw.ici_bw
    return t


# ---------------------------------------------------------------------------
# Snapshot migration cost (bytes over a link)
# ---------------------------------------------------------------------------
# Crash migration moves a KVSnapshot's rows between servers.  The byte
# count is architecture-determined; which link it crosses depends on the
# deployment (same host: device->device over NVLink/ICI; cross host:
# device->DRAM->NIC, bounded by the PCIe/host link).  GPU_PAPER carries
# both bandwidths: ``ici_bw`` (NVLink-class P2P) and ``host_link_bw``
# (PCIe-class DRAM<->device).

SNAPSHOT_LINKS = ("nvlink", "pcie")


def kv_snapshot_bytes(cfg: ArchConfig, pos: int, max_len: int,
                      dtype_bytes: int = 2) -> int:
    """Modeled wire size of one request's ``KVSnapshot`` at ``pos`` tokens.

    Attention layers: K+V rows for the cached window
    (``min(pos, capacity)`` positions x n_kv_heads x head_dim, 2 tensors).
    SSM (mamba-style) layers: the recurrent state (heads x head_dim x
    d_state) + conv buffer — position-independent.  RG-LRU layers: the
    hidden state.  This is the *payload* a migration must move; the
    repo's in-memory snapshots carry full ``max_len`` rows (pre-sliced
    layout), so the model is the honest lower bound a wire format would
    ship.
    """
    # windowed attention rings hold at most attn_window rows (the same
    # capacity rule as transformer.attn_cache_capacity)
    capacity = min(max_len, cfg.attn_window) if cfg.attn_window > 0 \
        else max_len
    kv_len = min(pos, capacity)
    hd = cfg.resolved_head_dim
    total = 0
    for kind in cfg.layer_kinds():
        if kind in ("attn", "moe"):
            total += 2 * kv_len * cfg.n_kv_heads * hd * dtype_bytes
        elif kind == "ssm":
            # SSD state (H, P, N) + conv ring buffer
            total += (cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
                      + cfg.d_inner * cfg.ssm_conv) * dtype_bytes
        else:  # rec (RG-LRU): hidden state at the recurrence width
            total += (cfg.lru_width or cfg.d_model) * dtype_bytes
    return total


def snapshot_transfer_time(nbytes: int, hw: HwModel,
                           link: str = "nvlink") -> float:
    """Seconds to move ``nbytes`` of snapshot state over ``link``
    ("nvlink" = device-P2P ``ici_bw``, "pcie" = ``host_link_bw``), plus
    one hop latency.  ``bench_recovery`` reports this modeled time next
    to the measured post-crash TTFT so the functional CPU numbers carry a
    paper-testbed interpretation."""
    if link == "nvlink":
        bw = hw.ici_bw
    elif link == "pcie":
        bw = hw.host_link_bw
    else:
        raise ValueError(f"unknown link {link!r}; "
                         f"available: {SNAPSHOT_LINKS}")
    return hw.hop_latency + nbytes / bw


def state_resurrect_time(nbytes: int, hw: HwModel,
                         concurrent: int = 1) -> float:
    """Seconds to pull a spilled state-tier bundle (prefix-cache rows +
    KV snapshots) from host DRAM back onto a freshly spawned server.

    The bundle streams over the DRAM->device path, so with ``concurrent``
    simultaneous pulls (several servers resurrecting, or a resurrect
    overlapping host cold-start fills) each stream shares the aggregate
    via :func:`host_bw_effective` — the same contention model multicast
    prices host fills through — plus one per-device transfer setup cost.
    The cluster router prices spill/resurrect decisions with this
    (``docs/ARCHITECTURE.md`` § "Fleet state tier")."""
    return hw.transfer_fixed_s + nbytes / host_bw_effective(hw, concurrent)


# ---------------------------------------------------------------------------
# Cold start
# ---------------------------------------------------------------------------

@dataclass
class ColdStartResult:
    strategy: str
    ttft: float
    t_ready: float              # inference service ready (chain complete)
    t_full: float               # every device holds the full model
    breakdown: Dict[str, float]
    timeline: List[Tuple[float, str]] = field(default_factory=list)


def simulate_cold_start(cfg: ArchConfig, hw: HwModel, n_devices: int,
                        strategy: str, *, batch: int = 64, prompt: int = 64,
                        lora_rank: int = 0, n_adapters: int = 1,
                        ckpt_in_dram: bool = False,
                        dtype_bytes: int = 2) -> ColdStartResult:
    """TTFT of one cold start under a given loading strategy.

    strategies: 'transformers' | 'serverlessllm' | 'pipeboost'.
    """
    Wb = analytic.param_bytes(cfg, dtype_bytes)
    lora_frac = 0.0
    if lora_rank:
        # adapters on q,k,v,o of every attn layer
        hd = cfg.resolved_head_dim
        per_layer = lora_rank * (3 * cfg.d_model + hd * (cfg.n_heads + 2 * cfg.n_kv_heads)
                                 + cfg.n_heads * hd + cfg.d_model)
        n_attn = sum(1 for k in cfg.layer_kinds() if k in ("attn", "moe"))
        lora_b = per_layer * n_attn * dtype_bytes * n_adapters
        lora_frac = lora_b / Wb
    timeline: List[Tuple[float, str]] = []
    bd: Dict[str, float] = {}

    t_ckpt = 0.0 if ckpt_in_dram else Wb / hw.ssd_bw
    bd["load_ckpt_dram"] = t_ckpt
    bd["load_lora_dram"] = t_ckpt * lora_frac
    timeline.append((t_ckpt, "ckpt_in_dram"))

    if strategy == "transformers":
        # CPU-side deserialize (single stream), then every device pulls the
        # full parameter set concurrently.
        t_init = hw.init_meta_s * 2.0  # transformers-style init is heavier
        bw = _link_bw(hw, n_devices)
        t_xfer = hw.transfer_fixed_s + Wb * (1 + lora_frac) / bw
        t_ready = t_ckpt * (1 + lora_frac) + t_init + t_xfer
        t_full = t_ready
        bd["init_meta"] = t_init
        bd["load_params"] = t_xfer
        t_prefill = prefill_time(cfg, hw, batch, prompt, n_stages=1)
    elif strategy == "serverlessllm":
        t_init = hw.init_meta_s
        bw = _link_bw(hw, n_devices)
        t_xfer = hw.transfer_fixed_s + Wb * (1 + lora_frac) / bw
        t_ready = t_ckpt * (1 + lora_frac) + t_init + t_xfer
        t_full = t_ready
        bd["init_meta"] = t_init
        bd["load_params"] = t_xfer
        t_prefill = prefill_time(cfg, hw, batch, prompt, n_stages=1)
    elif strategy == "pipeboost":
        # each device transfers only its serve-span on the critical path
        t_init = hw.init_meta_s / n_devices + 0.02
        bw = _link_bw(hw, n_devices)
        span = Wb / n_devices
        t_xfer = hw.transfer_fixed_s + span * (1 + lora_frac) / bw
        t_ready = t_ckpt * (1 + lora_frac) + t_init + t_xfer
        # background fill of the remaining (N-1)/N while serving
        t_full = t_ready + (Wb - span) / bw
        bd["init_meta"] = t_init
        bd["load_params"] = t_xfer
        t_prefill = prefill_time(cfg, hw, batch, prompt, n_stages=n_devices)
    else:
        raise ValueError(strategy)

    if lora_rank:
        # merged-LoRA: one pass over the device-resident span of W
        span = Wb / (n_devices if strategy == "pipeboost" else 1)
        t_merge = span / hw.lora_merge_bw
        bd["lora_merge"] = t_merge
        t_ready += t_merge
        t_full += t_merge
    bd["prefill"] = t_prefill
    ttft = t_ready + t_prefill
    bd["total"] = ttft
    timeline.append((t_ready, "service_ready"))
    timeline.append((ttft, "first_token"))
    timeline.append((t_full, "fully_loaded"))
    return ColdStartResult(strategy, ttft, t_ready, t_full, bd, timeline)


# ---------------------------------------------------------------------------
# Recovery during loading (paper Fig. 15/16)
# ---------------------------------------------------------------------------

@dataclass
class RecoveryResult:
    mode: str
    recovery_time: float   # crash -> service resumes
    ttft: float            # request arrival (t=0) -> first token
    detail: Dict[str, float] = field(default_factory=dict)


def simulate_loading_failure(cfg: ArchConfig, hw: HwModel, n_devices: int,
                             failed: Sequence[int], fail_frac: float = 0.5,
                             mode: str = "pp", *, batch: int = 64,
                             prompt: int = 64,
                             dtype_bytes: int = 2) -> RecoveryResult:
    """Crash ``failed`` devices when each device has loaded ``fail_frac`` of
    its first segment; measure time until the (re-planned) chain is ready.

    mode='pp'   — paper's Pipeline-Parallel Recovery (planner.reassign)
    mode='full' — restart pipeline-parallel loading from scratch on survivors
    """
    Wb = analytic.param_bytes(cfg, dtype_bytes)
    lb = analytic.layer_bytes_list(cfg, dtype_bytes)
    plan = make_plan(lb, n_devices)
    seg_b = [s.bytes for s in plan.segments]
    bw = _link_bw(hw, n_devices)
    survivors = [d for d in range(n_devices) if d not in set(failed)]
    bw_after = _link_bw(hw, len(survivors))

    t_ckpt = Wb / hw.ssd_bw
    t_init = hw.init_meta_s / n_devices + 0.02
    # crash instant: each device mid-way through its first segment
    t_crash = t_ckpt + t_init + hw.transfer_fixed_s + \
        fail_frac * (Wb / n_devices) / bw

    loaded = {d: [] for d in range(n_devices)}  # fully-loaded segments only
    if mode == "pp":
        new_plan = reassign(plan, loaded, survivors)
        # each survivor finishes its current segment then loads its new span
        rem = {}
        for d in survivors:
            first = plan.order[d][0]
            need = (1 - fail_frac) * seg_b[first]
            for s in new_plan.serve_assignment[d]:
                if s != first:
                    need += seg_b[s]
            rem[d] = need
        t_load = max(rem.values()) / bw_after
        t_resume = t_crash + t_load
    elif mode == "full":
        # tear down and restart: re-init + transfer full span per survivor
        new_plan = make_plan(lb, len(survivors))
        cp = critical_path_bytes(new_plan)
        t_load = hw.transfer_fixed_s + max(cp.values()) / bw_after
        # complete restart: full framework/metadata re-init, not 1/N
        t_resume = t_crash + hw.init_meta_s + 0.02 + t_load
    else:
        raise ValueError(mode)

    t_prefill = prefill_time(cfg, hw, batch, prompt, n_stages=len(survivors))
    return RecoveryResult(mode, t_resume - t_crash, t_resume + t_prefill,
                          {"t_crash": t_crash, "t_resume": t_resume,
                           "prefill": t_prefill})


# ---------------------------------------------------------------------------
# Recovery during inference (paper Fig. 17)
# ---------------------------------------------------------------------------

def simulate_inference_failure(cfg: ArchConfig, hw: HwModel, n_devices: int,
                               *, fail_at: float = 6.0, horizon: float = 16.0,
                               batch: int = 8, prompt: int = 64,
                               kv_len: int = 256, mode: str = "pp",
                               dt: float = 0.25,
                               dtype_bytes: int = 2) -> List[Tuple[float, float]]:
    """Tokens/s timeline with one device crash at ``fail_at`` seconds.

    mode='pp':  re-plan to a shorter chain + KV-reconstruction stall for the
                layers whose KV lived on the dead device.
    mode='full': full reload of the model on survivors (service halt).
    """
    step_n = decode_step_time(cfg, hw, batch, kv_len, n_stages=n_devices)
    thr_n = batch / step_n
    survivors = n_devices - 1
    step_s = decode_step_time(cfg, hw, batch, kv_len, n_stages=survivors)
    thr_s = batch / step_s

    Wb = analytic.param_bytes(cfg, dtype_bytes)
    bw = _link_bw(hw, survivors)
    if mode == "pp":
        # survivors already hold most layers (background fill had progressed);
        # stall = load the dead device's span + rebuild its layers' KV
        t_load = (Wb / n_devices) / bw
        miss_frac = 1.0 / n_devices
        t_kv = prefill_time(cfg, hw, batch, prompt + kv_len) * miss_frac
        stall = t_load * 0.35 + t_kv  # span mostly pre-filled in background
    else:
        stall = hw.transfer_fixed_s + hw.init_meta_s / survivors + \
            (Wb / survivors) / bw + prefill_time(cfg, hw, batch,
                                                 prompt + kv_len)
    out = []
    t = 0.0
    while t < horizon:
        if t < fail_at:
            thr = thr_n
        elif t < fail_at + stall:
            thr = 0.0 if mode == "full" else thr_s * 0.5
        else:
            thr = thr_s
        out.append((round(t, 6), thr))
        t += dt
    return out


# ---------------------------------------------------------------------------
# Strategy crossover (paper Fig. 6): pipeline vs per-device inference
# ---------------------------------------------------------------------------

def simulate_request_latency(cfg: ArchConfig, hw: HwModel, n_devices: int,
                             rps: float, *, strategy: str = "pipeline",
                             batch: int = 1, prompt: int = 64,
                             gen_tokens: int = 32, horizon: float = 30.0,
                             seed: int = 0) -> Dict[str, float]:
    """Mean/var of request completion latency under Poisson-ish arrivals.

    'pipeline': all requests flow through one N-stage pipeline (hop overhead
                per stage per step); 'single': requests round-robin over N
                independent replicas.
    """
    rng = _lcg(seed)
    arrivals = []
    t = 0.0
    while t < horizon:
        t += -math.log(max(rng(), 1e-12)) / max(rps, 1e-9)
        arrivals.append(t)
    # per-request compute is the same either way; the pipeline pays an
    # inter-stage hop (latency + hidden-state transfer) per token per
    # boundary — the communication overhead the paper's Fig. 6 blames.
    svc_compute = prefill_time(cfg, hw, batch, prompt) + \
        gen_tokens * decode_step_time(cfg, hw, batch, prompt)
    if strategy == "pipeline":
        hid = batch * cfg.d_model * 2
        hop = hw.hop_latency + hid / hw.ici_bw
        svc = svc_compute + (gen_tokens + 1) * (n_devices - 1) * hop
        servers = [0.0]
        admit_interval = svc / n_devices   # belt: n_devices mbs in flight
    else:
        svc = svc_compute
        servers = [0.0] * n_devices
        admit_interval = svc
    lat: List[float] = []
    for i, a in enumerate(arrivals):
        s = i % len(servers)
        start = max(a, servers[s])
        servers[s] = start + admit_interval
        lat.append(start + svc - a)
    mean = sum(lat) / len(lat)
    var = sum((x - mean) ** 2 for x in lat) / len(lat)
    return {"mean": mean, "var": var, "p50": sorted(lat)[len(lat) // 2],
            "n": float(len(lat))}


def _lcg(seed: int):
    state = [seed * 6364136223846793005 + 1442695040888963407]

    def nxt() -> float:
        state[0] = (state[0] * 6364136223846793005 + 1442695040888963407) % 2**64
        return (state[0] >> 11) / float(2**53)
    return nxt
