"""Bursty arrival traces for the serverless cluster (paper §2.1, Fig. 3).

Serverless LLM workloads are bursty: long quiet stretches punctuated by
request waves that force fleet-wide cold starts (the scenario HydraServe /
λScale benchmark against).  Three generators cover the space:

* ``poisson_trace``    — memoryless baseline (CV = 1).
* ``gamma_trace``      — Gamma-renewal arrivals; ``burstiness`` (= CV²) > 1
                         clusters arrivals into bursts with long gaps.
* ``burst_wave_trace`` — square-wave modulated Poisson: quiet base rate with
                         sudden waves, the canonical scale-out trigger.

Traces are plain ``Arrival`` records, replayable and JSON round-trippable
(``save_trace`` / ``load_trace``) so benchmark runs are reproducible and
real traces (e.g. Azure Functions) can be dropped in the same format.
All generators are deterministic in ``seed``.

Full-day replays *stream*: ``arrival_stream`` feeds the router's event
engine one arrival at a time, and ``iter_azure_trace`` synthesizes an
Azure-shape day minute-by-minute — a million-row trace is never resident
as a list.  See ``docs/ARCHITECTURE.md`` § "Cluster: traces".
"""
from __future__ import annotations

import csv
import heapq
import json
from dataclasses import asdict, dataclass, field
from typing import (Dict, Iterable, Iterator, List, Optional, Sequence,
                    Tuple)

import numpy as np


@dataclass(frozen=True)
class Arrival:
    """One request arrival: when it lands and what it asks for."""
    time: float
    prompt_len: int = 8
    max_new_tokens: int = 6
    adapter: Optional[str] = None
    seed: int = 0               # per-request prompt-content seed
    model: Optional[str] = None          # fleet pool (None = default pool)
    ttft_deadline_s: Optional[float] = None  # TTFT SLO relative to arrival
    # shared-prefix prompts (prefix-cache workloads): the first
    # ``prefix_len`` tokens are drawn from ``prefix_seed`` so arrivals
    # sharing it share an exact token prefix; 0/None = fully per-request
    # content (the pre-state-tier behavior, and what old JSON loads as)
    prefix_len: int = 0
    prefix_seed: Optional[int] = None


def _materialize(times: Sequence[float], rng: np.random.Generator, *,
                 prompt_len: int, max_new_tokens: int,
                 adapters: Sequence[str] = (), adapter_prob: float = 0.5,
                 model: Optional[str] = None,
                 ttft_deadline_s: Optional[float] = None) -> List[Arrival]:
    out = []
    for i, t in enumerate(times):
        adapter = None
        if adapters and rng.random() < adapter_prob:
            adapter = adapters[int(rng.integers(len(adapters)))]
        out.append(Arrival(float(t), prompt_len, max_new_tokens, adapter,
                           seed=int(rng.integers(2**31 - 1)), model=model,
                           ttft_deadline_s=ttft_deadline_s))
    return out


def poisson_trace(rate: float, horizon: float, *, seed: int = 0,
                  prompt_len: int = 8, max_new_tokens: int = 6,
                  adapters: Sequence[str] = (), adapter_prob: float = 0.5,
                  model: Optional[str] = None,
                  ttft_deadline_s: Optional[float] = None) -> List[Arrival]:
    """Homogeneous Poisson arrivals at ``rate`` req/s over ``horizon`` s."""
    rng = np.random.default_rng(seed)
    times, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / max(rate, 1e-9))
        if t >= horizon:
            break
        times.append(t)
    return _materialize(times, rng, prompt_len=prompt_len,
                        max_new_tokens=max_new_tokens, adapters=adapters,
                        adapter_prob=adapter_prob, model=model,
                        ttft_deadline_s=ttft_deadline_s)


def gamma_trace(rate: float, horizon: float, *, burstiness: float = 4.0,
                seed: int = 0, prompt_len: int = 8, max_new_tokens: int = 6,
                adapters: Sequence[str] = (), adapter_prob: float = 0.5,
                model: Optional[str] = None,
                ttft_deadline_s: Optional[float] = None) -> List[Arrival]:
    """Gamma-renewal arrivals with mean rate ``rate`` and CV² = burstiness.

    shape k = 1/burstiness < 1 makes inter-arrivals heavy at zero (bursts)
    with occasional long gaps; burstiness = 1 degenerates to Poisson.
    """
    shape = 1.0 / max(burstiness, 1e-6)
    scale = 1.0 / (max(rate, 1e-9) * shape)   # mean = shape*scale = 1/rate
    rng = np.random.default_rng(seed)
    times, t = [], 0.0
    while True:
        t += rng.gamma(shape, scale)
        if t >= horizon:
            break
        times.append(t)
    return _materialize(times, rng, prompt_len=prompt_len,
                        max_new_tokens=max_new_tokens, adapters=adapters,
                        adapter_prob=adapter_prob, model=model,
                        ttft_deadline_s=ttft_deadline_s)


def burst_wave_trace(n_requests: int, *, base_rate: float = 0.5,
                     wave_rate: float = 20.0, wave_at: float = 2.0,
                     wave_len: float = 2.0, seed: int = 0,
                     prompt_len: int = 8, max_new_tokens: int = 6,
                     adapters: Sequence[str] = (), adapter_prob: float = 0.5,
                     model: Optional[str] = None,
                     ttft_deadline_s: Optional[float] = None
                     ) -> List[Arrival]:
    """Quiet Poisson base load with one sudden wave of ``wave_rate`` starting
    at ``wave_at`` — the fleet-cold-start scenario (stops after
    ``n_requests`` total)."""
    rng = np.random.default_rng(seed)
    times, t = [], 0.0
    while len(times) < n_requests:
        in_wave = wave_at <= t < wave_at + wave_len
        r = wave_rate if in_wave else base_rate
        dt = rng.exponential(1.0 / max(r, 1e-9))
        # don't let a quiet-phase gap jump the wave start
        if not in_wave and t < wave_at < t + dt:
            t = wave_at
            continue
        t += dt
        times.append(t)
    return _materialize(times, rng, prompt_len=prompt_len,
                        max_new_tokens=max_new_tokens, adapters=adapters,
                        adapter_prob=adapter_prob, model=model,
                        ttft_deadline_s=ttft_deadline_s)


def merge_traces(*traces: Sequence[Arrival]) -> List[Arrival]:
    """Interleave per-model/per-adapter traces into one time-sorted stream
    (each input is already sorted; stable across equal times)."""
    return list(heapq.merge(*traces, key=lambda a: a.time))


def arrival_stream(trace: Iterable[Arrival]) -> Iterator[Arrival]:
    """Time-ordered arrival iterator for ``ClusterRouter.run``.

    Lists/tuples are sorted here (the semantics ``run`` always had); any
    other iterable is assumed already time-ordered and passed through
    lazily — the streaming contract that lets ``iter_azure_trace`` replay
    a million-row day without ever materializing it.
    """
    if isinstance(trace, (list, tuple)):
        return iter(sorted(trace, key=lambda a: a.time))
    return iter(trace)


# ---------------------------------------------------------------------------
# Azure Functions trace ingestion
# ---------------------------------------------------------------------------

def load_azure_trace(path: str, *, minute_s: float = 60.0,
                     rate_scale: float = 1.0, prompt_len: int = 8,
                     max_new_tokens: int = 6,
                     models: Sequence[str] = (),
                     adapters: Sequence[Optional[str]] = (None,),
                     ttft_deadline_s: Optional[float] = None,
                     max_requests: Optional[int] = None,
                     seed: int = 0) -> List[Arrival]:
    """Convert the public Azure Functions invocation-count CSV shape into
    ``Arrival``s (the real-workload replay ROADMAP names).

    The dataset (Shahrad et al., ATC'20) is one row per function —
    ``HashOwner,HashApp,HashFunction,Trigger,1..1440`` — where the numeric
    columns are per-minute invocation counts.  Mapping:

    * every numeric-named column is one trace minute; minute ``m`` spans
      ``[(m-1)*minute_s, m*minute_s)`` seconds (shrink ``minute_s`` to
      time-compress a day onto a bench horizon);
    * per-function per-minute counts are scaled by ``rate_scale`` and
      rounded stochastically (a count of 2.4 yields 2 arrivals plus one
      more with p=0.4), then placed uniformly inside the minute;
    * functions map deterministically (sorted by their hash triple) onto
      the provided ``models``/``adapters`` round-robin — the per-function
      → adapter/model mapping PipeBoost's shared-base-model premise
      (§2.1) implies.  Empty ``models`` leaves ``Arrival.model`` None
      (single-pool replay); ``adapters`` defaults to base-only.

    Deterministic in ``seed``; arrivals return time-sorted, optionally
    truncated to the first ``max_requests``.
    """
    rng = np.random.default_rng(seed)
    with open(path, newline="") as f:
        rows = list(csv.DictReader(f))
    if not rows:
        return []
    minute_cols = sorted((c for c in rows[0] if c and c.strip().isdigit()),
                         key=int)
    if not minute_cols:
        raise ValueError(f"{path}: no per-minute count columns "
                         "(expected the Azure Functions CSV shape)")
    rows.sort(key=lambda r: (r.get("HashOwner", ""), r.get("HashApp", ""),
                             r.get("HashFunction", "")))
    out: List[Arrival] = []
    for fi, row in enumerate(rows):
        model = models[fi % len(models)] if models else None
        adapter = adapters[fi % len(adapters)] if adapters else None
        for col in minute_cols:
            raw = (row.get(col) or "0").strip()
            scaled = float(raw or 0) * rate_scale
            n = int(scaled) + (1 if rng.random() < scaled - int(scaled)
                               else 0)
            if n <= 0:
                continue
            # minute columns are 1-based day minutes; honor gaps and
            # trimmed excerpts (column "10" IS minute 10, wherever it
            # sits in the header)
            t0 = (int(col) - 1) * minute_s
            for t in sorted(t0 + rng.random(n) * minute_s):
                out.append(Arrival(float(t), prompt_len, max_new_tokens,
                                   adapter,
                                   seed=int(rng.integers(2**31 - 1)),
                                   model=model,
                                   ttft_deadline_s=ttft_deadline_s))
    out.sort(key=lambda a: a.time)
    return out[:max_requests] if max_requests is not None else out


def iter_azure_trace(path: str, *, minute_s: float = 60.0,
                     rate_scale: float = 1.0, prompt_len: int = 8,
                     max_new_tokens: int = 6,
                     models: Sequence[str] = (),
                     adapters: Sequence[Optional[str]] = (None,),
                     ttft_deadline_s: Optional[float] = None,
                     max_requests: Optional[int] = None,
                     seed: int = 0) -> Iterator[Arrival]:
    """Streaming, minute-major counterpart of :func:`load_azure_trace`.

    Same CSV shape and same per-minute model (scaled counts, stochastic
    rounding, uniform placement, deterministic function→model/adapter
    round-robin), but generated one *day minute* at a time and yielded in
    time order — a full day ``rate_scale``-d to a million arrivals is
    never resident as a list.  Feed it straight to ``ClusterRouter.run``
    (the event engine consumes arrivals lazily).

    Note: a distinct generator, not a drop-in RNG-replay of
    ``load_azure_trace`` — the minute-major draw order yields different
    (equally distributed) jitter for the same seed.
    """
    rng = np.random.default_rng(seed)
    with open(path, newline="") as f:
        rows = list(csv.DictReader(f))     # one row per FUNCTION: small
    if not rows:
        return
    minute_cols = sorted((c for c in rows[0] if c and c.strip().isdigit()),
                         key=int)
    if not minute_cols:
        raise ValueError(f"{path}: no per-minute count columns "
                         "(expected the Azure Functions CSV shape)")
    rows.sort(key=lambda r: (r.get("HashOwner", ""), r.get("HashApp", ""),
                             r.get("HashFunction", "")))
    fns = [(models[fi % len(models)] if models else None,
            adapters[fi % len(adapters)] if adapters else None, row)
           for fi, row in enumerate(rows)]
    emitted = 0
    for col in minute_cols:
        t0 = (int(col) - 1) * minute_s
        batch: List[Arrival] = []
        for model, adapter, row in fns:
            raw = (row.get(col) or "0").strip()
            scaled = float(raw or 0) * rate_scale
            n = int(scaled) + (1 if rng.random() < scaled - int(scaled)
                               else 0)
            if n <= 0:
                continue
            times = t0 + rng.random(n) * minute_s
            seeds = rng.integers(2**31 - 1, size=n)
            batch.extend(Arrival(float(t), prompt_len, max_new_tokens,
                                 adapter, seed=int(s), model=model,
                                 ttft_deadline_s=ttft_deadline_s)
                         for t, s in zip(times, seeds))
        batch.sort(key=lambda a: a.time)
        for a in batch:
            if max_requests is not None and emitted >= max_requests:
                return
            emitted += 1
            yield a


# ---------------------------------------------------------------------------
# Replayable trace format
# ---------------------------------------------------------------------------

def save_trace(path: str, trace: Sequence[Arrival]) -> None:
    """Write a trace as versioned JSON (replayable, diffable)."""
    with open(path, "w") as f:
        json.dump({"version": 1, "arrivals": [asdict(a) for a in trace]},
                  f, indent=1)


def load_trace(path: str) -> List[Arrival]:
    """Read a ``save_trace`` JSON file back into ``Arrival``s."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("version") != 1:
        raise ValueError(f"unknown trace version {doc.get('version')!r}")
    return [Arrival(**a) for a in doc["arrivals"]]


def prompt_tokens(arrival: Arrival, vocab_size: int) -> np.ndarray:
    """Deterministic prompt content for an arrival (seed-addressed).

    With ``prefix_len`` > 0 the first ``prefix_len`` tokens come from
    ``prefix_seed`` (arrivals sharing it share the exact token prefix —
    the shared-system-prompt shape the prefix cache exploits) and the
    remainder from the per-request ``seed``.  ``prefix_len`` 0 keeps the
    original single-draw behavior bit-for-bit.
    """
    hi = min(vocab_size, 250)
    n_pre = min(max(0, arrival.prefix_len), arrival.prompt_len) \
        if arrival.prefix_seed is not None else 0
    if n_pre == 0:
        rng = np.random.default_rng(arrival.seed)
        return rng.integers(0, hi,
                            size=arrival.prompt_len).astype(np.int64)
    pre = np.random.default_rng(arrival.prefix_seed) \
        .integers(0, hi, size=n_pre).astype(np.int64)
    sfx = np.random.default_rng(arrival.seed) \
        .integers(0, hi, size=arrival.prompt_len - n_pre).astype(np.int64)
    return np.concatenate([pre, sfx])


def repeated_prefix_trace(n: int, *, prefix_len: int, suffix_len: int,
                          n_prefixes: int = 1, gap_s: float = 0.2,
                          max_new_tokens: int = 6, seed: int = 0,
                          model: Optional[str] = None,
                          adapter: Optional[str] = None,
                          ttft_deadline_s: Optional[float] = None
                          ) -> List[Arrival]:
    """Evenly spaced arrivals whose prompts cycle over ``n_prefixes``
    shared token prefixes with per-request suffixes — the workload shape
    (system prompt + unique user turn) the cross-request prefix cache is
    built for.  Deterministic in ``seed``; arrival ``i`` lands at
    ``i * gap_s`` and reuses prefix ``i % n_prefixes``.

    Pick a ``gap_s`` OFF the router's tick grid (not a multiple of
    ``tick_s`` — same rule as chaos event times): an arrival exactly on
    a tick boundary can be admitted on different ticks by the tick and
    event engines (their clocks accumulate float error differently)."""
    out = []
    for i in range(n):
        out.append(Arrival(
            time=i * gap_s, prompt_len=prefix_len + suffix_len,
            max_new_tokens=max_new_tokens, adapter=adapter,
            seed=seed + i, model=model, ttft_deadline_s=ttft_deadline_s,
            prefix_len=prefix_len, prefix_seed=10_000 + (i % n_prefixes)))
    return out


# ---------------------------------------------------------------------------
# Chaos schedules (seeded fault injection)
# ---------------------------------------------------------------------------

CHAOS_KINDS = ("crash", "partial_crash", "rejoin", "source_crash",
               "fill_crash")

# the load-stage fault vocabulary (PR 9): kinds that target the multicast
# scale-out path — a warm server mid-send ("source_crash") or a spawning
# receiver mid-fill ("fill_crash").  Both execute as whole-server crashes
# (the multicast manager re-roots around whichever role the victim held);
# keeping them distinct kinds makes schedules self-describing and lets
# random_chaos target the load stage on purpose.
LOAD_CHAOS_KINDS = ("source_crash", "fill_crash")


@dataclass(frozen=True)
class ChaosEvent:
    """One scheduled fault: kill a whole server, kill some of its devices,
    or bring a server / a device list back.

    Load-stage kinds (``source_crash`` / ``fill_crash``) name the victim's
    role in a multicast scale-out — a warm load source vs a receiver
    mid-fill — and execute as whole-server crashes; the multicast manager
    re-roots transfers around the victim either way.

    ``devices`` names the affected device ids for ``partial_crash`` and
    for a device-granular ``rejoin``; empty means the whole server.
    Times should sit OFF the router's tick grid (like arrival times) so
    the tick and event engines agree on the applying tick bit-for-bit.
    """
    time: float
    kind: str                       # one of CHAOS_KINDS
    server: int
    devices: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.kind not in CHAOS_KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r}; "
                             f"known kinds: {CHAOS_KINDS}")
        object.__setattr__(self, "devices", tuple(self.devices))


@dataclass
class ChaosSchedule:
    """A replayable fault-injection script, executed by
    ``ClusterRouter.run(chaos=...)`` identically under the tick and event
    engines: an event applies at the first tick whose (pre-advance) clock
    has reached its time — exactly the arrival-admission rule."""
    events: List[ChaosEvent] = field(default_factory=list)

    def __post_init__(self):
        self.events = sorted(self.events, key=lambda e: e.time)

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)


# chaos schema versions: 1 = the original crash/partial_crash/rejoin
# vocabulary; 2 = adds the load-stage kinds (LOAD_CHAOS_KINDS).  save_chaos
# stamps the lowest version that can express the schedule so old readers
# keep loading old-vocabulary files.
CHAOS_SCHEMA_VERSIONS = (1, 2)


def save_chaos(path: str, schedule: ChaosSchedule) -> None:
    """Write a chaos schedule as versioned JSON (replayable, diffable).

    Schedules using only the original kinds save as version 1 (readable
    by pre-multicast loaders); any load-stage event bumps the file to
    version 2."""
    version = 2 if any(e.kind in LOAD_CHAOS_KINDS
                       for e in schedule.events) else 1
    with open(path, "w") as f:
        json.dump({"version": version,
                   "events": [asdict(e) for e in schedule.events]},
                  f, indent=1)


def load_chaos(path: str) -> ChaosSchedule:
    """Read a ``save_chaos`` JSON file back into a ``ChaosSchedule``.

    Accepts schema versions ``CHAOS_SCHEMA_VERSIONS``; unknown versions
    and unknown event kinds raise ``ValueError``s that name the file,
    the offending event, and the accepted vocabulary."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("version") not in CHAOS_SCHEMA_VERSIONS:
        raise ValueError(
            f"{path}: unknown chaos version {doc.get('version')!r}; "
            f"this reader understands versions {CHAOS_SCHEMA_VERSIONS}")
    events = []
    for i, e in enumerate(doc.get("events", [])):
        try:
            events.append(ChaosEvent(**e))
        except (TypeError, ValueError) as exc:
            raise ValueError(f"{path}: bad chaos event #{i} {e!r}: {exc}") \
                from exc
    return ChaosSchedule(events)


def random_chaos(n_faults: int, horizon: float, n_servers: int, *,
                 seed: int = 0, n_devices: int = 0,
                 partial_prob: float = 0.0,
                 load_fault_prob: float = 0.0,
                 rejoin_delay_s: float = 1.0,
                 tick_s: float = 0.05) -> ChaosSchedule:
    """Seeded random fault script: ``n_faults`` crashes uniformly over
    ``(0, horizon)``, each paired with a rejoin ``rejoin_delay_s`` later.

    With ``partial_prob`` > 0 (needs ``n_devices``), a fault is a
    ``partial_crash`` of a random proper device subset, rejoined at device
    granularity.  With ``load_fault_prob`` > 0, a fault targets the
    multicast load stage instead: a ``source_crash`` or ``fill_crash``
    (50/50), paired with a whole-server rejoin like a plain crash.
    Event times are nudged off the ``tick_s`` grid so tick and event
    engines replay them on the same tick.  Deterministic by ``seed``.
    """
    rng = np.random.default_rng(seed)
    events: List[ChaosEvent] = []
    for _ in range(n_faults):
        t = float(rng.uniform(0.0, horizon))
        if abs(t / tick_s - round(t / tick_s)) < 1e-6:   # off-grid nudge
            t += 0.37 * tick_s
        sid = int(rng.integers(n_servers))
        if rng.random() < load_fault_prob:
            kind = LOAD_CHAOS_KINDS[int(rng.integers(2))]
            events.append(ChaosEvent(t, kind, sid))
            events.append(ChaosEvent(t + rejoin_delay_s, "rejoin", sid))
            continue
        partial = (n_devices > 1 and rng.random() < partial_prob)
        if partial:
            k = int(rng.integers(1, n_devices))          # proper subset
            devs = tuple(sorted(rng.choice(n_devices, size=k,
                                           replace=False).tolist()))
            events.append(ChaosEvent(t, "partial_crash", sid, devs))
            events.append(ChaosEvent(t + rejoin_delay_s, "rejoin", sid,
                                     devs))
        else:
            events.append(ChaosEvent(t, "crash", sid))
            events.append(ChaosEvent(t + rejoin_delay_s, "rejoin", sid))
    return ChaosSchedule(events)
