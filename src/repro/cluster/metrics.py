"""Cluster metrics: TTFT/TBT percentiles, queue depth, GPU-seconds.

One ``ClusterMetrics`` instance per router run accumulates per-request
records and per-tick gauges, then summarizes to a flat dict / JSON blob so
``benchmarks/`` can track the trajectory across PRs.  Times are in router
clock seconds (logical ticks × tick_s on CPU; wall seconds on real slices).
Records carry the request's absolute TTFT deadline, so ``summary`` also
reports SLO attainment and ``ttft_curve`` the percentile curves the
full-day Azure replay benchmark appends to ``BENCH_fleet.json``.

See ``docs/ARCHITECTURE.md`` § "Cluster: metrics" and
``docs/BENCHMARKS.md`` for the recorded schema.
"""
from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Tuple


def percentile(xs: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0,100]); 0.0 on empty input.

    Nearest rank is the smallest 1-based rank k with k/n >= q/100, i.e.
    ``k = ceil(q/100 * n)`` — NOT a rounded interpolation over the index
    range (``round(q/100 * (n-1))`` biases high percentiles downward,
    e.g. it reports p50 of 100 samples as the 51st value).
    """
    if not xs:
        return 0.0
    s = sorted(xs)
    k = min(len(s) - 1, max(0, math.ceil(q / 100.0 * len(s)) - 1))
    return s[k]


def _gauge_max(samples: List[Tuple[float, int]]) -> float:
    """Max of a per-tick gauge.  In a fleet, every pool appends its own
    sample at the shared tick timestamp — same-time samples sum first, so
    the max is fleet-wide, not per-pool."""
    agg: Dict[float, int] = {}
    for t, v in samples:
        agg[t] = agg.get(t, 0) + v
    return float(max(agg.values(), default=0))


@dataclass(slots=True)
class RequestRecord:
    """Per-request latency record.  ``slots=True`` matters: a full-day
    Azure replay holds ~10⁶ of these, and slots halve the footprint."""
    rid: int
    arrival: float
    first_token: Optional[float] = None
    finished: Optional[float] = None
    n_tokens: int = 0
    reroutes: int = 0            # times the request moved servers (crashes)
    # server that completed it: sid, or "pool/sid" in a multi-model fleet
    server: object = -1
    model: Optional[str] = None  # fleet pool that served it (multi-model)
    deadline: Optional[float] = None  # absolute TTFT deadline (SLO)

    @property
    def ttft(self) -> Optional[float]:
        return None if self.first_token is None \
            else self.first_token - self.arrival

    @property
    def tbt(self) -> Optional[float]:
        """Mean time-between-tokens after the first."""
        if self.finished is None or self.first_token is None \
                or self.n_tokens < 2:
            return None
        return (self.finished - self.first_token) / (self.n_tokens - 1)


@dataclass
class ClusterMetrics:
    """Shared metrics store: per-request records, per-tick gauges, event
    log, and the recovery/cold-start/hot-path accounting a run folds in —
    summarized to a flat dict (``summary``) or JSON (``to_json``)."""
    records: Dict[int, RequestRecord] = field(default_factory=dict)
    queue_depth: List[Tuple[float, int]] = field(default_factory=list)
    n_servers: List[Tuple[float, int]] = field(default_factory=list)
    gpu_seconds: float = 0.0
    # device-seconds of capacity lost to partially-crashed servers that
    # kept serving (repartition mode): sum over ticks of
    # (dead devices on live servers) * tick duration
    degraded_seconds: float = 0.0
    events: List[Tuple[float, str, str]] = field(default_factory=list)
    hotpath: Dict[str, float] = field(default_factory=dict)
    # crash-recovery accounting: how each displaced in-flight request was
    # resumed ("migrate" = KV snapshot imported on a survivor, "reprefill" =
    # prompt+prefix recomputed, "reconstruct" = partial-crash in-place
    # rebuild) and how many prompt/prefix tokens each path saved or re-spent
    recovery: Dict[str, float] = field(default_factory=dict)
    # overlapped cold-start accounting, one record per server (latest
    # generation wins on rejoin): time_to_ready / time_to_fully_loaded on
    # the router clock, wall-clock equivalents + loaded bytes from the
    # engine's per-round fill accounting (see ClusterServer.cold_start_record)
    # — keyed by sid, or "pool/sid" strings in a multi-model fleet
    coldstart: Dict = field(default_factory=dict)
    # peer-to-peer multicast scale-out accounting (cluster/multicast.py):
    # bytes/segments by source kind plus the fault-handling counters
    # (re-roots, retries, host fallbacks, receiver stall time)
    multicast: Dict[str, float] = field(default_factory=dict)
    # fleet state-tier accounting (cluster/state_tier.py): warm-state
    # spill/resurrect counters from the run's shared StateTier
    state_tier: Dict[str, float] = field(default_factory=dict)
    # the time source this run records against (the router injects its
    # Clock here, so external instrumentation can stamp events with
    # ``metrics.now()`` under logical AND wall time without branching)
    clock: Optional[object] = field(default=None, repr=False, compare=False)

    def now(self) -> float:
        """The run's current time off the injected clock (0.0 unwired)."""
        return self.clock.now() if self.clock is not None else 0.0

    # ---- recording --------------------------------------------------------
    def on_submit(self, rid: int, arrival: float,
                  model: Optional[str] = None,
                  deadline: Optional[float] = None) -> None:
        """Open a request's record at its arrival time (``deadline`` is
        the absolute TTFT SLO instant, if the trace carries one)."""
        self.records[rid] = RequestRecord(rid, arrival, model=model,
                                          deadline=deadline)

    def on_first_token(self, rid: int, t: float) -> None:
        """Stamp the first-token instant (idempotent: reroutes and
        re-prefills after a crash must not move an already-set TTFT)."""
        r = self.records[rid]
        if r.first_token is None:
            r.first_token = t

    def on_finish(self, rid: int, t: float, n_tokens: int,
                  server) -> None:
        """Close a request's record: finish time, length, serving server."""
        r = self.records[rid]
        r.finished = t
        r.n_tokens = n_tokens
        r.server = server

    def on_reroute(self, rid: int) -> None:
        """Count one cross-server move (crash re-dispatch) for ``rid``."""
        self.records[rid].reroutes += 1

    def on_tick(self, t: float, queue_depth: int, n_servers: int,
                gpu_busy: int, tick_s: float) -> None:
        """One dense-tick gauge sample; accrues ``gpu_busy * tick_s``
        GPU-seconds (the event engine settles quiescent gaps separately —
        see ``ClusterRouter._settle_gap``)."""
        self.queue_depth.append((t, queue_depth))
        self.n_servers.append((t, n_servers))
        self.gpu_seconds += gpu_busy * tick_s

    def on_event(self, t: float, kind: str, detail: str = "") -> None:
        """Append to the free-form event log (spawns, crashes, retires,
        unservable requests, ...)."""
        self.events.append((t, kind, detail))

    def on_recovery(self, mode: str, rid: int, n_tokens: int) -> None:
        """One in-flight request resumed after a crash via ``mode``.

        ``n_tokens``: for "migrate", the prompt+prefix tokens whose state
        moved instead of being recomputed; for "reprefill", the tokens that
        had to be re-prefilled on the survivor; for "repartition", the
        tokens whose state stayed in place across the stage re-split
        (none re-prefilled, none moved off-server).
        """
        assert mode in ("migrate", "reprefill", "repartition"), mode
        self.recovery[f"mode_{mode}"] = \
            self.recovery.get(f"mode_{mode}", 0.0) + 1.0
        key = {"migrate": "migrated_tokens",
               "reprefill": "reprefill_tokens",
               "repartition": "repartition_tokens"}[mode]
        self.recovery[key] = self.recovery.get(key, 0.0) + float(n_tokens)

    def on_reconstruct(self, stats: Dict[str, float]) -> None:
        """Accumulate one partial-crash ``reconstruct_cache`` stats dict
        (per-layer work counts: kv_reused / full_prefill / window_recompute
        / layers_skipped / layers_recomputed + token counts); the
        reconstructed requests count toward ``mode_reconstruct``."""
        for k, v in stats.items():
            if k == "reconstructed_reqs":
                continue              # surfaced as mode_reconstruct below
            key = f"reconstruct_{k}"
            self.recovery[key] = self.recovery.get(key, 0.0) + float(v)
        self.recovery["mode_reconstruct"] = \
            self.recovery.get("mode_reconstruct", 0.0) \
            + float(stats.get("reconstructed_reqs", 0.0))

    def on_relay(self, stats: Dict[str, float]) -> None:
        """Accumulate one repartition ``relay_inflight`` stats dict (same
        per-layer work counts as reconstruction, landed in one scatter);
        requests themselves count toward ``mode_repartition`` via
        ``on_recovery`` — this records only the re-lay work."""
        for k, v in stats.items():
            if k == "relayed_reqs":
                continue              # surfaced as mode_repartition counts
            key = f"relay_{k}"
            self.recovery[key] = self.recovery.get(key, 0.0) + float(v)

    def on_multicast(self, stats: Dict[str, float]) -> None:
        """Fold one ``MulticastManager.stats()`` dict into the store
        (sum-accumulates, so multi-pool fleets can fold one manager per
        pool): peer vs host traffic split, re-roots after source crashes,
        retry/backoff attempts, graceful host fallbacks, stall time."""
        for k, v in stats.items():
            self.multicast[k] = self.multicast.get(k, 0.0) + float(v)

    def record_hotpath(self, stats: Dict[str, float]) -> None:
        """Accumulate one server's decode hot-path stats (see
        ``serving.engine.ContinuousBatcher.hotpath_stats``): counters sum
        across servers; compile counts sum too (each server jits its own
        functions), so per-server regressions stay visible in the total."""
        for k in ("n_decode_steps", "decode_time_s", "n_prefill_calls",
                  "n_prefill_reqs", "n_prefill_pipeline", "n_prefill_tokens",
                  "n_batched_imports", "n_relay_scatters",
                  "prefix_hits", "prefix_hit_tokens", "prefix_evictions",
                  "decode_compiles", "prefill_compiles"):
            self.hotpath[k] = self.hotpath.get(k, 0.0) + stats.get(k, 0.0)

    def on_state_tier(self, stats: Dict[str, float]) -> None:
        """Record the run's ``StateTier.stats()`` snapshot.  REPLACE
        semantics (not sum): the tier's counters are already lifetime
        totals for the shared instance, and the router re-folds them at
        ``finalize_metrics`` — summing would double-count every call."""
        self.state_tier = {k: float(v) for k, v in stats.items()}

    def record_coldstart(self, sid, rec: Dict) -> None:
        """Record one server's cold-start accounting (latest wins).
        ``sid`` is an int for a standalone router, "pool/sid" in a fleet."""
        self.coldstart[sid] = rec

    # ---- summary ----------------------------------------------------------
    def ttft_curve(self, qs: Tuple[float, ...] = (50, 90, 95, 99, 99.9)
                   ) -> Dict[str, float]:
        """TTFT percentile curve over completed requests — the shape the
        full-day replay benchmark records (one sort, many quantiles)."""
        ttfts = sorted(r.ttft for r in self.records.values()
                       if r.finished is not None and r.ttft is not None)
        out: Dict[str, float] = {}
        for q in qs:
            if not ttfts:
                out[f"ttft_p{q:g}"] = 0.0
                continue
            k = min(len(ttfts) - 1,
                    max(0, math.ceil(q / 100.0 * len(ttfts)) - 1))
            out[f"ttft_p{q:g}"] = ttfts[k]
        return out

    def slo_stats(self) -> Tuple[float, float]:
        """(attainment, n) over deadline-carrying requests: the fraction
        whose first token beat its absolute TTFT deadline.  A request that
        never produced a first token counts as a miss; requests without
        deadlines are excluded entirely."""
        with_slo = [r for r in self.records.values()
                    if r.deadline is not None]
        if not with_slo:
            return 0.0, 0.0
        hit = sum(1 for r in with_slo
                  if r.first_token is not None
                  and r.first_token <= r.deadline + 1e-9)
        return hit / len(with_slo), float(len(with_slo))

    def summary(self) -> Dict[str, float]:
        """Flatten the run to stable scalar keys: request counts, TTFT /
        TBT percentiles, SLO attainment, gauge maxima, GPU-seconds,
        throughput, plus always-present recovery and cold-start keys (so
        trajectory diffs line up across runs with and without crashes)."""
        done = [r for r in self.records.values() if r.finished is not None]
        ttfts = [r.ttft for r in done if r.ttft is not None]
        tbts = [r.tbt for r in done if r.tbt is not None]
        horizon = max((r.finished for r in done), default=0.0)
        slo_att, slo_n = self.slo_stats()
        out = {
            "n_requests": float(len(self.records)),
            "n_completed": float(len(done)),
            "n_rerouted": float(sum(1 for r in done if r.reroutes)),
            "ttft_mean": sum(ttfts) / len(ttfts) if ttfts else 0.0,
            "ttft_p50": percentile(ttfts, 50),
            "ttft_p90": percentile(ttfts, 90),
            "ttft_p99": percentile(ttfts, 99),
            "slo_attainment": slo_att,
            "slo_n": slo_n,
            "tbt_mean": sum(tbts) / len(tbts) if tbts else 0.0,
            "tbt_p50": percentile(tbts, 50),
            "tbt_p99": percentile(tbts, 99),
            "queue_depth_max": _gauge_max(self.queue_depth),
            "servers_max": _gauge_max(self.n_servers),
            "gpu_seconds": self.gpu_seconds,
            "degraded_seconds": self.degraded_seconds,
            "tokens_total": float(sum(r.n_tokens for r in done)),
            "throughput_tok_s": (sum(r.n_tokens for r in done) / horizon
                                 if horizon > 0 else 0.0),
        }
        for k, v in self.hotpath.items():
            out[f"hotpath_{k}"] = v
        # always-present recovery counters (zero when no crash happened) so
        # trajectory diffs and the bench JSON have stable keys
        rec = {"mode_migrate": 0.0, "mode_reprefill": 0.0,
               "mode_reconstruct": 0.0, "mode_repartition": 0.0,
               "migrated_tokens": 0.0, "reprefill_tokens": 0.0,
               "repartition_tokens": 0.0}
        rec.update(self.recovery)
        for k, v in rec.items():
            out[f"recovery_{k}"] = v
        # always-present multicast counters (zeros when multicast is off)
        mc = {"peer_bytes": 0.0, "host_bytes": 0.0, "peer_segments": 0.0,
              "host_segments": 0.0, "reroots": 0.0, "retries": 0.0,
              "host_fallbacks": 0.0, "stalled_seconds": 0.0}
        mc.update(self.multicast)
        for k, v in mc.items():
            out[f"multicast_{k}"] = v
        # always-present state-tier / prefix-cache counters (zeros when the
        # prefix cache is off) — the five keys the bench schema pins
        out["prefix_hits"] = self.hotpath.get("prefix_hits", 0.0)
        out["prefix_hit_tokens"] = self.hotpath.get("prefix_hit_tokens", 0.0)
        out["prefix_evictions"] = self.hotpath.get("prefix_evictions", 0.0)
        out["spill_resurrections"] = \
            self.state_tier.get("spill_resurrections", 0.0)
        out["spilled_bytes"] = self.state_tier.get("spilled_bytes", 0.0)
        if self.hotpath.get("decode_time_s", 0.0) > 0:
            out["hotpath_decode_steps_per_s"] = \
                self.hotpath["n_decode_steps"] / self.hotpath["decode_time_s"]
        # cold-start summary (always-present keys; zeros when no server
        # reported) — scale-up latency as the fleet experienced it
        ttrs = [r["time_to_ready"] for r in self.coldstart.values()
                if r.get("time_to_ready") is not None]
        ttfs = [r["time_to_fully_loaded"] for r in self.coldstart.values()
                if r.get("time_to_fully_loaded") is not None]
        out["coldstart_n_servers"] = float(len(self.coldstart))
        out["coldstart_time_to_ready_mean"] = \
            sum(ttrs) / len(ttrs) if ttrs else 0.0
        out["coldstart_time_to_ready_max"] = max(ttrs, default=0.0)
        out["coldstart_time_to_fully_loaded_mean"] = \
            sum(ttfs) / len(ttfs) if ttfs else 0.0
        out["coldstart_served_while_loading"] = float(sum(
            1 for r in self.coldstart.values()
            if r.get("served_while_loading")))
        out["coldstart_loaded_bytes"] = float(sum(
            r.get("loaded_bytes") or 0 for r in self.coldstart.values()))
        return out

    def summary_by_model(self) -> Dict[str, Dict[str, float]]:
        """Cross-pool view: per-model request-latency summaries (fleet
        runs tag records with their pool; untagged requests group under
        ``"default"``)."""
        groups: Dict[str, List[RequestRecord]] = {}
        for r in self.records.values():
            groups.setdefault(r.model or "default", []).append(r)
        out: Dict[str, Dict[str, float]] = {}
        for model, recs in sorted(groups.items()):
            done = [r for r in recs if r.finished is not None]
            ttfts = [r.ttft for r in done if r.ttft is not None]
            tbts = [r.tbt for r in done if r.tbt is not None]
            out[model] = {
                "n_requests": float(len(recs)),
                "n_completed": float(len(done)),
                "ttft_mean": sum(ttfts) / len(ttfts) if ttfts else 0.0,
                "ttft_p50": percentile(ttfts, 50),
                "ttft_p99": percentile(ttfts, 99),
                "tbt_p50": percentile(tbts, 50),
                "tbt_p99": percentile(tbts, 99),
                "tokens_total": float(sum(r.n_tokens for r in done)),
            }
        return out

    def to_json(self, path: Optional[str] = None) -> str:
        """Full dump — summary, per-model summaries, every request
        record, gauges, events — as a JSON string (also written to
        ``path`` when given)."""
        doc = {
            "summary": self.summary(),
            "models": self.summary_by_model(),
            "requests": [asdict(r) for r in
                         sorted(self.records.values(), key=lambda r: r.rid)],
            "queue_depth": self.queue_depth,
            "n_servers": self.n_servers,
            "events": self.events,
            "recovery": self.recovery,
            "coldstart": [self.coldstart[sid]
                          for sid in sorted(self.coldstart,
                                            key=lambda k: (str(type(k)),
                                                           k))],
        }
        blob = json.dumps(doc, indent=1)
        if path:
            with open(path, "w") as f:
                f.write(blob)
        return blob
