"""Cluster router: bursty traffic over autoscaled PipeBoost servers.

Each ``ClusterServer`` composes the two single-server pieces the repo
already proves correct: a ``PipeBoostEngine`` (pipelined cold start, crash,
recovery, strategy switch — core/engine.py) gating a continuous-batched
``ServingEngine`` (serving/engine.py).  The ``ClusterRouter`` owns a shared
logical clock, replays an arrival trace, dispatches to the least-loaded
admitting server, drives the autoscaler, and re-routes in-flight requests
off crashed servers — their generated prefix re-prefills on a survivor, so
greedy outputs are EXACTLY the tokens of a crash-free run (the cluster-level
analogue of the engine's KV-reconstruction exactness).

Server lifecycle::

    spawn -> loading --ready--> serving --crash(partial)--> recovering
    serving --crash(total)--> down --rejoin--> loading
    serving --idle + autoscaler--> retired

Time: one router tick = ``tick_s`` logical seconds; per tick a loading
server advances ``load_rounds_per_tick`` rounds and a serving server runs
one continuous-batching decode step.  On a real slice the same router runs
off the wall clock.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.cluster.autoscaler import Autoscaler
from repro.cluster.metrics import ClusterMetrics
from repro.cluster.traces import Arrival, prompt_tokens
from repro.configs.base import ArchConfig
from repro.core.adapter_scheduler import EpochSchedulerPolicy
from repro.core.engine import PipeBoostEngine
from repro.serving.engine import (ServeRequest, ServingEngine,
                                  quantized_greedy)


@dataclass
class ClusterConfig:
    n_devices: int = 2             # logical devices per server
    n_slots: int = 4               # continuous-batching slots per server
    max_len: int = 96
    tick_s: float = 0.05           # logical seconds per router tick
    load_rounds_per_tick: int = 1  # cold-start progress per tick
    recovery_ticks: int = 2        # service pause: crash -> rejoined chain
    epoch_budget: int = 4          # adapter epoch budget per server
    migrate_on_crash: bool = True  # KV-snapshot migration to survivors
    # (False = legacy re-prefill re-route; kept as the bench baseline)


class ClusterServer:
    """One autoscaled GPU-server replica."""

    def __init__(self, sid: int, cfg: ArchConfig, params, ccfg: ClusterConfig,
                 adapter_params: Optional[Dict[str, Any]] = None):
        self.sid = sid
        self.ccfg = ccfg
        self.engine = PipeBoostEngine(cfg, params, n_devices=ccfg.n_devices,
                                      max_len=ccfg.max_len)
        self.srv = ServingEngine(
            cfg, params, n_slots=ccfg.n_slots, max_len=ccfg.max_len,
            policy=EpochSchedulerPolicy(epoch_budget=ccfg.epoch_budget,
                                        max_batch=ccfg.n_slots),
            adapter_params=adapter_params or {})
        self.srv.batcher.sampler = quantized_greedy
        self.state = "loading"
        self.idle_ticks = 0
        self.served_while_loading = False   # admitted before fully loaded
        self._recover_left = 0
        self.last_recovery: Dict[str, float] = {}  # partial-crash rebuild
        # stats (kv_reconstruct work counts); read by the router right
        # after crash(), reset only at this server's next crash()

    # ---- scheduling surface ----------------------------------------------
    @property
    def admitting(self) -> bool:
        return self.state == "serving"

    @property
    def load(self) -> int:
        return self.srv.n_pending

    @property
    def oldest_queued_arrival(self) -> Optional[float]:
        """Earliest arrival among requests queued here without a first
        token yet (feeds the autoscaler's TTFT-SLO signal)."""
        waiting = [r.arrival for r in self.srv.queued_requests()
                   if r.first_token_at is None]
        return min(waiting) if waiting else None

    def submit(self, req: ServeRequest) -> None:
        self.srv.submit(req)

    # ---- lifecycle --------------------------------------------------------
    def tick(self, now: float) -> List[ServeRequest]:
        """Advance one router tick; returns requests finished this tick."""
        if self.state == "loading":
            for _ in range(self.ccfg.load_rounds_per_tick):
                self.engine.load_round()
            if self.engine.ready:       # viable chain => admit immediately
                self.state = "serving"
            return []
        if self.state == "recovering":
            self._recover_left -= 1
            if self._recover_left <= 0:
                self.engine.recover()   # re-plan + reload to a viable chain
                self.state = "serving"
            return []
        if self.state in ("down", "retired"):
            return []
        # serving: background fill until full, then the §4.3.3 switch
        if not self.engine.fully_loaded:
            self.engine.load_round()
            if self.srv.n_pending:
                self.served_while_loading = True
        elif self.engine.strategy == "pipeline":
            # crossover policy: switch to per-device serving as soon as the
            # full model is resident (rate-based crossover is a future knob)
            self.engine.maybe_switch_strategy(request_rate=0.0)
        done = self.srv.step(now=now)
        self.idle_ticks = 0 if self.srv.n_pending else self.idle_ticks + 1
        return done

    def crash(self, device_ids: Optional[Sequence[int]] = None
              ) -> List[ServeRequest]:
        """Kill devices (all of them by default).

        Whole-server crash: hands back every in-flight + queued request
        for cross-server re-routing; in-flight requests carry their
        ``KVSnapshot`` so survivors can resume them without re-prefill.

        Partial crash (survivors remain): the server keeps its requests —
        only the layers whose KV/state lived on the dead devices are
        rebuilt in place via ``reconstruct_cache`` (Q-only recompute for
        attention layers whose KV survived, §4.4.2); work stats land in
        ``last_recovery`` for the router's metrics.  Returns [].
        """
        ids = (list(device_ids) if device_ids is not None
               else [d.idx for d in self.engine.devices])
        dead = set(ids)
        survivors = [d.idx for d in self.engine.devices
                     if d.alive and d.idx not in dead]
        self.last_recovery = {}
        if not survivors:
            drained = self.srv.drain_inflight(
                export_state=self.ccfg.migrate_on_crash)
            self.engine.crash(ids)
            self.state = "down"
            return drained
        lost = self.engine.lost_state_layers(ids)   # before devices die
        self.engine.crash(ids)
        if any(lost):
            self.last_recovery = self.srv.reconstruct_inflight(
                [not l for l in lost])
        self.state = "recovering"
        self._recover_left = self.ccfg.recovery_ticks
        return []

    def rejoin(self) -> None:
        """Reboot a fully-down server back into the fleet (fresh cold
        start through the pipelined loader)."""
        self.engine.restart()
        self.state = "loading"

    def retire(self) -> List[ServeRequest]:
        # scale-down is voluntary: leftovers re-queue through dispatch
        leftovers = self.srv.drain_inflight(export_state=False)
        self.state = "retired"
        return leftovers


class ClusterRouter:
    """Trace replay + dispatch + autoscaling + crash handling."""

    def __init__(self, cfg: ArchConfig, params, *, n_servers: int = 2,
                 ccfg: Optional[ClusterConfig] = None,
                 autoscaler: Optional[Autoscaler] = None,
                 adapter_params: Optional[Dict[str, Any]] = None,
                 metrics: Optional[ClusterMetrics] = None):
        self.cfg = cfg
        self.params = params
        self.ccfg = ccfg or ClusterConfig()
        self.autoscaler = autoscaler
        self.adapter_params = adapter_params
        self.metrics = metrics or ClusterMetrics()
        self.clock = 0.0
        self.servers: List[ClusterServer] = []
        self.queue: Deque[ServeRequest] = deque()
        self._arrival_time: Dict[int, float] = {}
        self._rid = itertools.count()
        for _ in range(n_servers):
            self.spawn_server()

    # ---- fleet ops --------------------------------------------------------
    def spawn_server(self) -> ClusterServer:
        s = ClusterServer(len(self.servers), self.cfg, self.params,
                          self.ccfg, self.adapter_params)
        self.servers.append(s)
        self.metrics.on_event(self.clock, "spawn", f"server{s.sid}")
        return s

    def crash_server(self, sid: int,
                     device_ids: Optional[Sequence[int]] = None) -> None:
        """Crash a server and recover its work, cheapest mode first.

        Whole-server crash: each in-flight request's ``KVSnapshot``
        migrates to a survivor with a free slot (``admit_with_state`` —
        zero prompt tokens re-prefilled); requests no survivor can take
        fall back to the queue and re-prefill on admission (the legacy
        path, also the behaviour when ``migrate_on_crash`` is off).
        Partial crash: the server rebuilds only its dead layers in place
        (``reconstruct_cache``) and keeps serving; nothing re-routes.
        Per-mode counts and token savings land in the metrics' recovery
        counters.
        """
        server = self.servers[sid]
        drained = server.crash(device_ids)
        if server.last_recovery:
            self.metrics.on_reconstruct(server.last_recovery)
            self.metrics.on_event(
                self.clock, "recover",
                f"server{sid} reconstruct "
                f"reqs={server.last_recovery.get('reconstructed_reqs', 0):.0f} "
                f"kv_reused={server.last_recovery.get('kv_reused', 0):.0f} "
                f"full_prefill={server.last_recovery.get('full_prefill', 0):.0f}")
        migrated = reprefilled = 0
        leftovers: List[ServeRequest] = []
        for req in drained:
            if not req.generated:          # queued-only: plain re-dispatch
                req.snapshot = None
                leftovers.append(req)
                continue
            self.metrics.on_reroute(req.rid)   # mid-decode: moved servers
            n_state = req.snapshot.pos if req.snapshot is not None else 0
            if (self.ccfg.migrate_on_crash and req.snapshot is not None
                    and self._try_migrate(req)):
                migrated += 1
                self.metrics.on_recovery("migrate", req.rid, n_state)
            else:
                req.snapshot = None        # state lost: re-prefill path
                reprefilled += 1
                self.metrics.on_recovery(
                    "reprefill", req.rid,
                    len(req.tokens) + len(req.generated))
                leftovers.append(req)
        self.metrics.on_event(self.clock, "crash",
                              f"server{sid} migrated={migrated} "
                              f"reprefilled={reprefilled} "
                              f"requeued={len(leftovers) - reprefilled}")
        for req in reversed(leftovers):
            self.queue.appendleft(req)

    def _try_migrate(self, req: ServeRequest) -> bool:
        """Import ``req``'s snapshot into the least-loaded admitting
        survivor with a free slot; False when none can take it."""
        cands = [s for s in self.servers
                 if s.admitting and s.srv.batcher.free]
        for s in sorted(cands, key=lambda s: (s.load, s.sid)):
            s.srv.clock = max(s.srv.clock, self.clock)
            if s.srv.admit_with_state(req):
                return True
        return False

    def rejoin_server(self, sid: int) -> None:
        self.servers[sid].rejoin()
        self.metrics.on_event(self.clock, "rejoin", f"server{sid}")

    # ---- request path -----------------------------------------------------
    def submit(self, arrival: Arrival) -> int:
        if arrival.adapter and arrival.adapter not in (
                self.adapter_params or {}):
            raise ValueError(
                f"trace names adapter {arrival.adapter!r} but the router "
                f"has adapter_params for {sorted(self.adapter_params or {})}")
        rid = next(self._rid)
        req = ServeRequest(rid, prompt_tokens(arrival, self.cfg.vocab_size),
                           max_new_tokens=arrival.max_new_tokens,
                           adapter=arrival.adapter, arrival=arrival.time)
        self._arrival_time[rid] = arrival.time
        self.metrics.on_submit(rid, arrival.time)
        self.queue.append(req)
        return rid

    def _dispatch(self) -> None:
        # capacity-bounded: hand a server at most n_slots outstanding
        # requests; the backlog stays in the router queue so a server that
        # cold-starts mid-burst absorbs it (and the queue's wait keeps
        # feeding the autoscaler's SLO signal)
        while self.queue:
            cands = [s for s in self.servers
                     if s.admitting and s.load < self.ccfg.n_slots]
            if not cands:
                return
            target = min(cands, key=lambda s: (s.load, s.sid))
            # sync the server clock so dispatch-time stamps are router time
            target.srv.clock = max(target.srv.clock, self.clock)
            target.submit(self.queue.popleft())

    @property
    def pending(self) -> int:
        return len(self.queue) + sum(s.load for s in self.servers)

    # ---- main loop --------------------------------------------------------
    def tick(self) -> List[ServeRequest]:
        """One cluster tick: autoscale, dispatch, advance every server."""
        now = self.clock
        if self.autoscaler is not None:
            # head-of-line wait spans the router queue AND requests still
            # queued inside servers (dispatch drains the router queue every
            # tick, so server-side waiters carry the TTFT-SLO signal)
            waits = [self._arrival_time[r.rid] for r in self.queue]
            waits += [a for s in self.servers
                      if s.state not in ("down", "retired")
                      and (a := s.oldest_queued_arrival) is not None]
            oldest = now - min(waits) if waits else 0.0
            d = self.autoscaler.decide(now, self.pending, oldest,
                                       self.servers)
            for _ in range(d.spawn):
                self.metrics.on_event(now, "scale_up", "")
                self.spawn_server()
            for sid in d.retire:
                self.metrics.on_event(now, "retire", f"server{sid}")
                self.queue.extend(self.servers[sid].retire())
        self._dispatch()
        finished: List[ServeRequest] = []
        for s in self.servers:
            for r in s.tick(now):
                self.metrics.on_first_token(r.rid, r.first_token_at)
                self.metrics.on_finish(r.rid, r.finished_at,
                                       len(r.generated), s.sid)
                finished.append(r)
        busy = sum(self.ccfg.n_devices for s in self.servers
                   if s.state not in ("down", "retired"))
        self.metrics.on_tick(now, self.pending, len(
            [s for s in self.servers if s.state not in ("down", "retired")]),
            busy, self.ccfg.tick_s)
        self.clock = now + self.ccfg.tick_s
        return finished

    def run(self, trace: Sequence[Arrival], *, max_ticks: int = 200_000,
            crash_after_completions: Optional[int] = None,
            crash_server_id: int = 1,
            crash_devices: Optional[Sequence[int]] = None,
            rejoin_after_ticks: Optional[int] = None
            ) -> List[ServeRequest]:
        """Replay ``trace`` to completion; returns finished requests.

        ``crash_after_completions``: once that many requests completed,
        crash ``crash_server_id`` (all its devices unless ``crash_devices``
        narrows it) and re-route its work; with ``rejoin_after_ticks`` the
        downed server reboots into the fleet that many ticks later.
        """
        arrivals = sorted(trace, key=lambda a: a.time)
        i = 0
        completed: List[ServeRequest] = []
        crashed_at_tick: Optional[int] = None
        for t in range(max_ticks):
            while i < len(arrivals) and arrivals[i].time <= self.clock:
                self.submit(arrivals[i])
                i += 1
            completed.extend(self.tick())
            if (crash_after_completions is not None
                    and crashed_at_tick is None
                    and len(completed) >= crash_after_completions
                    and crash_server_id < len(self.servers)):
                self.crash_server(crash_server_id, crash_devices)
                crashed_at_tick = t
            if (crashed_at_tick is not None and rejoin_after_ticks is not None
                    and t == crashed_at_tick + rejoin_after_ticks
                    and self.servers[crash_server_id].state == "down"):
                self.rejoin_server(crash_server_id)
            if i >= len(arrivals) and self.pending == 0:
                break
        for s in self.servers:
            self.metrics.record_hotpath(s.srv.hotpath_stats())
        return completed
