"""Cluster router: bursty traffic over autoscaled PipeBoost servers.

Each ``ClusterServer`` composes the two single-server pieces the repo
already proves correct: a ``PipeBoostEngine`` (pipelined cold start, crash,
recovery, strategy switch — core/engine.py) gating a continuous-batched
``ServingEngine`` (serving/engine.py).  The ``ClusterRouter`` owns the
queue, the server lifecycle, and crash re-routing; the actual scheduling
decisions are delegated to pluggable pieces from ``cluster/scheduler.py``:

* a ``DispatchPolicy`` picks which queued request goes to which server
  (``LeastLoaded`` is the default and reproduces the pre-refactor
  routing; ``SloAware``/``AdapterAffine`` add deadline- and
  adapter-aware scheduling);
* a ``PlacementPolicy`` decides which adapters a spawned server preloads;
* a ``Clock`` (``LogicalClock`` ticks or ``WallClock`` off
  ``time.monotonic``) is injected through router, autoscaler, and
  metrics — simulation and real slices run the SAME code.

Crash re-routing is state-preserving: a crashed server's in-flight
requests carry their ``KVSnapshot`` to survivors, so greedy outputs are
EXACTLY the tokens of a crash-free run (the cluster-level analogue of the
engine's KV-reconstruction exactness).

Server lifecycle::

    spawn -> loading --ready--> serving --crash(partial)--> recovering
    serving --crash(total)--> down --rejoin--> loading
    serving --idle + autoscaler--> retired

Time: one router tick = ``tick_s`` clock seconds; per tick a loading
server advances ``load_rounds_per_tick`` rounds and a serving server runs
one continuous-batching decode step.

``run`` is a *discrete-event* loop (``engine="event"``, the default):
while any server has work (loading, recovering, decoding, background
fill) or the queue is non-empty, it processes every tick densely —
bit-identical to the legacy polling loop (``engine="tick"``, kept as the
equivalence oracle).  The moment the fleet goes quiescent it jumps the
clock straight to the next lifecycle event — next arrival, idle-retire
deadline, scheduled rejoin — aligned to the tick grid, so a full-day
trace with million-row gaps replays in seconds instead of polling every
server every ``tick_s``.  See ``docs/ARCHITECTURE.md`` § "Cluster: the
event engine".
"""
from __future__ import annotations

import itertools
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.cluster.autoscaler import Autoscaler
from repro.cluster.metrics import ClusterMetrics
from repro.cluster.multicast import MulticastConfig, MulticastManager
from repro.cluster.scheduler import (Clock, DispatchPolicy, LeastLoaded,
                                     LogicalClock, PlacementPolicy,
                                     PreloadAll)
from repro.cluster.state_tier import StateTier
from repro.cluster.traces import Arrival, arrival_stream, prompt_tokens
from repro.configs.base import ArchConfig
from repro.core.adapter_scheduler import EpochSchedulerPolicy
from repro.core.engine import PipeBoostEngine
from repro.core.simulator import GPU_PAPER, state_resurrect_time
from repro.serving.engine import (ServeRequest, ServingEngine,
                                  quantized_greedy)
from repro.serving.prefix_cache import PrefixCache


_PROMPT_STUBS: Dict[int, np.ndarray] = {}


def _prompt_stub(n: int) -> np.ndarray:
    """Shared zero prompt of length ``n`` for routers running with
    ``materialize_prompts=False`` (modeled backends never read the token
    values; ``len(req.tokens)`` stays truthful for accounting)."""
    arr = _PROMPT_STUBS.get(n)
    if arr is None:
        arr = _PROMPT_STUBS[n] = np.zeros(n, dtype=np.int32)
    return arr


@dataclass
class ClusterConfig:
    """Per-server shape + per-tick budgets shared by every server the
    router spawns (the field comments are the documentation)."""
    n_devices: int = 2             # logical devices per server
    n_slots: int = 4               # continuous-batching slots per server
    max_len: int = 96
    tick_s: float = 0.05           # logical seconds per router tick
    load_rounds_per_tick: int = 1  # cold-start progress per tick
    segments_per_round: int = 1    # per-device fill budget inside one round
    recovery_ticks: int = 2        # service pause: crash -> rejoined chain
    epoch_budget: int = 4          # adapter epoch budget per server
    migrate_on_crash: bool = True  # KV-snapshot migration to survivors
    # (False = legacy re-prefill re-route; kept as the bench baseline)
    partial_recovery: str = "reconstruct"  # partial-crash mode:
    # "reconstruct" = rebuild dead layers in place, stage plan unchanged
    # (PR 3 behaviour); "repartition" = elastic re-split of the pipeline
    # over the survivors (engine.repartition + one-scatter relay_inflight)
    repartition_ticks: int = 1     # service pause for a repartition — the
    # re-split reuses resident segments, so dispatch prices it cheaper
    # than the reconstruct pause (recovery_ticks)
    unservable_retries: int = 3    # placement-miss rechecks before the
    # "unservable" event fires (exponential backoff between rechecks)
    retry_backoff_s: float = 0.2   # first backoff; doubles per attempt
    multicast: Optional[MulticastConfig] = None  # peer-to-peer scale-out:
    # spawned servers pull their model copy from warm peers over ICI
    # (cluster/multicast.py) instead of each reading from host; None =
    # legacy host-only cold starts
    prefix_cache_bytes: int = 0    # per-server cross-request prefix cache
    # budget (serving/prefix_cache.py): admissions import cached prompt-
    # prefix KV and prefill only the suffix; 0 = off (legacy behaviour).
    # Pair with a router-level StateTier to keep the cache across
    # idle-retire/respawn cycles (the fleet state tier)


class ClusterServer:
    """One autoscaled GPU-server replica."""

    def __init__(self, sid: int, cfg: ArchConfig, params, ccfg: ClusterConfig,
                 adapter_params: Optional[Dict[str, Any]] = None):
        self.sid = sid
        self.ccfg = ccfg
        self.engine = PipeBoostEngine(cfg, params, n_devices=ccfg.n_devices,
                                      max_len=ccfg.max_len,
                                      segments_per_round=ccfg.segments_per_round)
        self.srv = ServingEngine(
            cfg, params, n_slots=ccfg.n_slots, max_len=ccfg.max_len,
            policy=EpochSchedulerPolicy(epoch_budget=ccfg.epoch_budget,
                                        max_batch=ccfg.n_slots),
            adapter_params=adapter_params or {})
        self.srv.batcher.sampler = quantized_greedy
        # overlapped cold start: on multi-device XLA backends the TTFT-
        # critical admission prefills lower through the engine's shard_map
        # pipeline belt until the strategy switch; on 1-device backends
        # enable_ returns False and the batcher keeps its single lowering
        if self.engine.enable_pipeline_prefill():
            self.srv.batcher.set_pipeline_prefill(
                self.engine.serving_pipeline_prefill,
                fits=self.engine.serving_pipeline_fits)
            self.srv.batcher.prefill_backend = (
                lambda: "pipeline" if self.engine.strategy == "pipeline"
                else "single")
        self.state = "loading"
        self.idle_ticks = 0
        self.idle_since: Optional[float] = None  # clock time idleness began
        self.served_while_loading = False   # admitted before fully loaded
        self.spawned_at = 0.0               # router stamps these in router
        self.ready_at: Optional[float] = None       # clock seconds
        self.fully_loaded_at: Optional[float] = None
        self._recover_left = 0
        self._ready_est: Optional[tuple] = None  # (now, s) rounds_to_ready
        self.last_recovery: Dict[str, float] = {}  # partial-crash rebuild
        # stats (kv_reconstruct work counts); read by the router right
        # after crash(), reset only at this server's next crash()
        self.recovery_mode: Optional[str] = None  # how the last partial
        # crash was handled ("reconstruct" | "repartition")
        # multicast scale-out: the router attaches a MulticastManager when
        # ClusterConfig.multicast is set; fill then arrives as peer
        # deliveries instead of host load rounds (until the copy lands)
        self._mc = None
        # fleet state tier: modeled seconds the spawn-time resurrect pull
        # takes (0 = cold spawn); overlaps the weight fill, priced into
        # predicted_ready_s so dispatch sees it
        self.resurrect_cost_s = 0.0

    # ---- state-tier surface ----------------------------------------------
    def attach_prefix_cache(self, cache) -> None:
        """Give this server's batcher a cross-request prefix cache (the
        router spawns one per server when
        ``ClusterConfig.prefix_cache_bytes`` is set)."""
        self.srv.attach_prefix_cache(cache)

    def predicted_prefix_tokens(self, req: ServeRequest) -> int:
        """Prompt tokens an admission of ``req`` would NOT re-prefill
        here (longest usable cached prefix; 0 without a cache) — the
        savings signal ``SloAware.prefix_bonus_s_per_token`` prices."""
        pc = self.srv.batcher.prefix_cache
        if pc is None:
            return 0
        return pc.match_len(self.srv.cfg.name, req.adapter,
                            np.asarray(req.tokens))

    def spill_state(self) -> Optional[Dict[str, Any]]:
        """Package warm state for the host tier at idle retirement: the
        prefix cache's entries (KV rows are already host numpy) plus the
        resident adapter params.  ``None`` when nothing warm is held —
        the router then retires without a spill."""
        pc = self.srv.batcher.prefix_cache
        entries = pc.export_entries() if pc is not None else []
        if not entries:
            return None
        return {"prefix_entries": entries,
                "adapters": dict(self.srv.adapter_params),
                "nbytes": int(sum(e.nbytes for _, e in entries))}

    def resurrect_from(self, bundle: Dict[str, Any],
                       cost_s: float = 0.0) -> int:
        """Seed this freshly spawned server from a spilled bundle:
        prefix entries merge into the attached cache, spilled adapters
        preload (widening ``can_serve``), and the modeled pull time is
        kept so dispatch prices readiness.  Returns entries admitted."""
        pc = self.srv.batcher.prefix_cache
        n = 0
        if pc is not None:
            n = pc.import_entries(bundle.get("prefix_entries", ()))
        for name, params in bundle.get("adapters", {}).items():
            self.srv.adapter_params.setdefault(name, params)
        self.resurrect_cost_s = max(self.resurrect_cost_s, cost_s)
        return n

    # ---- multicast surface ------------------------------------------------
    def mc_seg_bytes(self) -> List[int]:
        """Per-segment byte sizes of one model copy, in load-plan order —
        what the ``MulticastManager`` streams from peers."""
        return [s.bytes for s in self.engine.plan.segments]

    def mc_attach(self, manager) -> None:
        """Switch this server's cold-start fill to multicast deliveries
        (host load rounds pause until the peer copy has fully landed)."""
        self._mc = manager

    def mc_deliver(self, segments: Sequence[int]) -> None:
        """Materialise segments a peer finished streaming this tick: each
        lands on its serve-assignment owner device via the engine's
        targeted ``load_segment`` (tagged ``source="peer"``)."""
        for seg in sorted(segments):
            dev = self._mc_owner(seg)
            if dev is not None:
                self.engine.load_segment(dev, seg, source="peer")

    def _mc_owner(self, seg: int) -> Optional[int]:
        """Alive device that serves ``seg`` under the current plan (lowest
        alive device when the owner died mid-fill; None = all dead)."""
        alive = {d.idx for d in self.engine.devices if d.alive}
        for dev, segs in self.engine.plan.serve_assignment.items():
            if seg in segs and dev in alive:
                return dev
        return min(alive) if alive else None

    @property
    def mc_active_sends(self) -> int:
        """Outbound multicast transfers this server is sourcing (0 when
        multicast is off) — priced by ``SloAware.source_penalty_s``."""
        return 0 if self._mc is None else self._mc.active_sends(self.sid)

    # ---- scheduling surface ----------------------------------------------
    @property
    def admitting(self) -> bool:
        return self.state == "serving"

    @property
    def load(self) -> int:
        return self.srv.n_pending

    @property
    def needs_tick(self) -> bool:
        """Would a tick do real work on this server?  False == quiescent:
        the event engine may jump the clock past it.  Loading/recovering
        servers always progress per tick; a serving server progresses
        while it has pending/in-flight requests or background fill left.
        (A fully-loaded idle server's tick only bumps idle counters — the
        idle-retire *deadline* replaces that under the event engine.)"""
        if self.state in ("down", "retired"):
            return False
        if self.state in ("loading", "recovering"):
            return True
        return bool(self.srv.n_pending) or not self.engine.fully_loaded

    def can_serve(self, req: ServeRequest) -> bool:
        """Does this server hold the weights the request needs?  Placement
        may have preloaded only a subset of the pool's adapters."""
        return req.adapter is None or req.adapter in self.srv.adapter_params

    @property
    def degraded_devices(self) -> int:
        """Dead devices on a server still in the fleet — the capacity a
        repartitioned server keeps *not* having (metrics accrue
        ``degraded_seconds`` off this, per tick)."""
        if self.state in ("down", "retired"):
            return 0
        return sum(1 for d in self.engine.devices if not d.alive)

    def predicted_ready_s(self, now: float) -> float:
        """Predicted seconds until this server can admit (0 when serving).

        Loading servers estimate off the engine's load-plan progress
        (``rounds_to_ready`` — cold-start progress, the signal
        ``EngineStatus.time_to_ready`` stamps once it flips); recovering
        servers off the remaining recovery ticks.  Down/retired servers
        are never admittable (+inf).

        The load-plan simulation only changes when ``load_round`` runs
        (once per tick), and dispatch evaluates every (request, server)
        pair against one tick's ``now`` — so the estimate is cached per
        ``now`` instead of re-simulated per queue entry."""
        if self.state == "serving":
            return 0.0
        if self.state == "loading":
            if self._ready_est is None or self._ready_est[0] != now:
                rounds = self.engine.rounds_to_ready()
                ticks = math.ceil(rounds
                                  / max(1, self.ccfg.load_rounds_per_tick))
                self._ready_est = (now, ticks * self.ccfg.tick_s)
            est = self._ready_est[1]
            if self.resurrect_cost_s:
                # the state-tier pull overlaps the weight fill; it only
                # extends readiness when it outlasts the remaining load
                est = max(est, self.spawned_at + self.resurrect_cost_s - now)
            return est
        if self.state == "recovering":
            return max(0, self._recover_left) * self.ccfg.tick_s
        return math.inf

    @property
    def oldest_queued_arrival(self) -> Optional[float]:
        """Earliest arrival among requests queued here without a first
        token yet (feeds the autoscaler's TTFT-SLO signal)."""
        waiting = [r.arrival for r in self.srv.queued_requests()
                   if r.first_token_at is None]
        return min(waiting) if waiting else None

    def submit(self, req: ServeRequest) -> None:
        """Hand a dispatched request to this server's serving engine."""
        self.srv.submit(req)

    # ---- lifecycle --------------------------------------------------------
    def tick(self, now: float) -> List[ServeRequest]:
        """Advance one router tick; returns requests finished this tick."""
        if self.state == "loading":
            # under multicast the copy streams in from peers (delivered by
            # the router pre-tick); host rounds stay paused until it lands,
            # then resume for replication.  receiver_done is True for
            # unknown sids, so a detached/foreign server self-heals to host.
            if self._mc is None or self._mc.receiver_done(self.sid):
                for _ in range(self.ccfg.load_rounds_per_tick):
                    self.engine.load_round()
            if not self.engine.ready:
                return []
            # viable chain => serve THIS tick (the overlap: the queue
            # starts draining the moment ready flips, not a tick later;
            # background fill of the remaining segments continues below)
            self.state = "serving"
            self.ready_at = now
        if self.state == "recovering":
            self._recover_left -= 1
            if self._recover_left <= 0:
                if self.recovery_mode != "repartition":
                    # re-plan + reload to a viable chain; a repartition
                    # already did both synchronously inside crash()
                    self.engine.recover()
                self.state = "serving"
            return []
        if self.state in ("down", "retired"):
            return []
        # serving: background fill until full, then the §4.3.3 switch
        if not self.engine.fully_loaded:
            if self._mc is None or self._mc.receiver_done(self.sid):
                self.engine.load_round()
            if self.srv.n_pending:
                self.served_while_loading = True
        elif self.engine.strategy == "pipeline":
            # crossover policy: switch to per-device serving as soon as the
            # full model is resident (rate-based crossover is a future knob)
            self.engine.maybe_switch_strategy(request_rate=0.0)
        if self.fully_loaded_at is None and self.engine.fully_loaded:
            self.fully_loaded_at = now
        done = self.srv.step(now=now)
        if self.srv.n_pending:
            self.idle_ticks = 0
            self.idle_since = None
        else:
            self.idle_ticks += 1
            if self.idle_since is None:
                self.idle_since = now  # retire deadline = idle_since + idle_s
        return done

    def cold_start_record(self) -> Dict[str, Any]:
        """Per-server cold-start accounting (logical clock + the engine's
        wall-clock/byte accounting) for the cluster metrics JSON."""
        eng = self.engine.cold_start_stats()
        rdy = self.ready_at
        ful = self.fully_loaded_at
        # clamp: under a wall clock the spawn stamp can land microseconds
        # after the tick's ``now`` capture
        return {
            "server": self.sid,
            "time_to_ready": (None if rdy is None
                              else max(0.0, rdy - self.spawned_at)),
            "time_to_fully_loaded": (None if ful is None
                                     else max(0.0, ful - self.spawned_at)),
            "served_while_loading": self.served_while_loading,
            "wall_time_to_ready": eng["time_to_ready"],
            "wall_time_to_fully_loaded": eng["time_to_fully_loaded"],
            "loaded_bytes": eng["loaded_bytes"],
            "total_bytes": eng["total_bytes"],
            "n_rounds": eng["n_rounds"],
        }

    def crash(self, device_ids: Optional[Sequence[int]] = None
              ) -> List[ServeRequest]:
        """Kill devices (all of them by default).

        Whole-server crash: hands back every in-flight + queued request
        for cross-server re-routing; in-flight requests carry their
        ``KVSnapshot`` so survivors can resume them without re-prefill.

        Partial crash (survivors remain): the server keeps its requests.
        Under ``partial_recovery="reconstruct"`` only the layers whose
        KV/state lived on the dead devices are rebuilt in place via
        ``reconstruct_cache`` (Q-only recompute for attention layers whose
        KV survived, §4.4.2), stage plan unchanged.  Under
        ``"repartition"`` the engine elastically re-splits the pipeline
        over the survivors (``engine.repartition``) and the live batch is
        re-laid in ONE donated scatter (``relay_inflight``) — the service
        pause is the shorter ``repartition_ticks``.  Work stats land in
        ``last_recovery`` for the router's metrics.  Returns [].
        """
        ids = (list(device_ids) if device_ids is not None
               else [d.idx for d in self.engine.devices])
        # the cached rounds-to-ready estimate described the pre-crash load
        # plan; scoring a post-crash server with it would let SloAware
        # route onto a chain that no longer exists
        self._ready_est = None
        dead = set(ids)
        survivors = [d.idx for d in self.engine.devices
                     if d.alive and d.idx not in dead]
        self.last_recovery = {}
        self.recovery_mode = None
        if not survivors:
            drained = self.srv.drain_inflight(
                export_state=self.ccfg.migrate_on_crash)
            self.engine.crash(ids)
            self.state = "down"
            return drained
        lost = self.engine.lost_state_layers(ids)   # before devices die
        if self.ccfg.partial_recovery == "repartition":
            self.engine.repartition(dead=ids)   # crash + re-split + reload
            if any(lost):
                self.last_recovery = self.srv.relay_inflight(
                    [not l for l in lost])
            self.recovery_mode = "repartition"
            self.state = "recovering"
            self._recover_left = self.ccfg.repartition_ticks
            return []
        self.engine.crash(ids)
        if any(lost):
            self.last_recovery = self.srv.reconstruct_inflight(
                [not l for l in lost])
        self.recovery_mode = "reconstruct"
        self.state = "recovering"
        self._recover_left = self.ccfg.recovery_ticks
        return []

    def rejoin(self) -> None:
        """Reboot a fully-down server back into the fleet (fresh cold
        start through the pipelined loader)."""
        self.engine.restart()
        self.state = "loading"
        self.ready_at = None
        self.fully_loaded_at = None
        self.served_while_loading = False
        self._ready_est = None   # estimate belongs to the pre-crash plan

    def rejoin_devices(self, device_ids: Sequence[int]) -> None:
        """Device-granular rejoin on a LIVE server: dead devices come back
        empty and the stage plan widens over them.  Under
        ``partial_recovery="repartition"`` the engine re-splits in flight
        (in-flight requests keep decoding bit-identically); otherwise the
        devices just revive into the existing plan.  Either way the
        serving tick's background ``load_round`` refills them, since
        ``fully_loaded`` flips back to False."""
        self._ready_est = None
        if self.ccfg.partial_recovery == "repartition":
            self.engine.repartition(revive=list(device_ids))
        else:
            self.engine.revive(list(device_ids))

    def retire(self) -> List[ServeRequest]:
        """Voluntary scale-down: drain and hand back any leftovers (they
        re-queue through dispatch), then leave the fleet for good."""
        leftovers = self.srv.drain_inflight(export_state=False)
        self.state = "retired"
        return leftovers


class ClusterRouter:
    """Trace replay + queue + server lifecycle + crash handling; scheduling
    decisions delegate to the injected dispatch/placement policies."""

    def __init__(self, cfg: ArchConfig, params, *, n_servers: int = 2,
                 ccfg: Optional[ClusterConfig] = None,
                 autoscaler: Optional[Autoscaler] = None,
                 adapter_params: Optional[Dict[str, Any]] = None,
                 metrics: Optional[ClusterMetrics] = None,
                 dispatch: Optional[DispatchPolicy] = None,
                 placement: Optional[PlacementPolicy] = None,
                 clock: Optional[Clock] = None,
                 model: Optional[str] = None,
                 rid_counter: Optional[itertools.count] = None,
                 server_factory=None,
                 materialize_prompts: bool = True,
                 state_tier: Optional[StateTier] = None):
        self.cfg = cfg
        self.params = params
        self.ccfg = ccfg or ClusterConfig()
        self.autoscaler = autoscaler
        self.adapter_params = adapter_params
        self.metrics = metrics or ClusterMetrics()
        self.dispatch = dispatch or LeastLoaded()
        self.placement = placement or PreloadAll()
        self._clock: Clock = clock or LogicalClock()
        self.metrics.clock = self._clock
        self.model = model                  # pool name in a multi-model fleet
        # pluggable backend: ``server_factory(sid, cfg, params, ccfg,
        # adapters) -> ClusterServer-like`` swaps the JAX-backed server for
        # a modeled one (cluster/simserver.py) in full-day trace replays
        self.server_factory = server_factory or ClusterServer
        # False skips per-request prompt RNG materialization (a modeled
        # backend never reads the token values; million-row replays skip
        # one rng construction per arrival)
        self.materialize_prompts = materialize_prompts
        self.servers: List[ClusterServer] = []
        self.queue: Deque[ServeRequest] = deque()
        self._recent_adapters: Deque[str] = deque(maxlen=256)
        self._prev_tick_t: Optional[float] = None
        self._unservable_flagged: set = set()   # rids already evented
        self._unchecked: List[ServeRequest] = []  # new since last scan
        self._recheck_unservable = False        # fleet changed: rescan all
        # bounded-retry state for placement misses: rid -> (failed
        # attempts, clock time of the next recheck); the "unservable"
        # event only fires once the retries are exhausted
        self._retry_state: Dict[int, tuple] = {}
        self._stuck_ticks = 0                   # liveness: no-progress run
        # a fleet shares one rid counter across pools so metrics keys are
        # globally unique; standalone routers own theirs
        self._rid = rid_counter if rid_counter is not None else \
            itertools.count()
        # peer-to-peer multicast scale-out (cluster/multicast.py): every
        # spawned server registers as a receiver, warm peers relay
        self.multicast = (MulticastManager(self.ccfg.multicast)
                          if self.ccfg.multicast is not None else None)
        # fleet state tier (cluster/state_tier.py): idle retirements spill
        # warm prefix-cache/adapter state here; later spawns for the same
        # pool resurrect it.  Shared fleet-wide; None = legacy discard
        self.state_tier = state_tier
        for _ in range(n_servers):
            self.spawn_server()

    @property
    def clock(self) -> float:
        """Current router time in seconds (reads the injected clock)."""
        return self._clock.now()

    def _metrics_sid(self, sid: int):
        """Server key in shared (cross-pool) metrics stores."""
        return f"{self.model}/{sid}" if self.model is not None else sid

    # ---- fleet ops --------------------------------------------------------
    def spawn_server(self) -> ClusterServer:
        """Cold-start one server via ``server_factory``, preloading the
        adapter subset the placement policy picks from recent traffic."""
        aps = self.placement.adapters_for(self.adapter_params or {},
                                          list(self._recent_adapters))
        s = self.server_factory(len(self.servers), self.cfg, self.params,
                                self.ccfg, aps)
        s.spawned_at = self.clock
        self.servers.append(s)
        if (self.ccfg.prefix_cache_bytes > 0
                and hasattr(s, "attach_prefix_cache")):
            s.attach_prefix_cache(PrefixCache(self.ccfg.prefix_cache_bytes))
        if self.state_tier is not None and hasattr(s, "resurrect_from"):
            bundle = self.state_tier.take(self.model)
            if bundle is not None:
                # price the host->device pull: concurrent resurrect
                # streams share the aggregate host bandwidth, exactly
                # like simultaneous host cold-start fills
                hw = (self.ccfg.multicast.hw
                      if self.ccfg.multicast is not None else GPU_PAPER)
                concurrent = 1 + sum(
                    1 for x in self.servers
                    if x is not s and x.state == "loading"
                    and getattr(x, "resurrect_cost_s", 0.0) > 0.0
                    and self.clock - x.spawned_at < x.resurrect_cost_s)
                cost = state_resurrect_time(int(bundle.get("nbytes", 0)),
                                            hw, concurrent)
                n_ent = s.resurrect_from(bundle, cost_s=cost)
                self.metrics.on_event(
                    self.clock, "resurrect",
                    f"server{self._metrics_sid(s.sid)} entries={n_ent} "
                    f"bytes={bundle.get('nbytes', 0)} "
                    f"modeled_pull={cost:.3f}s")
        if self.multicast is not None and hasattr(s, "mc_seg_bytes"):
            self.multicast.register_receiver(s.sid, s.mc_seg_bytes())
            s.mc_attach(self.multicast)
        self._recheck_unservable = True
        self.metrics.on_event(self.clock, "spawn",
                              f"server{self._metrics_sid(s.sid)} "
                              f"adapters={sorted(aps)}")
        return s

    def crash_server(self, sid: int,
                     device_ids: Optional[Sequence[int]] = None) -> None:
        """Crash a server and recover its work, cheapest mode first.

        Whole-server crash: each in-flight request's ``KVSnapshot``
        migrates to a survivor with a free slot (``admit_with_state`` —
        zero prompt tokens re-prefilled); requests no survivor can take
        fall back to the queue and re-prefill on admission (the legacy
        path, also the behaviour when ``migrate_on_crash`` is off).
        Partial crash: the server rebuilds only its dead layers in place
        (``reconstruct_cache``) and keeps serving; nothing re-routes.
        Per-mode counts and token savings land in the metrics' recovery
        counters.
        """
        server = self.servers[sid]
        drained = server.crash(device_ids)
        if self.multicast is not None and server.state == "down":
            # the victim leaves the multicast tree: its inbound transfer
            # dies with it and every transfer it was sourcing re-roots
            # onto surviving holders (receivers resume, never restart)
            self.multicast.remove(sid)
        if getattr(server, "recovery_mode", None) == "repartition":
            # in-place elastic re-split: every live request stays put with
            # its whole decoded prefix — count each as repartition-
            # recovered (zero tokens re-prefilled, zero migrated off)
            if server.last_recovery:
                self.metrics.on_relay(server.last_recovery)
            n_rep = 0
            for _, req in sorted(server.srv.batcher.active.items()):
                self.metrics.on_recovery(
                    "repartition", req.rid,
                    len(req.tokens) + max(0, len(req.generated) - 1))
                n_rep += 1
            self.metrics.on_event(
                self.clock, "recover",
                f"server{self._metrics_sid(sid)} repartition reqs={n_rep} "
                f"relayed={server.last_recovery.get('relayed_reqs', 0):.0f} "
                f"kv_reused={server.last_recovery.get('kv_reused', 0):.0f} "
                f"full_prefill="
                f"{server.last_recovery.get('full_prefill', 0):.0f}")
        elif server.last_recovery:
            self.metrics.on_reconstruct(server.last_recovery)
            self.metrics.on_event(
                self.clock, "recover",
                f"server{self._metrics_sid(sid)} reconstruct "
                f"reqs={server.last_recovery.get('reconstructed_reqs', 0):.0f} "
                f"kv_reused={server.last_recovery.get('kv_reused', 0):.0f} "
                f"full_prefill={server.last_recovery.get('full_prefill', 0):.0f}")
        migrated = reprefilled = 0
        leftovers: List[ServeRequest] = []
        mid_decode: List[ServeRequest] = []
        for req in drained:
            if not req.generated:          # queued-only: plain re-dispatch
                req.snapshot = None
                leftovers.append(req)
            else:
                mid_decode.append(req)
        # Batched migration: survivors absorb victims least-loaded-first,
        # each taking as many snapshots as it has free slots in ONE donated
        # scatter (admit_with_state_batch) — not one import dispatch per
        # victim.  Requests no survivor can take fall back to re-prefill.
        n_state = {req.rid: (req.snapshot.pos if req.snapshot is not None
                             else 0) for req in mid_decode}
        accepted_ids = set()
        if self.ccfg.migrate_on_crash:
            pending = [r for r in mid_decode if r.snapshot is not None]
            cands = [s for s in self.servers
                     if s.admitting and s.srv.batcher.free]
            for s in sorted(cands, key=lambda s: (s.load, s.sid)):
                if not pending:
                    break
                # offer the whole backlog: the importer itself caps at its
                # free slots, and slicing here would let epoch-barrier
                # rejects starve migratable requests behind them
                s.srv.clock = max(s.srv.clock, self.clock)
                for r in s.srv.admit_with_state_batch(pending):
                    accepted_ids.add(r.rid)
                pending = [r for r in pending if r.rid not in accepted_ids]
        for req in mid_decode:
            self.metrics.on_reroute(req.rid)   # mid-decode: moved servers
            if req.rid in accepted_ids:
                migrated += 1
                self.metrics.on_recovery("migrate", req.rid,
                                         n_state[req.rid])
            else:
                req.snapshot = None        # state lost: re-prefill path
                reprefilled += 1
                self.metrics.on_recovery(
                    "reprefill", req.rid,
                    len(req.tokens) + len(req.generated))
                leftovers.append(req)
        self.metrics.on_event(self.clock, "crash",
                              f"server{self._metrics_sid(sid)} migrated={migrated} "
                              f"reprefilled={reprefilled} "
                              f"requeued={len(leftovers) - reprefilled}")
        for req in reversed(leftovers):
            self.queue.appendleft(req)
        self._recheck_unservable = True

    def rejoin_server(self, sid: int,
                      device_ids: Optional[Sequence[int]] = None) -> None:
        """Reboot a downed server into the fleet (fresh cold start; its
        spawn stamp resets so cold-start metrics track the reboot) — or,
        with ``device_ids`` on a LIVE server, rejoin just those devices
        (``ClusterServer.rejoin_devices``: the pipeline widens back
        without draining).  A retired server never rejoins: retirement is
        final (the race with a scheduled rejoin resolves to a no-op,
        surfaced as a ``rejoin_skipped`` event)."""
        server = self.servers[sid]
        if server.state == "retired":
            self.metrics.on_event(self.clock, "rejoin_skipped",
                                  f"server{self._metrics_sid(sid)} retired")
            return
        if device_ids is not None and server.state != "down":
            server.rejoin_devices(device_ids)
            self._recheck_unservable = True
            self.metrics.on_event(self.clock, "rejoin",
                                  f"server{self._metrics_sid(sid)} "
                                  f"devices={sorted(device_ids)}")
            return
        server.rejoin()
        server.spawned_at = self.clock
        if self.multicast is not None and hasattr(server, "mc_seg_bytes"):
            # the reboot is a fresh receiver: it re-enters the multicast
            # tree with an empty segment set and fills from warm peers
            self.multicast.register_receiver(sid, server.mc_seg_bytes())
            server.mc_attach(self.multicast)
        self._recheck_unservable = True
        self.metrics.on_event(self.clock, "rejoin",
                              f"server{self._metrics_sid(sid)}")

    # ---- request path -----------------------------------------------------
    def submit(self, arrival: Arrival) -> int:
        """Turn one trace ``Arrival`` into a queued ``ServeRequest``
        (prompt materialized or stubbed, absolute deadline stamped) and
        open its metrics record; returns the assigned rid."""
        if arrival.adapter and arrival.adapter not in (
                self.adapter_params or {}):
            raise ValueError(
                f"trace names adapter {arrival.adapter!r} but the router "
                f"has adapter_params for {sorted(self.adapter_params or {})}")
        rid = next(self._rid)
        if self.materialize_prompts:
            toks = prompt_tokens(arrival, self.cfg.vocab_size)
        else:
            toks = _prompt_stub(arrival.prompt_len)
        req = ServeRequest(rid, toks,
                           max_new_tokens=arrival.max_new_tokens,
                           adapter=arrival.adapter, arrival=arrival.time,
                           model=arrival.model or self.model,
                           deadline=(None if arrival.ttft_deadline_s is None
                                     else arrival.time
                                     + arrival.ttft_deadline_s))
        if arrival.adapter:
            self._recent_adapters.append(arrival.adapter)
        self.metrics.on_submit(rid, arrival.time, model=req.model,
                               deadline=req.deadline)
        self.queue.append(req)
        self._unchecked.append(req)
        return rid

    def _dispatch(self, now: Optional[float] = None) -> None:
        # capacity-bounded: hand a server at most n_slots outstanding
        # requests; the backlog stays in the router queue so a server that
        # cold-starts mid-burst absorbs it (and the queue's wait keeps
        # feeding the autoscaler's SLO signal).  The (request, server)
        # pairing itself is the injected policy's call.
        if now is None:
            now = self.clock
        # visibility: a request no provisioned server can serve (placement
        # preloaded subsets) is skipped by the policies, not dispatched —
        # surfaced once per request, after a bounded number of backoff-
        # spaced rechecks (the fleet may still spawn/rejoin a server that
        # preloads it).  Lazy: only requests queued since the last scan are
        # checked, plus requests whose backoff deadline passed, plus one
        # full rescan whenever the fleet composition changes (spawn /
        # crash / rejoin / retire) — not O(queue) every tick.
        live = [s for s in self.servers
                if s.state not in ("down", "retired")]
        to_check = (list(self.queue) if self._recheck_unservable
                    else list(self._unchecked))
        if self._retry_state:
            due = {rid for rid, (_, t_due) in self._retry_state.items()
                   if t_due <= now + 1e-9}
            if due:
                seen = {r.rid for r in to_check}
                to_check.extend(r for r in self.queue
                                if r.rid in due and r.rid not in seen)
        for req in to_check:
            if req.rid in self._unservable_flagged:
                continue
            if any(s.can_serve(req) for s in live):
                self._retry_state.pop(req.rid, None)  # servable again
                continue
            n, t_due = self._retry_state.get(req.rid, (0, -math.inf))
            if t_due > now + 1e-9:
                continue               # backoff not elapsed: recheck later
            n += 1
            if n > self.ccfg.unservable_retries:
                self._retry_state.pop(req.rid, None)
                self._unservable_flagged.add(req.rid)
                self.metrics.on_event(
                    now, "unservable",
                    f"req{req.rid} adapter={req.adapter!r}: no live server "
                    f"preloads it after {self.ccfg.unservable_retries} "
                    "retries (placement)")
            else:
                delay = self.ccfg.retry_backoff_s * (2 ** (n - 1))
                self._retry_state[req.rid] = (n, now + delay)
                self.metrics.on_event(
                    now, "retry",
                    f"req{req.rid} adapter={req.adapter!r} attempt "
                    f"{n}/{self.ccfg.unservable_retries} "
                    f"next_check=+{delay:.2f}s")
        self._unchecked = []
        self._recheck_unservable = False
        if not hasattr(self.dispatch, "select_many"):
            # compatibility: a select-only third-party policy dispatches
            # one request per call, exactly the pre-batching loop
            while self.queue:
                picked = self.dispatch.select(self.queue, self.servers, now,
                                              self.ccfg)
                if picked is None:
                    return
                idx, target = picked
                req = self.queue[idx]
                del self.queue[idx]
                target.srv.clock = max(target.srv.clock, now)
                target.submit(req)
            return
        while self.queue:
            # one batched round: the policy pairs every placeable request
            # in a single queue sort + scoring sweep (virtual load
            # accounting keeps it equivalent to the repeated-select loop)
            picks = self.dispatch.select_many(self.queue, self.servers, now,
                                              self.ccfg)
            if not picks:
                return
            reqs = list(self.queue)
            taken = set()
            for idx, target in picks:
                req = reqs[idx]
                taken.add(idx)
                # sync the server clock so dispatch stamps are router time
                target.srv.clock = max(target.srv.clock, now)
                target.submit(req)
            if len(taken) == len(reqs):
                self.queue.clear()
            else:
                self.queue = deque(r for j, r in enumerate(reqs)
                                   if j not in taken)

    @property
    def pending(self) -> int:
        return len(self.queue) + sum(s.load for s in self.servers)

    def stalled(self, arrivals_left: bool, patience: int = 500) -> bool:
        """Liveness guard for ``run``-style loops: True once the router
        has spent ``patience`` consecutive ticks with work stuck in the
        router queue, nothing in flight, no future arrivals, and no
        server mid-cold-start/recovery — i.e. no event left that could
        ever dispatch the remainder (requests whose adapter no
        provisioned server preloads).  Without this, an unservable
        request would spin the replay loop to ``max_ticks`` silently."""
        stuck = (not arrivals_left and self.pending > 0
                 and self.pending == len(self.queue)
                 and not any(s.state in ("loading", "recovering")
                             for s in self.servers))
        self._stuck_ticks = self._stuck_ticks + 1 if stuck else 0
        if self._stuck_ticks == patience + 1:   # event once, at the crossing
            self.metrics.on_event(
                self.clock, "starved",
                f"{len(self.queue)} request(s) undispatchable "
                f"(no server can serve them); giving up the replay")
        return self._stuck_ticks > patience

    # ---- main loop --------------------------------------------------------
    def tick(self, *, advance: bool = True,
             now: Optional[float] = None) -> List[ServeRequest]:
        """One cluster tick: autoscale, dispatch, advance every server.

        ``advance=False`` leaves the clock alone — a multi-pool fleet
        ticks every pool against the shared clock, then advances it once;
        the fleet also freezes one ``now`` for all pools so their samples
        share a timestamp even under a wall clock.
        """
        if now is None:
            now = self.clock
        if self.autoscaler is not None:
            # head-of-line wait spans the router queue AND requests still
            # queued inside servers (dispatch drains the router queue every
            # tick, so server-side waiters carry the TTFT-SLO signal)
            waits = [r.arrival for r in self.queue]
            waits += [a for s in self.servers
                      if s.state not in ("down", "retired")
                      and (a := s.oldest_queued_arrival) is not None]
            oldest = now - min(waits) if waits else 0.0
            d = self.autoscaler.decide(now, self.pending, oldest,
                                       self.servers, tick_s=self.ccfg.tick_s)
            for _ in range(d.spawn):
                self.metrics.on_event(now, "scale_up", "")
                self.spawn_server()
            for sid in d.retire:
                self.metrics.on_event(now, "retire",
                                      f"server{self._metrics_sid(sid)}")
                victim = self.servers[sid]
                if (self.state_tier is not None
                        and hasattr(victim, "spill_state")):
                    # idle scale-down keeps the warm state: prefix-cache
                    # rows + resident adapters spill to the host tier
                    # instead of dying with the replica
                    bundle = victim.spill_state()
                    if bundle is not None:
                        self.state_tier.spill(self.model, bundle)
                        self.metrics.on_event(
                            now, "spill",
                            f"server{self._metrics_sid(sid)} "
                            f"bytes={bundle['nbytes']} "
                            f"entries={len(bundle['prefix_entries'])}")
                self.queue.extend(victim.retire())
                if self.multicast is not None:
                    self.multicast.remove(sid)
                self._recheck_unservable = True
        self._dispatch(now)
        if self.multicast is not None:
            # advance peer transfers one tick and hand completed segments
            # to their receivers BEFORE the servers tick — a copy that
            # completes this tick flips ready and serves this same tick
            # (the PR 4 overlap, now fed over ICI instead of host)
            for msid, segs in self.multicast.advance(
                    now, self.ccfg.tick_s).items():
                self.servers[msid].mc_deliver(segs)
        finished: List[ServeRequest] = []
        for s in self.servers:
            was_loading = s.state == "loading"
            for r in s.tick(now):
                self.metrics.on_first_token(r.rid, r.first_token_at)
                self.metrics.on_finish(r.rid, r.finished_at,
                                       len(r.generated),
                                       self._metrics_sid(s.sid))
                finished.append(r)
            if was_loading and s.state == "serving":
                # scale-up latency = time-to-first-admittable, NOT
                # time-to-fully-loaded: the autoscaler's new capacity is
                # live from this moment while segments keep streaming in
                self.metrics.on_event(
                    now, "ready",
                    f"server{self._metrics_sid(s.sid)} time_to_ready="
                    f"{max(0.0, now - s.spawned_at):.2f}s "
                    f"loaded_bytes={s.engine.loaded_bytes()}")
        busy = sum(self.ccfg.n_devices for s in self.servers
                   if s.state not in ("down", "retired"))
        # GPU-seconds accrue over the REAL tick duration: under the logical
        # clock that's exactly tick_s; under the wall clock it's whatever
        # time the tick actually took (same code, no clock branch)
        dt = (self.ccfg.tick_s if self._prev_tick_t is None
              else max(0.0, now - self._prev_tick_t))
        self._prev_tick_t = now
        # degraded capacity: dead devices on servers that kept serving
        # (repartition mode) accrue device-seconds the fleet is short
        degraded = sum(getattr(s, "degraded_devices", 0)
                       for s in self.servers)
        if degraded:
            self.metrics.degraded_seconds += degraded * dt
        self.metrics.on_tick(now, self.pending, len(
            [s for s in self.servers if s.state not in ("down", "retired")]),
            busy, dt)
        if advance:
            self._clock.advance(self.ccfg.tick_s)
        return finished

    @property
    def quiescent(self) -> bool:
        """True when no tick would do any work: empty router queue and no
        server mid-load/-recovery/-decode/-fill.  The event engine only
        jumps the clock while this holds (a dense tick is a provable no-op
        then, so skipping it cannot change any token stream)."""
        return not self.queue and not any(s.needs_tick for s in self.servers)

    def next_event_time(self, next_arrival: Optional[float] = None,
                        extra: Sequence[float] = ()) -> Optional[float]:
        """Earliest lifecycle event that can wake a quiescent fleet: the
        next trace arrival, the autoscaler's idle-retire deadline, or a
        caller-scheduled instant (e.g. a crash-rejoin time).  ``None``
        means nothing will ever happen again."""
        cands = [t for t in extra if t is not None]
        if next_arrival is not None:
            cands.append(next_arrival)
        if self.autoscaler is not None:
            t = self.autoscaler.next_retire_time(self.servers,
                                                 self.ccfg.tick_s)
            if t is not None:
                cands.append(t)
        return min(cands) if cands else None

    def _settle_gap(self, t_wake: float) -> None:
        """Account a quiescent gap as if its idle ticks had run: GPU-
        seconds accrue at the current fleet composition up to the tick
        *before* the wake tick (the wake tick accrues its own ``tick_s``
        normally, exactly as under the polling loop)."""
        busy = sum(self.ccfg.n_devices for s in self.servers
                   if s.state not in ("down", "retired"))
        degraded = sum(getattr(s, "degraded_devices", 0)
                       for s in self.servers)
        lead = t_wake - self.ccfg.tick_s
        if self._prev_tick_t is not None and lead > self._prev_tick_t:
            self.metrics.gpu_seconds += busy * (lead - self._prev_tick_t)
            if degraded:
                self.metrics.degraded_seconds += \
                    degraded * (lead - self._prev_tick_t)
            self._prev_tick_t = lead

    def _jump_to(self, t_wake: float) -> None:
        """Event-engine clock jump across a quiescent gap: settle the
        skipped ticks' accounting, then move the clock — logical clocks
        teleport, wall clocks sleep instead of hot-polling."""
        self._settle_gap(t_wake)
        self._clock.sleep_until(t_wake)

    def _apply_chaos(self, ev) -> None:
        """Execute one ``ChaosEvent``.  Events that no longer make sense
        (crashing a down/retired server, rejoining a live one) resolve to
        deterministic no-ops surfaced as ``chaos_skip`` events, so a seeded
        schedule replays identically however the fleet evolved."""
        skip = None
        server = (self.servers[ev.server]
                  if 0 <= ev.server < len(self.servers) else None)
        if server is None:
            skip = "no such server"
        elif server.state == "retired":
            skip = "retired"
        elif ev.kind in ("crash", "partial_crash", "source_crash",
                         "fill_crash"):
            # the load-stage kinds (source_crash = a multicast source dies
            # mid-transfer, fill_crash = an in-flight receiver dies) are
            # whole-server crashes by intent: crash_server drops the victim
            # from the multicast tree, which re-roots its dependents
            if server.state == "down":
                skip = "already down"
            else:
                devices = (list(ev.devices)
                           if ev.kind == "partial_crash" and ev.devices
                           else None)
                self.crash_server(ev.server, devices)
                return
        elif ev.kind == "rejoin":
            if server.state == "down":
                self.rejoin_server(ev.server)
                return
            devs = getattr(getattr(server, "engine", None), "devices", [])
            dead = [d.idx for d in devs if not d.alive]
            want = [i for i in ev.devices if i in dead] or dead
            if ev.devices and want:
                self.rejoin_server(ev.server, want)
                return
            skip = "nothing to rejoin"
        else:
            skip = f"unknown kind {ev.kind!r}"
        self.metrics.on_event(
            self.clock, "chaos_skip",
            f"{ev.kind} server{self._metrics_sid(ev.server)}: {skip}")

    def run(self, trace, *, max_ticks: int = 200_000,
            crash_after_completions: Optional[int] = None,
            crash_server_id: int = 1,
            crash_devices: Optional[Sequence[int]] = None,
            rejoin_after_ticks: Optional[int] = None,
            chaos=None,
            engine: str = "event",
            collect_finished: bool = True) -> List[ServeRequest]:
        """Replay ``trace`` to completion; returns finished requests.

        ``trace`` may be a sequence of :class:`Arrival` (sorted here) or a
        time-ordered iterator (``traces.arrival_stream`` /
        ``iter_azure_trace``) — streamed arrivals are never materialized.

        ``engine="event"`` (default) jumps the clock across quiescent gaps
        to the next arrival / retire deadline / rejoin instant; while any
        work is in flight it processes every tick densely, so its token
        streams are identical to ``engine="tick"`` (the legacy poll-every-
        tick loop, kept as the equivalence oracle).

        ``crash_after_completions``: once that many requests completed,
        crash ``crash_server_id`` (all its devices unless ``crash_devices``
        narrows it) and re-route its work; with ``rejoin_after_ticks`` the
        downed server reboots into the fleet that many ticks later.

        ``chaos``: a :class:`repro.cluster.traces.ChaosSchedule` (or any
        iterable of ``ChaosEvent``) of scripted crash / partial-crash /
        rejoin faults.  Each event applies at the first tick whose
        pre-advance clock has reached its time — the arrival-admission
        rule — so a seeded schedule replays identically under both
        engines.

        ``collect_finished=False`` drops finished requests instead of
        returning them (million-row replays keep metrics, not payloads).
        """
        if engine not in ("event", "tick"):
            raise ValueError(f"unknown engine {engine!r}; "
                             "expected 'event' or 'tick'")
        stream = arrival_stream(trace)
        nxt = next(stream, None)
        tick_s = self.ccfg.tick_s
        completed: List[ServeRequest] = []
        n_completed = 0
        crashed = False
        chaos_left: Deque = deque(sorted(chaos or (),
                                         key=lambda e: e.time))
        # tick engine counts iterations; event engine schedules clock time
        rejoin_at: Optional[float] = None
        t = 0
        while t < max_ticks:
            while nxt is not None and nxt.time <= self.clock:
                self.submit(nxt)
                nxt = next(stream, None)
            while chaos_left and chaos_left[0].time <= self.clock:
                self._apply_chaos(chaos_left.popleft())
            if engine == "event" and self.quiescent:
                pending_rejoin = (rejoin_at is not None
                                  and self.servers[crash_server_id].state
                                  == "down")
                now = self.clock
                # the rejoin check below fires once the POST-advance clock
                # reaches rejoin_at (matching the tick engine's iteration
                # count), so the last dense tick it needs is the one AT
                # rejoin_at - tick_s — waking at rejoin_at itself would
                # reboot the server one tick late
                extra = [rejoin_at - tick_s] if pending_rejoin else []
                if chaos_left:
                    # chaos applies pre-tick against the pre-advance clock
                    # (the arrival rule): wake at the event time itself
                    extra.append(chaos_left[0].time)
                t_evt = self.next_event_time(
                    next_arrival=None if nxt is None else nxt.time,
                    extra=extra)
                if t_evt is None:
                    break           # nothing can ever wake the fleet again
                if t_evt - now > tick_s * 1e-6:
                    # jump to the first tick-grid point at/after the event
                    # (grid-aligned so the wake tick lands exactly where
                    # the polling loop would have processed the event)
                    k = max(1, math.ceil((t_evt - now) / tick_s - 1e-9))
                    k = min(k, max_ticks - t)
                    self._jump_to(now + k * tick_s)
                    t += k
                    continue
                # event is due now: process it as a normal dense tick
            done = self.tick()
            n_completed += len(done)
            if collect_finished:
                completed.extend(done)
            t += 1
            if (crash_after_completions is not None and not crashed
                    and n_completed >= crash_after_completions
                    and crash_server_id < len(self.servers)):
                self.crash_server(crash_server_id, crash_devices)
                crashed = True
                if rejoin_after_ticks is not None:
                    rejoin_at = (t - 1 + rejoin_after_ticks
                                 if engine == "tick"
                                 else self.clock + rejoin_after_ticks
                                 * tick_s)
            if (crashed and rejoin_at is not None
                    and self.servers[crash_server_id].state == "down"
                    and ((t - 1 == rejoin_at) if engine == "tick"
                         else self.clock >= rejoin_at - 1e-9)):
                self.rejoin_server(crash_server_id)
            if nxt is None and self.pending == 0 and not chaos_left:
                break
            if self.stalled(arrivals_left=(nxt is not None
                                           or bool(chaos_left))):
                break
        self.finalize_metrics()
        return completed

    def finalize_metrics(self) -> None:
        """Fold per-server hot-path and cold-start accounting into the
        metrics store (end of a run; fleets call this per pool)."""
        for s in self.servers:
            self.metrics.record_hotpath(s.srv.hotpath_stats())
            self.metrics.record_coldstart(self._metrics_sid(s.sid),
                                          s.cold_start_record())
        if self.multicast is not None:
            self.metrics.on_multicast(self.multicast.stats())
        if self.state_tier is not None:
            # replace-semantics: the tier's counters are fleet-global, so
            # per-pool finalize calls all observe the same totals
            self.metrics.on_state_tier(self.state_tier.stats())
