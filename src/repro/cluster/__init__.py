"""Serverless cluster layer (paper §4.1–§4.4 at fleet scale).

Composes the single-server pieces — PipeBoostEngine cold start/recovery
(core/engine.py) and continuous-batched serving (serving/engine.py) — into
the paper's end-to-end serverless scenario: bursty arrival traces routed
across N server replicas, an autoscaler that cold-starts servers mid-burst
and admits traffic the moment a viable pipeline chain exists, cross-server
re-routing of in-flight requests on a crash, and a JSON metrics layer
(TTFT/TBT percentiles, queue depth, GPU-seconds).

Scheduling is pluggable (cluster/scheduler.py): dispatch policies
(least-loaded / SLO-aware / adapter-affine), placement policies for what
a spawned server preloads, and injected clocks (logical ticks vs wall
time).  Multi-model fleets ride cluster/fleet.py: named per-model pools
over shared base params with per-pool autoscalers and cross-pool metrics.
"""
from repro.cluster.autoscaler import Autoscaler, AutoscalerConfig
from repro.cluster.fleet import Fleet, PoolSpec
from repro.cluster.metrics import ClusterMetrics, percentile
from repro.cluster.router import ClusterConfig, ClusterRouter, ClusterServer
from repro.cluster.scheduler import (DISPATCH_POLICIES, AdapterAffine,
                                     Clock, DispatchPolicy,
                                     HotAdapterPlacement, LeastLoaded,
                                     LogicalClock, PlacementPolicy,
                                     PreloadAll, SloAware, WallClock,
                                     make_dispatch)
from repro.cluster.traces import (Arrival, burst_wave_trace, gamma_trace,
                                  load_azure_trace, load_trace,
                                  merge_traces, poisson_trace, save_trace)

__all__ = [
    "AdapterAffine", "Arrival", "Autoscaler", "AutoscalerConfig", "Clock",
    "ClusterConfig", "ClusterMetrics", "ClusterRouter", "ClusterServer",
    "DISPATCH_POLICIES", "DispatchPolicy", "Fleet", "HotAdapterPlacement",
    "LeastLoaded", "LogicalClock", "PlacementPolicy", "PoolSpec",
    "PreloadAll", "SloAware", "WallClock", "burst_wave_trace",
    "gamma_trace", "load_azure_trace", "load_trace", "make_dispatch",
    "merge_traces", "percentile", "poisson_trace", "save_trace",
]
