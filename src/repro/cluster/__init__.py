"""Serverless cluster layer (paper §4.1–§4.4 at fleet scale).

Composes the single-server pieces — PipeBoostEngine cold start/recovery
(core/engine.py) and continuous-batched serving (serving/engine.py) — into
the paper's end-to-end serverless scenario: bursty arrival traces routed
across N server replicas, an autoscaler that cold-starts servers mid-burst
and admits traffic the moment a viable pipeline chain exists, cross-server
re-routing of in-flight requests on a crash, and a JSON metrics layer
(TTFT/TBT percentiles, SLO attainment, queue depth, GPU-seconds).

Replay is discrete-event (cluster/router.py): dense, bit-exact ticks while
any server has work, clock jumps across quiescent gaps to the next
arrival / idle-retire deadline / rejoin — full-day Azure traces stream in
(cluster/traces.py) and replay against modeled servers
(cluster/simserver.py) in seconds.

Scale-out is peer-to-peer when ``ClusterConfig.multicast`` is set
(cluster/multicast.py): spawning servers pull model segments from warm
peers over ICI — chain or tree propagation with mid-transfer failover
(re-root on source crash, resume from the last received segment, host
fallback) — so N simultaneous cold starts cost ~one host read.

Scale-down keeps its warmth when a ``StateTier`` is wired in
(cluster/state_tier.py): idle retirement spills the server's prefix-cache
contents (serving/prefix_cache.py) and resident-adapter set host-side,
and a later spawn for the same pool resurrects them — priced with the
same shared-host-bandwidth model as snapshot transfers.

Scheduling is pluggable (cluster/scheduler.py): batched dispatch policies
(least-loaded / SLO-aware / adapter-affine, all implementing
``select_many``), placement policies for what a spawned server preloads,
and injected clocks (logical ticks vs wall time).  Multi-model fleets
ride cluster/fleet.py: named per-model pools over shared base params with
per-pool autoscalers and cross-pool metrics.

See ``docs/ARCHITECTURE.md`` § "Cluster" for the subsystem map.
"""
from repro.cluster.autoscaler import (Autoscaler, AutoscalerConfig,
                                      ScaleDecision)
from repro.cluster.fleet import Fleet, PoolSpec
from repro.cluster.metrics import ClusterMetrics, percentile
from repro.cluster.multicast import MulticastConfig, MulticastManager
from repro.cluster.router import ClusterConfig, ClusterRouter, ClusterServer
from repro.cluster.scheduler import (DISPATCH_POLICIES, AdapterAffine,
                                     Clock, DispatchPolicy,
                                     HotAdapterPlacement, LeastLoaded,
                                     LogicalClock, PlacementPolicy,
                                     PreloadAll, SloAware, WallClock,
                                     make_dispatch)
from repro.cluster.simserver import (SimProfile, SimServer,
                                     sim_server_factory)
from repro.cluster.state_tier import StateTier
from repro.cluster.traces import (Arrival, ChaosEvent, ChaosSchedule,
                                  arrival_stream, burst_wave_trace,
                                  gamma_trace, iter_azure_trace,
                                  load_azure_trace, load_chaos, load_trace,
                                  merge_traces, poisson_trace, random_chaos,
                                  repeated_prefix_trace, save_chaos,
                                  save_trace)

__all__ = [
    "AdapterAffine", "Arrival", "Autoscaler", "AutoscalerConfig",
    "ChaosEvent", "ChaosSchedule", "Clock",
    "ClusterConfig", "ClusterMetrics", "ClusterRouter", "ClusterServer",
    "DISPATCH_POLICIES", "DispatchPolicy", "Fleet", "HotAdapterPlacement",
    "LeastLoaded", "LogicalClock", "MulticastConfig", "MulticastManager",
    "PlacementPolicy", "PoolSpec",
    "PreloadAll", "ScaleDecision", "SimProfile", "SimServer", "SloAware",
    "StateTier", "WallClock", "arrival_stream", "burst_wave_trace",
    "gamma_trace", "iter_azure_trace", "load_azure_trace", "load_chaos",
    "load_trace", "make_dispatch", "merge_traces", "percentile",
    "poisson_trace", "random_chaos", "repeated_prefix_trace", "save_chaos",
    "save_trace", "sim_server_factory",
]
