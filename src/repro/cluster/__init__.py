"""Serverless cluster layer (paper §4.1–§4.4 at fleet scale).

Composes the single-server pieces — PipeBoostEngine cold start/recovery
(core/engine.py) and continuous-batched serving (serving/engine.py) — into
the paper's end-to-end serverless scenario: bursty arrival traces routed
across N server replicas, an autoscaler that cold-starts servers mid-burst
and admits traffic the moment a viable pipeline chain exists, cross-server
re-routing of in-flight requests on a crash, and a JSON metrics layer
(TTFT/TBT percentiles, queue depth, GPU-seconds).
"""
from repro.cluster.autoscaler import Autoscaler, AutoscalerConfig
from repro.cluster.metrics import ClusterMetrics, percentile
from repro.cluster.router import ClusterConfig, ClusterRouter, ClusterServer
from repro.cluster.traces import (Arrival, burst_wave_trace, gamma_trace,
                                  load_trace, poisson_trace, save_trace)

__all__ = [
    "Arrival", "Autoscaler", "AutoscalerConfig", "ClusterConfig",
    "ClusterMetrics", "ClusterRouter", "ClusterServer", "burst_wave_trace",
    "gamma_trace", "load_trace", "percentile", "poisson_trace", "save_trace",
]
