"""Host-side state tier: warm-state spill on retire, resurrect on spawn.

Idle retirement used to throw a fully-warm server's state away: its
prefix-cache KV rows (serving/prefix_cache.py) and resident-adapter set
vanished with the replica, and the next scale-up for the same pool
started stone cold.  The ``StateTier`` keeps that state host-side
instead — the λScale/HydraServe view that inference state is a fast
migrating resource, applied to the scale-DOWN direction:

* ``ClusterRouter`` **spills** on autoscaler retirement: the retiring
  server's prefix-cache entries and adapter params land in the pool's
  bundle (``spill``), merged with whatever earlier retirements left.
* A later **spawn for the same pool resurrects** (``take``): the new
  server's prefix cache is pre-seeded and the spilled adapters are
  preloaded, so post-scale-up admissions hit warm prefixes instead of
  re-prefilling from token zero.  The pull is priced with
  ``core.simulator.state_resurrect_time`` (host-aggregate-shared
  bandwidth + fixed transfer cost), surfaced in the router's
  ``resurrect`` event and in ``SloAware``'s ready-time estimate.

Everything here is deterministic pure-Python host state (no wall clock,
no RNG, no device arrays — prefix rows are already host numpy), so tick
and event engine replays stay bit-identical.  One tier instance is
shared fleet-wide; bundles are keyed by pool name.

See ``docs/ARCHITECTURE.md`` § "Fleet state tier".
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional


class StateTier:
    """Per-pool host-side store of spilled warm server state.

    A bundle is a plain dict::

        {"prefix_entries": [(key, PrefixEntry), ...],   # cache contents
         "adapters": {name: params, ...},               # resident set
         "nbytes": int}                                 # payload size

    ``spill`` merges into the pool's bundle (later spills extend/replace
    earlier ones); ``take`` hands the whole bundle to a resurrecting
    spawn and removes it — exactly one spawn resurrects each spill
    generation, so concurrent spawns don't double-import the same rows.
    """

    def __init__(self) -> None:
        self._bundles: Dict[str, Dict[str, Any]] = {}
        self.spill_count = 0
        self.spilled_bytes = 0
        self.resurrections = 0
        self.resurrected_bytes = 0

    def spill(self, pool: Optional[str], bundle: Dict[str, Any]) -> None:
        """Merge a retiring server's bundle into the pool's stored one."""
        key = pool or "__pool__"
        dst = self._bundles.setdefault(
            key, {"prefix_entries": [], "adapters": {}, "nbytes": 0})
        dst["prefix_entries"] = (list(dst["prefix_entries"])
                                 + list(bundle.get("prefix_entries", ())))
        dst["adapters"].update(bundle.get("adapters", {}))
        nb = int(bundle.get("nbytes", 0))
        dst["nbytes"] += nb
        self.spill_count += 1
        self.spilled_bytes += nb

    def take(self, pool: Optional[str]) -> Optional[Dict[str, Any]]:
        """Pop the pool's bundle for a resurrecting spawn (None = cold)."""
        out = self._bundles.pop(pool or "__pool__", None)
        if out is not None:
            self.resurrections += 1
            self.resurrected_bytes += int(out.get("nbytes", 0))
        return out

    def peek_nbytes(self, pool: Optional[str]) -> int:
        """Stored bundle size for ``pool`` (0 when nothing is spilled) —
        what a prospective resurrect would have to transfer."""
        b = self._bundles.get(pool or "__pool__")
        return 0 if b is None else int(b.get("nbytes", 0))

    @property
    def pools(self) -> List[str]:
        """Pool keys currently holding a spilled bundle (sorted)."""
        return sorted(self._bundles)

    def stats(self) -> Dict[str, float]:
        """Lifetime counters, in the key shape ``ClusterMetrics``
        forwards into its always-present summary fields."""
        return {
            "spilled_bytes": float(self.spilled_bytes),
            "spill_count": float(self.spill_count),
            "spill_resurrections": float(self.resurrections),
            "resurrected_bytes": float(self.resurrected_bytes),
        }
