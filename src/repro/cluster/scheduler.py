"""Pluggable fleet scheduling: dispatch policies, placement, and clocks.

PipeBoost's premise (§2.1) is that many serverless tasks share one base
model and differ only by adapter — so *which server gets a request during
a burst* matters as much as how fast servers cold-start (HydraServe's
SLO-aware placement, λScale's scaling-state-aware request scheduling).
This module extracts the routing decision the ``ClusterRouter`` used to
hard-code into three separable pieces:

* ``DispatchPolicy`` — picks (request, server) pairs off the router queue.
  - ``LeastLoaded``     the pre-refactor behaviour, extracted verbatim:
                        fewest pending requests wins, ties by server id.
  - ``SloAware``        TTFT-deadline priority: earliest-deadline request
                        first, routed to the server minimizing *predicted*
                        first-token time (cold-start progress via the
                        engine's rounds-to-ready, epoch-switch drain
                        stalls via the batcher's resident-adapter set,
                        in-flight decode load via remaining tokens).
  - ``AdapterAffine``   prefers servers whose batcher already has the
                        request's adapter resident (no epoch-switch
                        stall), falling back to SLO-aware scoring.

* ``PlacementPolicy`` — decides what a *spawned* server preloads.  The
  model pool is decided by which pool's autoscaler fired (see
  ``cluster/fleet.py``); placement narrows the adapter set so a scale-up
  in a 100-adapter pool doesn't merge-load all 100.
  - ``PreloadAll``            every adapter the pool knows (default —
                              the pre-refactor behaviour).
  - ``HotAdapterPlacement``   the k most-recently-requested adapters.

* ``Clock`` — ``LogicalClock`` (discrete ticks, deterministic CI) vs
  ``WallClock`` (``time.monotonic``, real slices).  The router/autoscaler
  take ``now`` from the injected clock and never branch on its type: the
  same scheduler code runs simulation and real time.  ``sleep_until``
  lets the discrete-event replay loop jump a quiescent gap: logical
  clocks teleport, wall clocks actually sleep instead of hot-polling.

Dispatch is *batched*: ``select_many`` dispatches every placeable queued
request in one pass (one queue sort, one scoring sweep with virtual
load accounting), which is what lets a full-day trace replay run one
dispatch round per event instead of one O(Q log Q) ``select`` per
request.  ``select`` remains as a single-pick compatibility shim.

Pure host-side policy — no JAX.  Scoring peeks only at cheap scheduling
surfaces (queue depths, remaining-token counts, adapter residency,
load-plan progress), never at device state.

See ``docs/ARCHITECTURE.md`` § "Cluster: scheduling policies" for how
these pieces slot into the event engine.
"""
from __future__ import annotations

import math
import time
from collections import Counter
from dataclasses import dataclass, field
from typing import (Any, Dict, Optional, Protocol, Sequence, Tuple,
                    runtime_checkable)


# ---------------------------------------------------------------------------
# Clocks
# ---------------------------------------------------------------------------

@runtime_checkable
class Clock(Protocol):
    """Router time source.  ``now`` is seconds since the run started;
    ``advance`` is called once per router tick with the tick's nominal
    duration; ``sleep_until`` is how the discrete-event replay loop
    crosses a quiescent gap in one hop (see ``ClusterRouter.run``)."""

    def now(self) -> float:
        """Current time in seconds since the run started."""
        ...

    def advance(self, dt: float) -> None:
        """Account one router tick of nominal duration ``dt``."""
        ...

    def sleep_until(self, t: float) -> None:
        """Block (wall) or teleport (logical) until time ``t``."""
        ...


@dataclass
class LogicalClock:
    """Discrete-event time: one ``advance(tick_s)`` per router tick.
    Deterministic — the CI/simulation clock."""
    t: float = 0.0

    def now(self) -> float:
        """Current logical time (sum of advances and jumps)."""
        return self.t

    def advance(self, dt: float) -> None:
        """Step logical time forward by one tick of ``dt`` seconds."""
        self.t += dt

    def sleep_until(self, t: float) -> None:
        """Event-engine jump: teleport to ``t`` (never backwards)."""
        self.t = max(self.t, t)


class WallClock:
    """Real time off ``time.monotonic`` (zeroed at construction).

    ``advance`` is a no-op: wall time flows on its own while the tick does
    real work.  ``sleep_until`` really sleeps — under the event engine a
    quiescent fleet blocks until its next scheduled transition instead of
    hot-polling the tick loop.  Injecting this instead of ``LogicalClock``
    is the ONLY change needed to run the same router/autoscaler/policies
    on a real slice — no code forks anywhere downstream.
    """

    def __init__(self) -> None:
        self._t0 = time.monotonic()

    def now(self) -> float:
        """Seconds of real time since this clock was constructed."""
        return time.monotonic() - self._t0

    def advance(self, dt: float) -> None:
        """No-op: real time advances itself while the tick does work."""
        return None

    def sleep_until(self, t: float) -> None:
        """Really sleep until ``t`` (no-op if ``t`` already passed)."""
        dt = t - self.now()
        if dt > 0:
            time.sleep(dt)


# ---------------------------------------------------------------------------
# Dispatch policies
# ---------------------------------------------------------------------------
# ``servers`` are ClusterServer-likes exposing the scheduling surface:
# .sid .state .admitting .load .can_serve(req) .predicted_ready_s(now)
# .srv (ServingEngine: .resident_adapters() .predicted_step_cost_s()
#       .batcher.active / .batcher.free / .queued_requests())

def _capacity(server, n_slots: int) -> bool:
    return server.load < n_slots


class DispatchPolicy(Protocol):
    """One dispatch round: which queued requests go to which servers.

    ``select_many`` returns ``[(queue_index, server), ...]`` — every
    request placeable this round, indices into the *unmutated* queue, in
    dispatch order.  It must be equivalent to calling ``select``
    repeatedly with the router popping and submitting between calls;
    policies achieve that in one pass with *virtual load accounting*
    (each pick counts against its server's capacity and queue-wait score
    for subsequent picks).  An empty list means nothing can be dispatched
    (the backlog keeps feeding the autoscaler's SLO signal).

    ``select`` is the single-pick compatibility shim: first element of
    ``select_many`` or ``None``.  Neither may mutate the queue.
    """

    name: str

    def select(self, queue: Sequence, servers: Sequence, now: float,
               ccfg) -> Optional[Tuple[int, Any]]:
        """Pick ONE ``(queue_index, server)`` pair, or None."""
        ...

    def select_many(self, queue: Sequence, servers: Sequence, now: float,
                    ccfg) -> list:
        """Pick every placeable ``(queue_index, server)`` this round."""
        ...


@dataclass
class LeastLoaded:
    """Pre-refactor routing, extracted: FIFO queue order, dispatch to the
    admitting server with the fewest pending requests (ties by sid),
    capacity-bounded at ``n_slots`` outstanding per server.

    A request no current server can serve (placement preloaded a subset
    of adapters) is skipped, not allowed to block the head of the queue —
    with full preloads (the pre-refactor world) skipping never triggers
    and the decisions are identical to the old inline loop.
    """
    name: str = "least_loaded"

    def select_many(self, queue, servers, now, ccfg):
        """Batched FIFO dispatch: every placeable request in one pass."""
        # one FIFO pass; `extra` counts this round's virtual assignments so
        # each pick sees the load the repeated-select loop would have seen
        extra = {s.sid: 0 for s in servers}
        out = []
        for idx, req in enumerate(queue):
            cands = [s for s in servers
                     if s.admitting and s.load + extra[s.sid] < ccfg.n_slots
                     and s.can_serve(req)]
            if cands:
                best = min(cands,
                           key=lambda s: (s.load + extra[s.sid], s.sid))
                extra[best.sid] += 1
                out.append((idx, best))
                continue
            if any(s.admitting and s.load + extra[s.sid] < ccfg.n_slots
                   for s in servers):
                continue          # only THIS request is unservable: skip it
            break                 # fleet out of capacity: stop dispatching
        return out

    def select(self, queue, servers, now, ccfg):
        """Single-pick shim: first ``select_many`` pick or None."""
        picks = self.select_many(queue, servers, now, ccfg)
        return picks[0] if picks else None


@dataclass
class SloAware:
    """TTFT-deadline-priority dispatch to the predicted-fastest server.

    Request choice: the queued request with the earliest absolute TTFT
    deadline (``ServeRequest.deadline``; no deadline = +inf) — FIFO among
    equals.  Server choice: minimize predicted first-token time::

        t̂ = predicted_ready            (cold start / recovery remaining)
          + epoch_drain_stall          (batch busy on a DIFFERENT adapter:
                                        merged-LoRA must drain first —
                                        max remaining tokens in the batch)
          + slot_wait                  (no free slot: min remaining tokens
                                        until one opens)
          + queue_depth * step_cost    (admissions queued ahead)

    all in seconds of the injected clock.  ``step_cost_s`` pins the
    per-decode-step cost for deterministic scoring (benchmarks/tests);
    None consults the server's measured hook
    (``ServingEngine.predicted_step_cost_s``) with ``tick_s`` fallback.
    Warming servers are candidates (``consider_warming``): mid-burst it
    is often faster to queue on a server whose chain is one load-round
    from viable than behind a deep epoch on a serving one.

    Repartitioned servers (elastic recovery after a partial crash) stay
    in the candidate pool: their short ``repartition_ticks`` recovery
    window is already priced through ``predicted_ready_s``.  The lasting
    cost — fewer devices carrying the same pipeline — is priced per
    missing device via ``degraded_penalty_s_per_device`` (default 0 =
    capacity loss is free, matching pre-repartition behavior).
    """
    name: str = "slo_aware"
    step_cost_s: Optional[float] = None
    consider_warming: bool = True
    degraded_penalty_s_per_device: float = 0.0
    # multicast scale-out: a warm server sourcing peer transfers spends
    # link/host attention on them — flat penalty per active outbound send
    # (servers without the multicast surface read as 0 sends; default 0 =
    # sourcing is free, matching host-only behavior)
    source_penalty_s: float = 0.0
    # prefix-cache affinity: credit per prompt token a server's prefix
    # cache would reuse for this request (skipped prefill work).  Servers
    # without the surface read as 0 reusable tokens; default 0 = cache
    # state doesn't steer dispatch, matching pre-state-tier behavior
    prefix_bonus_s_per_token: float = 0.0

    def _step_cost(self, server, ccfg) -> float:
        if self.step_cost_s is not None:
            return self.step_cost_s
        return server.srv.predicted_step_cost_s(default=ccfg.tick_s)

    def predicted_first_token_s(self, server, req, now, ccfg) -> float:
        """Predicted seconds until ``server`` emits ``req``'s first
        token: readiness + epoch-drain stall + slot wait + queued-ahead
        work (the scoring model in the class docstring)."""
        cost = self._step_cost(server, ccfg)
        # predicted_ready_s counts ticks at nominal tick_s; convert to the
        # same per-tick cost unit as the drain/queue terms (under a wall
        # clock a tick really costs ~one measured decode step, not tick_s)
        t = server.predicted_ready_s(now) / ccfg.tick_s * cost
        b = server.srv.batcher
        rem = [max(0, r.max_new_tokens - len(r.generated))
               for r in b.active.values()]
        resident = server.srv.resident_adapters()
        if rem and req.adapter not in resident:
            t += max(rem) * cost                  # epoch barrier: full drain
        elif rem and not b.free:
            t += min(rem) * cost                  # wait for one slot
        # queued-ahead work: same-adapter requests ride the same admission
        # batch (≈ one step each); OTHER-adapter requests run whole epochs
        # before this adapter's turn — price their full remaining tokens,
        # or a dispatch can look fast on a server whose queue guarantees a
        # cross-epoch wait
        for q in server.srv.queued_requests():
            if q.adapter == req.adapter:
                t += cost
            else:
                t += max(1, q.max_new_tokens - len(q.generated)) * cost
        # degraded capacity: a repartitioned server runs the same pipeline
        # on fewer devices — flat penalty per dead device (sims without a
        # device list read as 0)
        t += self.degraded_penalty_s_per_device * \
            getattr(server, "degraded_devices", 0)
        # multicast sourcing load: outbound peer transfers this server is
        # feeding right now (0 when multicast is off or unsupported)
        t += self.source_penalty_s * getattr(server, "mc_active_sends", 0)
        # prefix-cache affinity: reusable cached-prefix tokens shave
        # prefill work — a credit, not a cost (0 when the cache is off or
        # the server lacks the surface)
        if self.prefix_bonus_s_per_token:
            fn = getattr(server, "predicted_prefix_tokens", None)
            if fn is not None:
                t -= self.prefix_bonus_s_per_token * fn(req)
        return t

    def _virtual_wait_s(self, server, assigned, req, ccfg) -> float:
        """Queue-wait contribution of this round's earlier virtual
        assignments to ``server`` — priced exactly like the real queued
        requests in ``predicted_first_token_s`` so one batched pass scores
        what a repeated single-select loop would have seen."""
        if not assigned:
            return 0.0
        cost = self._step_cost(server, ccfg)
        t = 0.0
        for q in assigned:
            if q.adapter == req.adapter:
                t += cost
            else:
                t += max(1, q.max_new_tokens - len(q.generated)) * cost
        return t

    def _candidates(self, req, servers, ccfg, extra=None):
        states = ("serving", "loading", "recovering") if self.consider_warming \
            else ("serving",)
        vload = (lambda s: len(extra[s.sid])) if extra is not None \
            else (lambda s: 0)
        return [s for s in servers
                if s.state in states and s.load + vload(s) < ccfg.n_slots
                and s.can_serve(req)]

    def _edf_order(self, reqs):
        # earliest-deadline-first; FIFO among equals (stable index tiebreak)
        return sorted(range(len(reqs)),
                      key=lambda i: (getattr(reqs[i], "deadline", None)
                                     if getattr(reqs[i], "deadline", None)
                                     is not None else math.inf, i))

    def select_many(self, queue, servers, now, ccfg):
        """Batched EDF dispatch: deadline-ordered sweep with virtual
        load/wait accounting per server."""
        # one EDF sort + one scoring sweep; a request no current server
        # can serve is skipped, never left blocking the rest.
        # (materialize once: the router hands us a deque, and O(n)
        # deque indexing inside the sort would make burst dispatch cubic)
        reqs = list(queue)
        extra = {s.sid: [] for s in servers}
        out = []
        for idx in self._edf_order(reqs):
            req = reqs[idx]
            cands = self._candidates(req, servers, ccfg, extra)
            if cands:
                best = min(cands, key=lambda s: (
                    self.predicted_first_token_s(s, req, now, ccfg)
                    + self._virtual_wait_s(s, extra[s.sid], req, ccfg),
                    s.sid))
                extra[best.sid].append(req)
                out.append((idx, best))
                continue
            if not any(s.state in ("serving", "loading", "recovering")
                       and s.load + len(extra[s.sid]) < ccfg.n_slots
                       for s in servers):
                break             # fleet out of capacity: stop dispatching
        return out

    def select(self, queue, servers, now, ccfg):
        """Single-pick shim: first ``select_many`` pick or None."""
        picks = self.select_many(queue, servers, now, ccfg)
        return picks[0] if picks else None


@dataclass
class AdapterAffine:
    """Adapter-affinity first, SLO-aware otherwise.

    Among capacity-holding serving servers, prefer those whose batcher
    already has the request's adapter resident (admission needs no
    epoch-switch drain); break ties by the SLO-aware predicted
    first-token time.  When no affine server exists, fall back to the
    full SLO-aware scoring (which prices the epoch stall instead of
    forbidding it).
    """
    name: str = "adapter_affine"
    slo: SloAware = field(default_factory=SloAware)

    def select_many(self, queue, servers, now, ccfg):
        """Batched dispatch: the SLO-aware sweep with a per-pick
        affinity override toward adapter-resident servers."""
        # the SLO-aware sweep, with an affinity override per pick: among
        # admitting servers holding the request's adapter resident, take
        # the best-scored one; virtual load lands on the FINAL choice
        slo = self.slo
        reqs = list(queue)
        extra = {s.sid: [] for s in servers}
        out = []
        for idx in slo._edf_order(reqs):
            req = reqs[idx]
            cands = slo._candidates(req, servers, ccfg, extra)
            if cands:
                score = lambda s: (
                    slo.predicted_first_token_s(s, req, now, ccfg)
                    + slo._virtual_wait_s(s, extra[s.sid], req, ccfg), s.sid)
                best = min(cands, key=score)
                affine = [s for s in servers
                          if s.admitting
                          and s.load + len(extra[s.sid]) < ccfg.n_slots
                          and s.can_serve(req)
                          and req.adapter in s.srv.resident_adapters()]
                if affine:
                    best = min(affine, key=score)
                extra[best.sid].append(req)
                out.append((idx, best))
                continue
            if not any(s.state in ("serving", "loading", "recovering")
                       and s.load + len(extra[s.sid]) < ccfg.n_slots
                       for s in servers):
                break             # fleet out of capacity: stop dispatching
        return out

    def select(self, queue, servers, now, ccfg):
        """Single-pick shim: first ``select_many`` pick or None."""
        picks = self.select_many(queue, servers, now, ccfg)
        return picks[0] if picks else None


DISPATCH_POLICIES = {
    "least_loaded": LeastLoaded,
    "slo_aware": SloAware,
    "adapter_affine": AdapterAffine,
}


def make_dispatch(name: str) -> DispatchPolicy:
    """CLI/bench helper: dispatch policy by registry name."""
    try:
        return DISPATCH_POLICIES[name]()
    except KeyError:
        raise ValueError(f"unknown dispatch policy {name!r}; "
                         f"available: {sorted(DISPATCH_POLICIES)}") from None


# ---------------------------------------------------------------------------
# Placement policies
# ---------------------------------------------------------------------------

class PlacementPolicy(Protocol):
    """What a freshly spawned server preloads.

    The *pool* (base model) is already decided — each pool's autoscaler
    spawns into its own pool (``cluster/fleet.py``); placement narrows
    the pool's adapter set to what the new server merge-loads.  ``recent``
    is the router's recently-requested adapter names, most recent last.
    """

    name: str

    def adapters_for(self, all_adapters: Dict[str, Any],
                     recent: Sequence[str]) -> Dict[str, Any]:
        """The adapter subset the new server should merge-load."""
        ...


@dataclass
class PreloadAll:
    """Every adapter the pool knows — the pre-refactor behaviour, and the
    right call while adapter sets are small."""
    name: str = "preload_all"

    def adapters_for(self, all_adapters, recent):
        """Everything the pool knows, history ignored."""
        return dict(all_adapters)


@dataclass
class HotAdapterPlacement:
    """Preload the ``k`` hottest adapters by recent request count (ties
    by recency), so a mid-burst scale-up pays k merge passes, not one per
    adapter the pool has ever seen.  Requests for non-resident adapters
    simply never dispatch to this server (``can_serve``) — they ride
    servers that do hold them."""
    k: int = 4
    name: str = "hot_adapters"

    def adapters_for(self, all_adapters, recent):
        """Top-``k`` adapters by recent request count (ties by recency);
        no history yet behaves like ``PreloadAll``."""
        seen = [a for a in recent if a in all_adapters]
        counts = Counter(seen)
        last_pos = {a: i for i, a in enumerate(seen)}
        hot = sorted(counts, key=lambda a: (-counts[a], -last_pos[a]))[:self.k]
        if not hot:                   # no history yet: behave like PreloadAll
            return dict(all_adapters)
        return {a: all_adapters[a] for a in hot}
