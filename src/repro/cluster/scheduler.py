"""Pluggable fleet scheduling: dispatch policies, placement, and clocks.

PipeBoost's premise (§2.1) is that many serverless tasks share one base
model and differ only by adapter — so *which server gets a request during
a burst* matters as much as how fast servers cold-start (HydraServe's
SLO-aware placement, λScale's scaling-state-aware request scheduling).
This module extracts the routing decision the ``ClusterRouter`` used to
hard-code into three separable pieces:

* ``DispatchPolicy`` — picks (request, server) pairs off the router queue.
  - ``LeastLoaded``     the pre-refactor behaviour, extracted verbatim:
                        fewest pending requests wins, ties by server id.
  - ``SloAware``        TTFT-deadline priority: earliest-deadline request
                        first, routed to the server minimizing *predicted*
                        first-token time (cold-start progress via the
                        engine's rounds-to-ready, epoch-switch drain
                        stalls via the batcher's resident-adapter set,
                        in-flight decode load via remaining tokens).
  - ``AdapterAffine``   prefers servers whose batcher already has the
                        request's adapter resident (no epoch-switch
                        stall), falling back to SLO-aware scoring.

* ``PlacementPolicy`` — decides what a *spawned* server preloads.  The
  model pool is decided by which pool's autoscaler fired (see
  ``cluster/fleet.py``); placement narrows the adapter set so a scale-up
  in a 100-adapter pool doesn't merge-load all 100.
  - ``PreloadAll``            every adapter the pool knows (default —
                              the pre-refactor behaviour).
  - ``HotAdapterPlacement``   the k most-recently-requested adapters.

* ``Clock`` — ``LogicalClock`` (discrete ticks, deterministic CI) vs
  ``WallClock`` (``time.monotonic``, real slices).  The router/autoscaler
  take ``now`` from the injected clock and never branch on its type: the
  same scheduler code runs simulation and real time.

Pure host-side policy — no JAX.  Scoring peeks only at cheap scheduling
surfaces (queue depths, remaining-token counts, adapter residency,
load-plan progress), never at device state.
"""
from __future__ import annotations

import math
import time
from collections import Counter
from dataclasses import dataclass, field
from typing import (Any, Dict, Optional, Protocol, Sequence, Tuple,
                    runtime_checkable)


# ---------------------------------------------------------------------------
# Clocks
# ---------------------------------------------------------------------------

@runtime_checkable
class Clock(Protocol):
    """Router time source.  ``now`` is seconds since the run started;
    ``advance`` is called once per router tick with the tick's nominal
    duration."""

    def now(self) -> float: ...

    def advance(self, dt: float) -> None: ...


@dataclass
class LogicalClock:
    """Discrete-event time: one ``advance(tick_s)`` per router tick.
    Deterministic — the CI/simulation clock."""
    t: float = 0.0

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


class WallClock:
    """Real time off ``time.monotonic`` (zeroed at construction).

    ``advance`` is a no-op: wall time flows on its own while the tick does
    real work.  Injecting this instead of ``LogicalClock`` is the ONLY
    change needed to run the same router/autoscaler/policies on a real
    slice — no code forks anywhere downstream.
    """

    def __init__(self) -> None:
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0

    def advance(self, dt: float) -> None:  # real time advances itself
        return None


# ---------------------------------------------------------------------------
# Dispatch policies
# ---------------------------------------------------------------------------
# ``servers`` are ClusterServer-likes exposing the scheduling surface:
# .sid .state .admitting .load .can_serve(req) .predicted_ready_s(now)
# .srv (ServingEngine: .resident_adapters() .predicted_step_cost_s()
#       .batcher.active / .batcher.free / .queued_requests())

def _capacity(server, n_slots: int) -> bool:
    return server.load < n_slots


class DispatchPolicy(Protocol):
    """One dispatch decision: which queued request goes to which server.

    ``select`` returns ``(queue_index, server)`` or ``None`` when nothing
    can be dispatched this tick (the router stops pulling and the backlog
    keeps feeding the autoscaler's SLO signal).  The router pops the
    request and submits it; ``select`` must not mutate the queue.
    """

    name: str

    def select(self, queue: Sequence, servers: Sequence, now: float,
               ccfg) -> Optional[Tuple[int, Any]]: ...


@dataclass
class LeastLoaded:
    """Pre-refactor routing, extracted: FIFO queue order, dispatch to the
    admitting server with the fewest pending requests (ties by sid),
    capacity-bounded at ``n_slots`` outstanding per server.

    A request no current server can serve (placement preloaded a subset
    of adapters) is skipped, not allowed to block the head of the queue —
    with full preloads (the pre-refactor world) skipping never triggers
    and the decisions are identical to the old inline loop.
    """
    name: str = "least_loaded"

    def select(self, queue, servers, now, ccfg):
        for idx, req in enumerate(queue):
            cands = [s for s in servers
                     if s.admitting and _capacity(s, ccfg.n_slots)
                     and s.can_serve(req)]
            if cands:
                return idx, min(cands, key=lambda s: (s.load, s.sid))
            if any(s.admitting and _capacity(s, ccfg.n_slots)
                   for s in servers):
                continue          # only THIS request is unservable: skip it
            return None           # fleet out of capacity: stop dispatching
        return None


@dataclass
class SloAware:
    """TTFT-deadline-priority dispatch to the predicted-fastest server.

    Request choice: the queued request with the earliest absolute TTFT
    deadline (``ServeRequest.deadline``; no deadline = +inf) — FIFO among
    equals.  Server choice: minimize predicted first-token time::

        t̂ = predicted_ready            (cold start / recovery remaining)
          + epoch_drain_stall          (batch busy on a DIFFERENT adapter:
                                        merged-LoRA must drain first —
                                        max remaining tokens in the batch)
          + slot_wait                  (no free slot: min remaining tokens
                                        until one opens)
          + queue_depth * step_cost    (admissions queued ahead)

    all in seconds of the injected clock.  ``step_cost_s`` pins the
    per-decode-step cost for deterministic scoring (benchmarks/tests);
    None consults the server's measured hook
    (``ServingEngine.predicted_step_cost_s``) with ``tick_s`` fallback.
    Warming servers are candidates (``consider_warming``): mid-burst it
    is often faster to queue on a server whose chain is one load-round
    from viable than behind a deep epoch on a serving one.
    """
    name: str = "slo_aware"
    step_cost_s: Optional[float] = None
    consider_warming: bool = True

    def _step_cost(self, server, ccfg) -> float:
        if self.step_cost_s is not None:
            return self.step_cost_s
        return server.srv.predicted_step_cost_s(default=ccfg.tick_s)

    def predicted_first_token_s(self, server, req, now, ccfg) -> float:
        cost = self._step_cost(server, ccfg)
        # predicted_ready_s counts ticks at nominal tick_s; convert to the
        # same per-tick cost unit as the drain/queue terms (under a wall
        # clock a tick really costs ~one measured decode step, not tick_s)
        t = server.predicted_ready_s(now) / ccfg.tick_s * cost
        b = server.srv.batcher
        rem = [max(0, r.max_new_tokens - len(r.generated))
               for r in b.active.values()]
        resident = server.srv.resident_adapters()
        if rem and req.adapter not in resident:
            t += max(rem) * cost                  # epoch barrier: full drain
        elif rem and not b.free:
            t += min(rem) * cost                  # wait for one slot
        # queued-ahead work: same-adapter requests ride the same admission
        # batch (≈ one step each); OTHER-adapter requests run whole epochs
        # before this adapter's turn — price their full remaining tokens,
        # or a dispatch can look fast on a server whose queue guarantees a
        # cross-epoch wait
        for q in server.srv.queued_requests():
            if q.adapter == req.adapter:
                t += cost
            else:
                t += max(1, q.max_new_tokens - len(q.generated)) * cost
        return t

    def _candidates(self, req, servers, ccfg):
        states = ("serving", "loading", "recovering") if self.consider_warming \
            else ("serving",)
        return [s for s in servers
                if s.state in states and _capacity(s, ccfg.n_slots)
                and s.can_serve(req)]

    def select(self, queue, servers, now, ccfg):
        # earliest-deadline-first over the queue; a request no current
        # server can serve is skipped, never left blocking the rest.
        # (materialize once: the router hands us a deque, and O(n)
        # deque indexing inside the sort would make burst dispatch cubic)
        reqs = list(queue)
        order = sorted(range(len(reqs)),
                       key=lambda i: (getattr(reqs[i], "deadline", None)
                                      if getattr(reqs[i], "deadline", None)
                                      is not None else math.inf, i))
        for idx in order:
            req = reqs[idx]
            cands = self._candidates(req, servers, ccfg)
            if cands:
                best = min(cands, key=lambda s: (
                    self.predicted_first_token_s(s, req, now, ccfg), s.sid))
                return idx, best
            if not any(s.state in ("serving", "loading", "recovering")
                       and _capacity(s, ccfg.n_slots) for s in servers):
                return None       # fleet out of capacity: stop dispatching
        return None


@dataclass
class AdapterAffine:
    """Adapter-affinity first, SLO-aware otherwise.

    Among capacity-holding serving servers, prefer those whose batcher
    already has the request's adapter resident (admission needs no
    epoch-switch drain); break ties by the SLO-aware predicted
    first-token time.  When no affine server exists, fall back to the
    full SLO-aware scoring (which prices the epoch stall instead of
    forbidding it).
    """
    name: str = "adapter_affine"
    slo: SloAware = field(default_factory=SloAware)

    def select(self, queue, servers, now, ccfg):
        if not queue:
            return None
        picked = self.slo.select(queue, servers, now, ccfg)
        if picked is None:
            return None
        idx, fallback = picked
        req = queue[idx]
        affine = [s for s in servers
                  if s.admitting and _capacity(s, ccfg.n_slots)
                  and s.can_serve(req)
                  and req.adapter in s.srv.resident_adapters()]
        if not affine:
            return idx, fallback
        best = min(affine, key=lambda s: (
            self.slo.predicted_first_token_s(s, req, now, ccfg), s.sid))
        return idx, best


DISPATCH_POLICIES = {
    "least_loaded": LeastLoaded,
    "slo_aware": SloAware,
    "adapter_affine": AdapterAffine,
}


def make_dispatch(name: str) -> DispatchPolicy:
    """CLI/bench helper: dispatch policy by registry name."""
    try:
        return DISPATCH_POLICIES[name]()
    except KeyError:
        raise ValueError(f"unknown dispatch policy {name!r}; "
                         f"available: {sorted(DISPATCH_POLICIES)}") from None


# ---------------------------------------------------------------------------
# Placement policies
# ---------------------------------------------------------------------------

class PlacementPolicy(Protocol):
    """What a freshly spawned server preloads.

    The *pool* (base model) is already decided — each pool's autoscaler
    spawns into its own pool (``cluster/fleet.py``); placement narrows
    the pool's adapter set to what the new server merge-loads.  ``recent``
    is the router's recently-requested adapter names, most recent last.
    """

    name: str

    def adapters_for(self, all_adapters: Dict[str, Any],
                     recent: Sequence[str]) -> Dict[str, Any]: ...


@dataclass
class PreloadAll:
    """Every adapter the pool knows — the pre-refactor behaviour, and the
    right call while adapter sets are small."""
    name: str = "preload_all"

    def adapters_for(self, all_adapters, recent):
        return dict(all_adapters)


@dataclass
class HotAdapterPlacement:
    """Preload the ``k`` hottest adapters by recent request count (ties
    by recency), so a mid-burst scale-up pays k merge passes, not one per
    adapter the pool has ever seen.  Requests for non-resident adapters
    simply never dispatch to this server (``can_serve``) — they ride
    servers that do hold them."""
    k: int = 4
    name: str = "hot_adapters"

    def adapters_for(self, all_adapters, recent):
        seen = [a for a in recent if a in all_adapters]
        counts = Counter(seen)
        last_pos = {a: i for i, a in enumerate(seen)}
        hot = sorted(counts, key=lambda a: (-counts[a], -last_pos[a]))[:self.k]
        if not hot:                   # no history yet: behave like PreloadAll
            return dict(all_adapters)
        return {a: all_adapters[a] for a in hot}
