"""Modeled (JAX-free) cluster server for full-day trace replay.

The real ``ClusterServer`` runs an actual pipelined cold start and a real
continuous-batching decode per tick — exactly right for correctness tests
and small benches, and exactly wrong for replaying the ~10⁶ arrivals in a
full Azure Functions day on CPU.  ``SimServer`` keeps the *scheduling
surface* bit-compatible (state machine, ``load``/``admitting``/
``can_serve``/``predicted_ready_s``/``needs_tick``, a batcher facade with
``active``/``free``, resident adapters, queued requests) while modeling
the data plane:

* cold start: ready after ``SimProfile.ready_ticks`` ticks, fully loaded
  after ``full_ticks`` — the tick-count shape of the pipelined loader;
* decode: one token per active request per tick; admission emits the
  first token and the same tick's decode step emits the next, matching
  ``ServingEngine.step`` (admission prefill + batch decode per call);
* adapter epochs: the active batch shares one adapter (the merged-LoRA
  epoch barrier) — a queued request for a different adapter waits for a
  full drain.  FIFO with head-of-line barrier; a documented
  approximation of the epoch scheduler's budgeted rotation.

Because it plugs into ``ClusterRouter`` via ``server_factory``, every
piece above the server — dispatch policies, autoscaler, event engine,
metrics, traces — is the REAL code under test; only the token generation
is synthetic.  ``benchmarks/run.py``'s ``azure_day`` bench replays a
million-arrival day this way in seconds.

See ``docs/ARCHITECTURE.md`` § "Cluster: the modeled backend".
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.engine import ServeRequest


@dataclass
class SimProfile:
    """Tick-count shape of the modeled server's cold start.

    ``ready_ticks``/``full_ticks`` drive the default host fill; under
    multicast scale-out the fill is bandwidth-priced instead, as
    ``n_segments`` equal shares of ``bytes_total`` delivered by the
    ``MulticastManager`` (ready once the same ready/full *fraction* of
    segments has landed)."""
    ready_ticks: int = 2        # spawn -> admitting (1/N of the model in)
    full_ticks: int = 10        # spawn -> fully loaded (background fill)
    bytes_total: int = 1 << 30  # pretend checkpoint size (accounting only)
    n_segments: int = 8         # multicast granularity (segments per copy)
    # modeled KV footprint per cached prompt token: prices rows-less
    # PrefixCache entries (and thus state-tier spill bundles) in bytes
    kv_bytes_per_token: int = 1 << 12


class _SimBatcher:
    """Slot accounting shaped like ``serving.engine.ContinuousBatcher``:
    policies read ``.active`` (rid -> request) and ``.free`` (open slot
    ids) to price slot waits and epoch drains."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.active: Dict[int, ServeRequest] = {}
        self.free: List[int] = list(range(n_slots - 1, -1, -1))


class _SimServing:
    """``ServingEngine`` facade over modeled decode (the ``srv`` the
    scheduling policies introspect)."""

    def __init__(self, n_slots: int, adapter_params: Dict[str, Any]):
        self.adapter_params = adapter_params
        self.batcher = _SimBatcher(n_slots)
        self.pending: deque = deque()
        self.clock = 0.0
        self.epoch_adapter: Optional[str] = None
        self.n_steps = 0
        # modeled prefix-cache mirror (rows-less entries): token VALUES are
        # unchanged on a hit — only the hit/byte accounting moves, so the
        # tick==event stream-parity invariant is untouched
        self._pc = None
        self._pc_tag = "sim"
        self._pc_bytes_per_token = 1 << 12
        self._pc_evict_base = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        self.n_prefill_tokens = 0

    # ---- scheduling surface (mirrors ServingEngine) -----------------------
    @property
    def n_pending(self) -> int:
        return len(self.pending) + len(self.batcher.active)

    def queued_requests(self) -> List[ServeRequest]:
        return list(self.pending)

    def resident_adapters(self) -> set:
        if self.batcher.active:
            return {self.epoch_adapter}
        return set(self.adapter_params) | {None}

    def predicted_step_cost_s(self, default: float = 0.05) -> float:
        return default            # modeled: a decode step costs one tick

    def hotpath_stats(self) -> Dict[str, float]:
        evics = 0.0 if self._pc is None \
            else float(self._pc.evictions - self._pc_evict_base)
        return {"n_decode_steps": float(self.n_steps),
                "n_prefill_tokens": float(self.n_prefill_tokens),
                "prefix_hits": float(self.prefix_hits),
                "prefix_hit_tokens": float(self.prefix_hit_tokens),
                "prefix_evictions": evics}

    # ---- data plane (modeled) ---------------------------------------------
    def submit(self, req: ServeRequest) -> None:
        self.pending.append(req)

    def step(self, now: Optional[float] = None) -> List[ServeRequest]:
        """One modeled engine step: admit (first token), then decode one
        token for every active request — the call shape of
        ``ServingEngine.step``."""
        if now is not None:
            self.clock = max(self.clock, now)
        b = self.batcher
        # admission: FIFO under the epoch barrier (active batch shares one
        # adapter); the head blocking on an epoch switch waits for drain
        while self.pending and b.free:
            req = self.pending[0]
            if b.active and req.adapter != self.epoch_adapter:
                break
            self.pending.popleft()
            if not b.active:
                self.epoch_adapter = req.adapter
            req.slot = b.free.pop()
            b.active[req.rid] = req
            # prefix-cache probe (accounting only: the modeled token stream
            # never depends on cache state, mirroring the real engine's
            # bit-identical-to-cold-prefill guarantee)
            k = 0
            if self._pc is not None and not req.generated:
                hit = self._pc.probe(self._pc_tag, req.adapter,
                                     np.asarray(req.tokens, np.int64))
                if hit is not None:
                    entry, k = hit
                    self.prefix_hits += 1
                    self.prefix_hit_tokens += k
                    self._pc.release(entry)
            self.n_prefill_tokens += max(0, len(req.tokens) - k)
            if req.first_token_at is None:
                req.first_token_at = self.clock
            req.generated.append((req.rid + len(req.generated)) % 250)
        # decode: every active request (including just-admitted — same as
        # the real engine, where admission prefill precedes the batch step)
        done: List[ServeRequest] = []
        for rid in list(b.active):
            req = b.active[rid]
            req.generated.append((req.rid + len(req.generated)) % 250)
            if len(req.generated) >= req.max_new_tokens:
                req.finished_at = self.clock
                req.done = True
                b.free.append(req.slot)
                del b.active[rid]
                done.append(req)
                # deposit the finished prompt's prefix (rows-less entry,
                # priced at kv_bytes_per_token) — same >=2-token floor as
                # the real engine's _deposit_prefixes
                if self._pc is not None and len(req.tokens) >= 2:
                    toks = np.asarray(req.tokens, np.int64)
                    self._pc.insert(
                        self._pc_tag, req.adapter, toks, len(req.tokens),
                        rows=None,
                        nbytes=len(req.tokens) * self._pc_bytes_per_token)
        self.n_steps += 1
        return done

    def drain_inflight(self, export_state: bool = False
                       ) -> List[ServeRequest]:
        out = list(self.batcher.active.values()) + list(self.pending)
        for r in out:
            r.snapshot = None     # modeled backend has no KV to export
            r.slot = None
        self.batcher = _SimBatcher(self.batcher.n_slots)
        self.pending.clear()
        self.epoch_adapter = None
        return out


class SimServer:
    """Drop-in ``ClusterServer`` replacement with a modeled data plane.

    Pass ``sim_server_factory(profile)`` as ``ClusterRouter``'s
    ``server_factory`` — the router, autoscaler, and dispatch policies
    cannot tell the difference (same lifecycle states, same scheduling
    surface), but a tick costs ~microseconds instead of a JAX dispatch.
    """

    def __init__(self, sid: int, cfg, params, ccfg,
                 adapter_params: Optional[Dict[str, Any]] = None,
                 profile: Optional[SimProfile] = None):
        self.sid = sid
        self.cfg = cfg
        self.ccfg = ccfg
        self.profile = profile or SimProfile()
        self.srv = _SimServing(ccfg.n_slots, dict(adapter_params or {}))
        self.state = "loading"
        self.idle_ticks = 0
        self.idle_since: Optional[float] = None
        self.served_while_loading = False
        self.spawned_at = 0.0
        self.ready_at: Optional[float] = None
        self.fully_loaded_at: Optional[float] = None
        self._recover_left = 0
        self._load_ticks = 0
        self.last_recovery: Dict[str, float] = {}
        self.engine = self            # router reads s.engine.loaded_bytes()
        # multicast scale-out: when the router attaches a manager, fill
        # progress is delivered segments instead of counted load ticks
        self._mc = None
        self._segs_done = 0
        # state-tier resurrect: modeled pull cost in whole ticks, gating
        # the loading -> serving flip alongside the normal ready condition
        self.resurrect_cost_s = 0.0
        self._resurrect_ticks_left = 0

    # ---- state-tier surface (mirrors ClusterServer) -----------------------
    def attach_prefix_cache(self, cache) -> None:
        """Wire a (rows-less) ``PrefixCache`` into the modeled engine's
        admission accounting; eviction deltas rebase so a store moving
        between servers never double-counts."""
        self.srv._pc = cache
        self.srv._pc_tag = getattr(self.cfg, "name", None) or "sim"
        self.srv._pc_bytes_per_token = self.profile.kv_bytes_per_token
        self.srv._pc_evict_base = 0 if cache is None else cache.evictions

    def predicted_prefix_tokens(self, req: ServeRequest) -> int:
        """Cached-prefix tokens a dispatch of ``req`` here would reuse
        (pure read — ``SloAware.prefix_bonus_s_per_token`` pricing)."""
        pc = self.srv._pc
        if pc is None:
            return 0
        return pc.match_len(self.srv._pc_tag, req.adapter,
                            np.asarray(req.tokens, np.int64))

    def spill_state(self) -> Optional[Dict[str, Any]]:
        """Bundle this server's warm state for the ``StateTier`` (None
        when there is nothing worth spilling)."""
        pc = self.srv._pc
        if pc is None:
            return None
        entries = pc.export_entries()
        if not entries:
            return None
        return {"prefix_entries": entries,
                "adapters": dict(self.srv.adapter_params),
                "nbytes": int(sum(e.nbytes for _, e in entries))}

    def resurrect_from(self, bundle: Dict[str, Any],
                       cost_s: float = 0.0) -> int:
        """Seed this spawn from a spilled bundle; the modeled pull holds
        the server in ``loading`` for ``ceil(cost_s / tick_s)`` extra
        ticks (max-overlapped with the normal cold start, like the real
        lane's ``predicted_ready_s`` bound).  Returns entries admitted."""
        pc = self.srv._pc
        n = 0
        if pc is not None:
            n = pc.import_entries(bundle.get("prefix_entries", ()))
        for name, params in bundle.get("adapters", {}).items():
            self.srv.adapter_params.setdefault(name, params)
        self.resurrect_cost_s = max(self.resurrect_cost_s, float(cost_s))
        self._resurrect_ticks_left = max(
            self._resurrect_ticks_left,
            int(math.ceil(cost_s / max(self.ccfg.tick_s, 1e-9))))
        return n

    # ---- multicast surface (mirrors ClusterServer) ------------------------
    @property
    def _ready_segs(self) -> int:
        """Segments needed before admitting: the same ready fraction the
        tick-counted cold start uses (``ready_ticks/full_ticks``)."""
        p = self.profile
        return max(1, math.ceil(p.n_segments * p.ready_ticks
                                / max(1, p.full_ticks)))

    def mc_seg_bytes(self) -> List[int]:
        """Per-segment byte sizes of one model copy (equal shares of
        ``bytes_total``, remainder on the last segment)."""
        p = self.profile
        share = p.bytes_total // p.n_segments
        out = [share] * p.n_segments
        out[-1] += p.bytes_total - share * p.n_segments
        return out

    def mc_attach(self, manager) -> None:
        """Switch this server's fill to multicast deliveries."""
        self._mc = manager
        self._segs_done = 0

    def mc_deliver(self, segments: Sequence[int]) -> None:
        """Accept segments the manager finished streaming this tick."""
        self._segs_done += len(segments)

    @property
    def mc_active_sends(self) -> int:
        """Outbound multicast transfers this server is sourcing (0 when
        multicast is off) — priced by ``SloAware.source_penalty_s``."""
        return 0 if self._mc is None else self._mc.active_sends(self.sid)

    # ---- engine facade ----------------------------------------------------
    @property
    def fully_loaded(self) -> bool:
        if self._mc is not None:
            return self._segs_done >= self.profile.n_segments
        return self._load_ticks >= self.profile.full_ticks

    def loaded_bytes(self) -> int:
        """Modeled fill progress in bytes (delivered segments under
        multicast, linear in load ticks otherwise)."""
        if self._mc is not None:
            frac = min(1.0, self._segs_done / max(1, self.profile.n_segments))
        else:
            frac = min(1.0, self._load_ticks / max(1, self.profile.full_ticks))
        return int(self.profile.bytes_total * frac)

    def cold_start_stats(self) -> Dict[str, Any]:
        """Engine-facade stats (no wall-clock accounting: modeled)."""
        n_rounds = (self._segs_done if self._mc is not None
                    else self._load_ticks)
        return {"time_to_ready": None, "time_to_fully_loaded": None,
                "loaded_bytes": self.loaded_bytes(),
                "total_bytes": self.profile.bytes_total,
                "n_rounds": n_rounds}

    # ---- scheduling surface -----------------------------------------------
    @property
    def admitting(self) -> bool:
        return self.state == "serving"

    @property
    def degraded_devices(self) -> int:
        """Dead-device count while serving (surface parity with
        ``ClusterServer``).  Modeled servers have no device list, so a
        SimServer is never partially degraded: 0."""
        return 0

    @property
    def load(self) -> int:
        return self.srv.n_pending

    @property
    def needs_tick(self) -> bool:
        if self.state in ("down", "retired"):
            return False
        if self.state in ("loading", "recovering"):
            return True
        return bool(self.srv.n_pending) or not self.fully_loaded

    def can_serve(self, req: ServeRequest) -> bool:
        """Whether this server preloaded the request's adapter."""
        return req.adapter is None or req.adapter in self.srv.adapter_params

    def predicted_ready_s(self, now: float) -> float:
        """Seconds until admitting: remaining load/recovery ticks at
        nominal ``tick_s`` (0 serving, +inf down/retired)."""
        if self.state == "serving":
            return 0.0
        if self.state == "loading":
            if self._mc is not None:
                base = self._mc.eta_s(self.sid,
                                      self._ready_segs - self._segs_done)
            else:
                left = max(0, self.profile.ready_ticks - self._load_ticks)
                base = left * self.ccfg.tick_s
            # a state-tier pull overlaps the cold start; readiness is the
            # slower of the two (mirrors ClusterServer.predicted_ready_s)
            return max(base, self._resurrect_ticks_left * self.ccfg.tick_s)
        if self.state == "recovering":
            return max(0, self._recover_left) * self.ccfg.tick_s
        return math.inf

    @property
    def oldest_queued_arrival(self) -> Optional[float]:
        waiting = [r.arrival for r in self.srv.pending
                   if r.first_token_at is None]
        return min(waiting) if waiting else None

    def submit(self, req: ServeRequest) -> None:
        """Queue a dispatched request on the modeled serving engine."""
        self.srv.submit(req)

    # ---- lifecycle (mirrors ClusterServer.tick) ---------------------------
    def tick(self, now: float) -> List[ServeRequest]:
        """One lifecycle tick, mirroring ``ClusterServer.tick``: load
        progress (ready flip serves the SAME tick), recovery countdown,
        background fill, one modeled engine step, idle bookkeeping."""
        if self.state == "loading":
            if self._resurrect_ticks_left > 0:
                self._resurrect_ticks_left -= 1   # state-tier pull in flight
            if self._mc is None:
                self._load_ticks += 1
                if self._load_ticks < self.profile.ready_ticks:
                    return []
            elif self._segs_done < self._ready_segs:
                return []       # multicast fill: waiting on deliveries
            if self._resurrect_ticks_left > 0:
                return []       # warm pull outlives the cold start: wait
            self.state = "serving"
            if self.ready_at is None:
                self.ready_at = now
        if self.state == "recovering":
            self._recover_left -= 1
            if self._recover_left <= 0:
                self.state = "serving"
            return []
        if self.state in ("down", "retired"):
            return []
        if not self.fully_loaded:
            if self._mc is None:
                self._load_ticks += 1   # background fill (host ticks)
            if self.srv.n_pending:
                self.served_while_loading = True
        if self.fully_loaded and self.fully_loaded_at is None:
            self.fully_loaded_at = now
        done = self.srv.step(now=now)
        if self.srv.n_pending:
            self.idle_ticks = 0
            self.idle_since = None
        else:
            self.idle_ticks += 1
            if self.idle_since is None:
                self.idle_since = now
        return done

    def cold_start_record(self) -> Dict[str, Any]:
        """Cold-start accounting in ``ClusterServer.cold_start_record``'s
        exact shape (wall fields None: modeled)."""
        eng = self.cold_start_stats()
        rdy, ful = self.ready_at, self.fully_loaded_at
        return {
            "server": self.sid,
            "time_to_ready": (None if rdy is None
                              else max(0.0, rdy - self.spawned_at)),
            "time_to_fully_loaded": (None if ful is None
                                     else max(0.0, ful - self.spawned_at)),
            "served_while_loading": self.served_while_loading,
            "wall_time_to_ready": eng["time_to_ready"],
            "wall_time_to_fully_loaded": eng["time_to_fully_loaded"],
            "loaded_bytes": eng["loaded_bytes"],
            "total_bytes": eng["total_bytes"],
            "n_rounds": eng["n_rounds"],
        }

    def crash(self, device_ids: Optional[Sequence[int]] = None
              ) -> List[ServeRequest]:
        """Whole-server crash only (the modeled backend has no per-device
        KV state to partially lose): drains everything for re-dispatch."""
        self.last_recovery = {}
        drained = self.srv.drain_inflight()
        self.state = "down"
        return drained

    def rejoin(self) -> None:
        """Reboot after a crash: full cold start from zero load ticks
        (and zero delivered segments under multicast)."""
        self.state = "loading"
        self._load_ticks = 0
        self._segs_done = 0
        self._resurrect_ticks_left = 0
        self.ready_at = None
        self.fully_loaded_at = None
        self.served_while_loading = False

    def retire(self) -> List[ServeRequest]:
        """Voluntary scale-down; leftovers re-queue through dispatch."""
        leftovers = self.srv.drain_inflight()
        self.state = "retired"
        return leftovers


def sim_server_factory(profile: Optional[SimProfile] = None):
    """A ``server_factory`` for ``ClusterRouter``: every spawned server is
    a ``SimServer`` with the given cold-start profile."""
    def factory(sid, cfg, params, ccfg, adapter_params=None):
        return SimServer(sid, cfg, params, ccfg, adapter_params,
                         profile=profile)
    return factory
