"""Multi-model fleet: named per-model server pools on one shared clock.

PipeBoost's serverless scenario (§2.1) is many functions sharing a few
base models and differing by adapter.  A ``Fleet`` maps that onto named
``ModelPool``s — each pool is a full ``ClusterRouter`` (queue, lifecycle,
crash re-route, its own autoscaler and dispatch/placement policies) over
its base model's params — while the fleet owns what must be shared:

* one injected ``Clock`` (logical or wall — same code either way),
* one ``ClusterMetrics`` store (cross-pool percentiles + per-model
  breakdown via ``summary_by_model``; request ids are fleet-global),
* trace demux: ``Arrival.model`` routes each request to its pool.

Pools over the *same* base model can share one params pytree (pass the
same object to several specs) — the functional analogue of N pools of
servers loading segments of one checkpoint, which is exactly the
many-adapters-one-base fleet the paper's premise implies.

``Fleet.run`` is discrete-event by default, like the router's: while any
pool has work it ticks every pool densely against the shared clock; when
EVERY pool is quiescent it jumps straight to the earliest next event
across the fleet.  See ``docs/ARCHITECTURE.md`` § "Cluster: multi-model
fleets".
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

from repro.cluster.autoscaler import Autoscaler
from repro.cluster.metrics import ClusterMetrics
from repro.cluster.router import ClusterConfig, ClusterRouter
from repro.cluster.scheduler import (Clock, DispatchPolicy, LogicalClock,
                                     PlacementPolicy)
from repro.cluster.state_tier import StateTier
from repro.cluster.traces import Arrival, arrival_stream


@dataclass
class PoolSpec:
    """One model pool's recipe: base model + sizing + policies.  Fields
    left None fall back to the ``ClusterRouter`` defaults."""
    cfg: Any                                  # ArchConfig of the base model
    params: Any                               # base params (shareable)
    n_servers: int = 1
    ccfg: Optional[ClusterConfig] = None
    autoscaler: Optional[Autoscaler] = None
    adapter_params: Optional[Dict[str, Any]] = None
    dispatch: Optional[DispatchPolicy] = None
    placement: Optional[PlacementPolicy] = None
    server_factory: Any = None      # ClusterServer-like ctor (sim backends)
    materialize_prompts: bool = True
    # warm-state spill/resurrect across scale-down/up (one StateTier can
    # be shared fleet-wide: bundles are keyed by pool name); None = off
    state_tier: Optional[StateTier] = None


class Fleet:
    """Named per-model pools sharing a clock, metrics, and rid space."""

    def __init__(self, pools: Dict[str, PoolSpec], *,
                 clock: Optional[Clock] = None,
                 metrics: Optional[ClusterMetrics] = None,
                 default_model: Optional[str] = None):
        if not pools:
            raise ValueError("a fleet needs at least one pool")
        self._clock: Clock = clock or LogicalClock()
        self.metrics = metrics or ClusterMetrics()
        self.metrics.clock = self._clock
        self.default_model = default_model or next(iter(pools))
        if self.default_model not in pools:
            raise ValueError(f"default_model {self.default_model!r} is not "
                             f"a pool: {sorted(pools)}")
        rid = itertools.count()
        self.pools: Dict[str, ClusterRouter] = {}
        for name, spec in pools.items():
            self.pools[name] = ClusterRouter(
                spec.cfg, spec.params, n_servers=spec.n_servers,
                ccfg=spec.ccfg, autoscaler=spec.autoscaler,
                adapter_params=spec.adapter_params, metrics=self.metrics,
                dispatch=spec.dispatch, placement=spec.placement,
                clock=self._clock, model=name, rid_counter=rid,
                server_factory=spec.server_factory,
                materialize_prompts=spec.materialize_prompts,
                state_tier=spec.state_tier)

    @property
    def clock(self) -> float:
        return self._clock.now()

    def pool_for(self, arrival: Arrival) -> ClusterRouter:
        """The pool an arrival routes to (``Arrival.model``, or the
        fleet's default pool when the trace leaves it unset)."""
        name = arrival.model or self.default_model
        if name not in self.pools:
            raise ValueError(f"trace names model {name!r} but the fleet "
                             f"has pools for {sorted(self.pools)}")
        return self.pools[name]

    def submit(self, arrival: Arrival) -> int:
        """Demux one arrival to its pool; returns the fleet-global rid."""
        return self.pool_for(arrival).submit(arrival)

    def crash_server(self, model: str, sid: int,
                     device_ids: Optional[Sequence[int]] = None) -> None:
        """Crash server ``sid`` of pool ``model`` (all devices, or the
        ``device_ids`` subset for a partial crash)."""
        self.pools[model].crash_server(sid, device_ids)

    @property
    def pending(self) -> int:
        return sum(p.pending for p in self.pools.values())

    def tick(self) -> List:
        """One fleet tick: every pool ticks against the shared clock, then
        the clock advances ONCE (pools must agree on tick_s — asserted at
        run time, not assumed).  ``now`` is frozen across the pools so
        their gauges/events share one timestamp under wall clocks too."""
        now = self._clock.now()
        finished: List = []
        for pool in self.pools.values():
            finished.extend(pool.tick(advance=False, now=now))
        self._clock.advance(self._tick_s())
        return finished

    def _tick_s(self) -> float:
        ticks = {p.ccfg.tick_s for p in self.pools.values()}
        if len(ticks) != 1:
            raise ValueError(f"pools disagree on tick_s: {sorted(ticks)}")
        return next(iter(ticks))

    def run(self, trace, *, max_ticks: int = 200_000,
            engine: str = "event") -> List:
        """Replay a (multi-model) trace across the pools to completion.

        ``trace`` may be a sequence (sorted here) or a time-ordered
        iterator.  ``engine="event"`` (default) jumps the shared clock
        across fleet-wide quiescent gaps to the earliest next event of
        any pool; ``engine="tick"`` polls every tick (the equivalence
        oracle, identical token streams)."""
        if engine not in ("event", "tick"):
            raise ValueError(f"unknown engine {engine!r}; "
                             "expected 'event' or 'tick'")
        stream = arrival_stream(trace)
        nxt = next(stream, None)
        tick_s = self._tick_s()
        completed: List = []
        t = 0
        while t < max_ticks:
            while nxt is not None and nxt.time <= self.clock:
                self.submit(nxt)
                nxt = next(stream, None)
            if engine == "event" and all(p.quiescent
                                         for p in self.pools.values()):
                now = self.clock
                cands = [c for p in self.pools.values()
                         if (c := p.next_event_time()) is not None]
                if nxt is not None:
                    cands.append(nxt.time)
                if not cands:
                    break       # nothing can ever wake any pool again
                t_evt = min(cands)
                if t_evt - now > tick_s * 1e-6:
                    k = max(1, math.ceil((t_evt - now) / tick_s - 1e-9))
                    k = min(k, max_ticks - t)
                    t_wake = now + k * tick_s
                    for p in self.pools.values():
                        p._settle_gap(t_wake)
                    self._clock.sleep_until(t_wake)
                    t += k
                    continue
                # earliest event is due now: process it as a dense tick
            completed.extend(self.tick())
            t += 1
            if nxt is None and self.pending == 0:
                break
            # liveness: stop when EVERY pool is either done or provably
            # stuck (see ClusterRouter.stalled) — a pool still making
            # progress keeps the fleet ticking.  Evaluate every pool
            # (no short-circuit): stalled() advances per-pool counters.
            states = [(p, p.stalled(arrivals_left=nxt is not None))
                      for p in self.pools.values()]
            if self.pending and all(st or p.pending == 0
                                    for p, st in states):
                break
        for pool in self.pools.values():
            pool.finalize_metrics()
        return completed
