"""Multi-model fleet: named per-model server pools on one shared clock.

PipeBoost's serverless scenario (§2.1) is many functions sharing a few
base models and differing by adapter.  A ``Fleet`` maps that onto named
``ModelPool``s — each pool is a full ``ClusterRouter`` (queue, lifecycle,
crash re-route, its own autoscaler and dispatch/placement policies) over
its base model's params — while the fleet owns what must be shared:

* one injected ``Clock`` (logical or wall — same code either way),
* one ``ClusterMetrics`` store (cross-pool percentiles + per-model
  breakdown via ``summary_by_model``; request ids are fleet-global),
* trace demux: ``Arrival.model`` routes each request to its pool.

Pools over the *same* base model can share one params pytree (pass the
same object to several specs) — the functional analogue of N pools of
servers loading segments of one checkpoint, which is exactly the
many-adapters-one-base fleet the paper's premise implies.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

from repro.cluster.autoscaler import Autoscaler
from repro.cluster.metrics import ClusterMetrics
from repro.cluster.router import ClusterConfig, ClusterRouter
from repro.cluster.scheduler import (Clock, DispatchPolicy, LogicalClock,
                                     PlacementPolicy)
from repro.cluster.traces import Arrival


@dataclass
class PoolSpec:
    """One model pool's recipe: base model + sizing + policies.  Fields
    left None fall back to the ``ClusterRouter`` defaults."""
    cfg: Any                                  # ArchConfig of the base model
    params: Any                               # base params (shareable)
    n_servers: int = 1
    ccfg: Optional[ClusterConfig] = None
    autoscaler: Optional[Autoscaler] = None
    adapter_params: Optional[Dict[str, Any]] = None
    dispatch: Optional[DispatchPolicy] = None
    placement: Optional[PlacementPolicy] = None


class Fleet:
    """Named per-model pools sharing a clock, metrics, and rid space."""

    def __init__(self, pools: Dict[str, PoolSpec], *,
                 clock: Optional[Clock] = None,
                 metrics: Optional[ClusterMetrics] = None,
                 default_model: Optional[str] = None):
        if not pools:
            raise ValueError("a fleet needs at least one pool")
        self._clock: Clock = clock or LogicalClock()
        self.metrics = metrics or ClusterMetrics()
        self.metrics.clock = self._clock
        self.default_model = default_model or next(iter(pools))
        if self.default_model not in pools:
            raise ValueError(f"default_model {self.default_model!r} is not "
                             f"a pool: {sorted(pools)}")
        rid = itertools.count()
        self.pools: Dict[str, ClusterRouter] = {}
        for name, spec in pools.items():
            self.pools[name] = ClusterRouter(
                spec.cfg, spec.params, n_servers=spec.n_servers,
                ccfg=spec.ccfg, autoscaler=spec.autoscaler,
                adapter_params=spec.adapter_params, metrics=self.metrics,
                dispatch=spec.dispatch, placement=spec.placement,
                clock=self._clock, model=name, rid_counter=rid)

    @property
    def clock(self) -> float:
        return self._clock.now()

    def pool_for(self, arrival: Arrival) -> ClusterRouter:
        name = arrival.model or self.default_model
        if name not in self.pools:
            raise ValueError(f"trace names model {name!r} but the fleet "
                             f"has pools for {sorted(self.pools)}")
        return self.pools[name]

    def submit(self, arrival: Arrival) -> int:
        return self.pool_for(arrival).submit(arrival)

    def crash_server(self, model: str, sid: int,
                     device_ids: Optional[Sequence[int]] = None) -> None:
        self.pools[model].crash_server(sid, device_ids)

    @property
    def pending(self) -> int:
        return sum(p.pending for p in self.pools.values())

    def tick(self) -> List:
        """One fleet tick: every pool ticks against the shared clock, then
        the clock advances ONCE (pools must agree on tick_s — asserted at
        run time, not assumed).  ``now`` is frozen across the pools so
        their gauges/events share one timestamp under wall clocks too."""
        now = self._clock.now()
        finished: List = []
        for pool in self.pools.values():
            finished.extend(pool.tick(advance=False, now=now))
        self._clock.advance(self._tick_s())
        return finished

    def _tick_s(self) -> float:
        ticks = {p.ccfg.tick_s for p in self.pools.values()}
        if len(ticks) != 1:
            raise ValueError(f"pools disagree on tick_s: {sorted(ticks)}")
        return next(iter(ticks))

    def run(self, trace: Sequence[Arrival], *,
            max_ticks: int = 200_000) -> List:
        """Replay a (multi-model) trace across the pools to completion."""
        arrivals = sorted(trace, key=lambda a: a.time)
        i = 0
        completed: List = []
        for _ in range(max_ticks):
            while i < len(arrivals) and arrivals[i].time <= self.clock:
                self.submit(arrivals[i])
                i += 1
            completed.extend(self.tick())
            if i >= len(arrivals) and self.pending == 0:
                break
            # liveness: stop when EVERY pool is either done or provably
            # stuck (see ClusterRouter.stalled) — a pool still making
            # progress keeps the fleet ticking.  Evaluate every pool
            # (no short-circuit): stalled() advances per-pool counters.
            states = [(p, p.stalled(arrivals_left=i < len(arrivals)))
                      for p in self.pools.values()]
            if self.pending and all(st or p.pending == 0
                                    for p, st in states):
                break
        for pool in self.pools.values():
            pool.finalize_metrics()
        return completed
