"""SLO-driven autoscaler for PipeBoost server fleets (paper §2.1, §4.1).

The point of PipeBoost's fast cold start is that scaling out on a burst is
*cheap*: a fresh multi-GPU server admits traffic after each device loads
only ~1/N of the model.  The autoscaler exploits exactly that — it watches
queue pressure and head-of-line wait (a TTFT SLO proxy) and cold-starts a
new server the moment either degrades, instead of over-provisioning.

Decisions are *time-based*, not call-count-based: spawn cooldown and idle
retirement compare against the injected clock's ``now``, so the same
policy behaves identically under a ``LogicalClock`` tick loop, the
discrete-event engine (which calls ``decide`` at irregular intervals), and
a ``WallClock`` fleet (where "200 ticks idle" used to mean milliseconds of
real time).  The legacy tick thresholds are kept as deriving defaults:
``idle_ticks_before_retire * tick_s`` seconds unless
``idle_seconds_before_retire`` is set explicitly.

Pure policy, no JAX: ``decide`` maps observed cluster state to actions; the
router executes them (spawn => ``ClusterServer`` cold start, retire =>
drain + shutdown of an idle replica).  See ``docs/ARCHITECTURE.md``
§ "Cluster: autoscaling".
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence


@dataclass
class AutoscalerConfig:
    """Scale-out/retire thresholds (see module docstring for the time
    semantics of the cooldown and idle fields)."""
    target_queue_per_server: float = 4.0   # pending reqs per admitting server
    ttft_slo_s: float = 1.0                # head-of-line wait budget
    max_servers: int = 8
    min_servers: int = 1
    scale_up_cooldown_ticks: int = 5       # between consecutive spawns
    idle_ticks_before_retire: int = 200
    max_warming: int = 1                   # concurrent cold starts
    spawn_batch: int = 1                   # servers per pressured spawn
    # decision (multicast scale-out makes N simultaneous cold starts cost
    # ~one host read, so bursts can spawn in batches; 1 = legacy)
    # time-based overrides; None derives seconds from the tick thresholds
    # above (ticks * tick_s) so existing configs keep their behaviour
    scale_up_cooldown_s: Optional[float] = None
    idle_seconds_before_retire: Optional[float] = None


@dataclass
class ScaleDecision:
    """One round's actions: how many servers to spawn, which to retire."""
    spawn: int = 0
    retire: List[int] = field(default_factory=list)  # server ids to retire


class Autoscaler:
    """Maps observed fleet state to spawn/retire decisions each round;
    stateful only for the spawn cooldown and scale-op counters."""

    def __init__(self, cfg: AutoscalerConfig = None):
        self.cfg = cfg or AutoscalerConfig()
        self._cooldown_until = -1.0
        self.n_scale_ups = 0
        self.n_retires = 0

    def _cooldown_s(self, tick_s: float) -> float:
        if self.cfg.scale_up_cooldown_s is not None:
            return self.cfg.scale_up_cooldown_s
        return self.cfg.scale_up_cooldown_ticks * tick_s

    def _idle_s(self, tick_s: float) -> float:
        if self.cfg.idle_seconds_before_retire is not None:
            return self.cfg.idle_seconds_before_retire
        return self.cfg.idle_ticks_before_retire * tick_s

    def _idle_long_enough(self, s, now: float, tick_s: float) -> bool:
        # time-based when the server tracks idle_since (ClusterServer);
        # tick-count fallback keeps bare test fakes working
        since = getattr(s, "idle_since", None)
        if since is not None:
            return now - since >= self._idle_s(tick_s) - 1e-9
        return s.idle_ticks >= self.cfg.idle_ticks_before_retire

    def decide(self, now: float, pending: int, oldest_wait: float,
               servers: Sequence, tick_s: float = 0.05) -> ScaleDecision:
        """One decision per dispatch round (tick or event).

        ``pending``: router queue + per-server queued/in-flight requests.
        ``oldest_wait``: age of the oldest not-yet-first-token request.
        ``servers``: ClusterServer-likes exposing .state/.admitting/
        .idle_ticks/.sid (and .idle_since for time-based retirement).
        ``tick_s``: nominal tick length, used only to derive seconds from
        legacy tick-count thresholds.
        """
        cfg = self.cfg
        out = ScaleDecision()
        admitting = [s for s in servers if s.admitting]
        warming = [s for s in servers if s.state == "loading"]
        # downed servers count against the cap — they may rejoin, and the
        # cap bounds the provisioned fleet, not just the healthy slice
        live = [s for s in servers if s.state != "retired"]

        per_server = pending / max(1, len(admitting))
        pressured = (per_server > cfg.target_queue_per_server
                     or oldest_wait > cfg.ttft_slo_s)
        if (pressured and now >= self._cooldown_until - 1e-9
                and len(warming) < cfg.max_warming
                and len(live) < cfg.max_servers):
            # batch spawn bounded by both caps (the guard above makes each
            # headroom >= 1, so spawn_batch=1 reproduces legacy decisions)
            out.spawn = max(1, min(cfg.spawn_batch,
                                   cfg.max_warming - len(warming),
                                   cfg.max_servers - len(live)))
            self._cooldown_until = now + self._cooldown_s(tick_s)
            self.n_scale_ups += out.spawn

        if pending == 0:
            for s in admitting:
                if (self._idle_long_enough(s, now, tick_s)
                        and len(live) - len(out.retire) > cfg.min_servers):
                    out.retire.append(s.sid)
                    self.n_retires += 1
        return out

    def next_retire_time(self, servers: Sequence,
                         tick_s: float = 0.05) -> Optional[float]:
        """Earliest future instant an idle server becomes retirable — the
        event engine's "idle deadline" event.  None when no retirement can
        fire (nothing idle, or the min_servers floor would block it)."""
        cfg = self.cfg
        live = [s for s in servers if s.state != "retired"]
        if len(live) <= cfg.min_servers:
            return None
        idle_s = self._idle_s(tick_s)
        times = [s.idle_since + idle_s for s in live
                 if s.admitting and getattr(s, "idle_since", None) is not None]
        return min(times) if times else None
