"""SLO-driven autoscaler for PipeBoost server fleets (paper §2.1, §4.1).

The point of PipeBoost's fast cold start is that scaling out on a burst is
*cheap*: a fresh multi-GPU server admits traffic after each device loads
only ~1/N of the model.  The autoscaler exploits exactly that — it watches
queue pressure and head-of-line wait (a TTFT SLO proxy) and cold-starts a
new server the moment either degrades, instead of over-provisioning.

Pure policy, no JAX: ``decide`` maps observed cluster state to actions; the
router executes them (spawn => ``ClusterServer`` cold start, retire =>
drain + shutdown of an idle replica).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence


@dataclass
class AutoscalerConfig:
    target_queue_per_server: float = 4.0   # pending reqs per admitting server
    ttft_slo_s: float = 1.0                # head-of-line wait budget
    max_servers: int = 8
    min_servers: int = 1
    scale_up_cooldown_ticks: int = 5       # between consecutive spawns
    idle_ticks_before_retire: int = 200
    max_warming: int = 1                   # concurrent cold starts


@dataclass
class ScaleDecision:
    spawn: int = 0
    retire: List[int] = field(default_factory=list)  # server ids to retire


class Autoscaler:
    def __init__(self, cfg: AutoscalerConfig = None):
        self.cfg = cfg or AutoscalerConfig()
        self._cooldown = 0
        self.n_scale_ups = 0
        self.n_retires = 0

    def decide(self, now: float, pending: int, oldest_wait: float,
               servers: Sequence) -> ScaleDecision:
        """One decision per router tick.

        ``pending``: router queue + per-server queued/in-flight requests.
        ``oldest_wait``: age of the oldest not-yet-first-token request.
        ``servers``: ClusterServer-likes exposing .state/.admitting/
        .idle_ticks/.sid.
        """
        cfg = self.cfg
        out = ScaleDecision()
        self._cooldown = max(0, self._cooldown - 1)
        admitting = [s for s in servers if s.admitting]
        warming = [s for s in servers if s.state == "loading"]
        # downed servers count against the cap — they may rejoin, and the
        # cap bounds the provisioned fleet, not just the healthy slice
        live = [s for s in servers if s.state != "retired"]

        per_server = pending / max(1, len(admitting))
        pressured = (per_server > cfg.target_queue_per_server
                     or oldest_wait > cfg.ttft_slo_s)
        if (pressured and self._cooldown == 0
                and len(warming) < cfg.max_warming
                and len(live) < cfg.max_servers):
            out.spawn = 1
            self._cooldown = cfg.scale_up_cooldown_ticks
            self.n_scale_ups += 1

        if pending == 0:
            for s in admitting:
                if (s.idle_ticks >= cfg.idle_ticks_before_retire
                        and len(live) - len(out.retire) > cfg.min_servers):
                    out.retire.append(s.sid)
                    self.n_retires += 1
        return out
