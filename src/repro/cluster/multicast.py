"""Peer-to-peer multicast scale-out: warm servers as load sources.

Scale-up used to pull every new server's model copy from host DRAM at
``host_link_bw`` — N simultaneous cold starts read the checkpoint N times
and contend for ``host_agg_bw``.  This module implements the λScale /
HydraServe direction (ROADMAP open item 1): spawning servers pull layer
*segments* from warm or partially-warm peers over ``ici_bw`` instead, and
every receiver relays segments it already holds onward — a chain
(``fanout=1``) or binary tree (``fanout=2``) propagation in which N
simultaneous cold starts cost ~one host read of aggregate host traffic.

The transfer economics come from the ``HwModel`` cost model in
``core/simulator.py`` (the same one ``snapshot_transfer_time`` prices
migrations with): peer links move bytes at ``hw.ici_bw`` plus one
``hw.hop_latency`` per segment; host pulls move at
``host_bw_effective(hw, concurrent)`` so simultaneous host streams share
the aggregate read path.  ``MulticastManager.advance(now, dt)`` moves the
fluid model forward one router tick; completed segments are handed to
their receivers *before* the servers tick, so the PR 4 overlapped-fill
machinery (same-tick ready flips, serving mid-fill) works unchanged on
top.

Fault tolerance — the robustness core:

* **source crash**: every transfer sourced from the victim aborts;
  receivers keep all fully-received segments (resume, never restart from
  zero) and re-root onto a surviving holder the next tick.
* **orphaned segment**: if a segment some peer once held has no live
  holder, the receiver retries with exponential backoff
  (``retry_backoff_s * 2^(n-1)``) up to ``max_retries`` times — a peer
  mid-pull may complete it — then degrades gracefully to a host fill
  (counted as ``host_fallbacks``).
* **receiver crash**: its inbound transfer dies with it; its children
  re-root like any source loss.  On rejoin the router re-registers it as
  a fresh receiver.

Everything here is deterministic pure-Python bookkeeping (no JAX, no wall
clock, no RNG): receivers are processed in sid order and transfers move
by per-tick byte budgets, so the tick and event cluster engines — which
both tick densely while any server is loading — execute the same
schedule bit-for-bit.

See ``docs/ARCHITECTURE.md`` § "Cluster: multicast scale-out".
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set

from repro.core.simulator import (GPU_PAPER, HwModel, host_bw_effective,
                                  snapshot_transfer_time)

# multicast propagation shapes: "chain" relays through one child per
# source, "tree" through two, "host" disables peer serving entirely (every
# receiver pulls from host under the shared-aggregate cost model — the
# honest contended baseline bench_multicast compares against)
TOPOLOGIES = ("chain", "tree", "host")


@dataclass(frozen=True)
class MulticastConfig:
    """Shape and fault-handling knobs of one fleet's multicast scale-out.

    ``fanout`` is the max concurrent outbound transfers per source; None
    derives it from the topology (chain=1, tree=2, host=0).
    ``max_retries``/``retry_backoff_s`` bound the search for a surviving
    holder of an orphaned segment before degrading to host fill.
    """
    topology: str = "tree"
    hw: HwModel = GPU_PAPER
    fanout: Optional[int] = None
    max_retries: int = 3
    retry_backoff_s: float = 0.1

    def __post_init__(self):
        if self.topology not in TOPOLOGIES:
            raise ValueError(f"unknown multicast topology "
                             f"{self.topology!r}; available: {TOPOLOGIES}")

    @property
    def effective_fanout(self) -> int:
        """Outbound transfer slots per source (explicit or per-topology)."""
        if self.fanout is not None:
            return self.fanout
        return {"chain": 1, "tree": 2, "host": 0}[self.topology]


@dataclass
class _Receiver:
    """One spawning server's multicast state: which segments it holds,
    the transfer in flight to it, and its retry ladder for orphaned
    segments.  A completed receiver stays registered — it is the warmest
    possible relay source."""
    sid: int
    seg_bytes: List[int]
    have: Set[int] = field(default_factory=set)
    seg: Optional[int] = None        # segment in flight (None = idle)
    source: Optional[int] = None     # peer sid, or None = host pull
    parent: Optional[int] = None     # current propagation-tree parent:
    # the peer the LAST transfer came from (None = this receiver roots
    # at host); source preference keeps a receiver riding its parent, so
    # the edge persists between transfers and a parent crash re-roots it
    lat_left: float = 0.0            # hop latency still to pay
    bytes_left: float = 0.0          # segment bytes still to move
    retries: int = 0                 # consecutive holderless attempts
    next_try: float = -math.inf      # backoff deadline for the next attempt

    @property
    def done(self) -> bool:
        """True once every segment has been fully received."""
        return len(self.have) >= len(self.seg_bytes)

    def head(self) -> Optional[int]:
        """Next segment to fetch (in-order fill; None when done)."""
        for s in range(len(self.seg_bytes)):
            if s not in self.have:
                return s
        return None

    def abort(self) -> None:
        """Drop the in-flight transfer (source died): completed segments
        are kept — resume from the last fully-received segment."""
        self.seg = None
        self.source = None
        self.lat_left = 0.0
        self.bytes_left = 0.0
        self.next_try = -math.inf


class MulticastManager:
    """Segment-granular multicast scheduler for one router's fleet.

    The router registers every spawned server as a receiver
    (``register_receiver``) and optionally warm non-receiver servers as
    sources (``register_source``); ``advance`` runs once per dense tick
    and returns ``{sid: [segments completed]}`` for the router to deliver
    before the servers tick.  ``remove`` reacts to crashes/retires by
    aborting the victim's transfers and re-rooting its dependents;
    ``stats`` feeds ``ClusterMetrics.on_multicast``.
    """

    def __init__(self, cfg: Optional[MulticastConfig] = None):
        self.cfg = cfg or MulticastConfig()
        self.receivers: Dict[int, _Receiver] = {}
        # warm servers that are sources WITHOUT being receivers
        # (sid -> segments held); receivers relay implicitly via `have`
        self.sources: Dict[int, Set[int]] = {}
        # segments that have ever been fully held by anyone: a missing
        # holder for a seeded segment means a source DIED (retry ladder),
        # an unseeded segment simply has not been bootstrapped yet (pull
        # it from host without burning retries)
        self._seeded: Set[int] = set()
        self._stats: Dict[str, float] = {
            "peer_bytes": 0.0, "host_bytes": 0.0,
            "peer_segments": 0.0, "host_segments": 0.0,
            "reroots": 0.0, "retries": 0.0, "host_fallbacks": 0.0,
            "stalled_seconds": 0.0,
        }

    # ---- membership -------------------------------------------------------
    def register_receiver(self, sid: int,
                          seg_bytes: Sequence[int]) -> None:
        """Enroll a spawning server (fresh or rejoining) as a receiver of
        one full model copy, segment by segment."""
        self.receivers[sid] = _Receiver(sid, [int(b) for b in seg_bytes])

    def register_source(self, sid: int, segments: Sequence[int]) -> None:
        """Enroll (or refresh) a warm non-receiver server as a source
        holding ``segments``; receivers never need this — their ``have``
        set makes them relays automatically."""
        held = set(int(s) for s in segments)
        self.sources[sid] = held
        self._seeded |= held

    def remove(self, sid: int) -> None:
        """A server left the fleet (crash or retire): abort its inbound
        transfer and re-root every dependent — a receiver whose active
        transfer it was sourcing OR whose propagation-tree parent it was.
        Dependents keep all fully-received segments (resume from the last
        complete segment, never restart) and pick a surviving source on
        the next advance; each counts one ``reroots``.  The victim is
        forgotten as a holder."""
        for r in self.receivers.values():
            dependent = ((r.seg is not None and r.source == sid)
                         or r.parent == sid)
            if r.seg is not None and r.source == sid:
                r.abort()
            if dependent and not r.done:
                r.parent = None
                r.next_try = -math.inf
                self._stats["reroots"] += 1.0
        self.sources.pop(sid, None)
        self.receivers.pop(sid, None)

    # ---- introspection ----------------------------------------------------
    @property
    def active(self) -> bool:
        """True while any receiver still has segments to fetch."""
        return any(not r.done for r in self.receivers.values())

    def receiver_done(self, sid: int) -> bool:
        """Has ``sid``'s model copy fully arrived (True for unknowns, so
        non-multicast servers background-fill normally)?"""
        r = self.receivers.get(sid)
        return r is None or r.done

    def active_sends(self, sid: int) -> int:
        """Outbound transfers ``sid`` is currently sourcing (the load the
        SLO-aware dispatch can price via ``source_penalty_s``)."""
        return sum(1 for r in self.receivers.values()
                   if r.seg is not None and r.source == sid)

    def eta_s(self, sid: int, n_segments: Optional[int] = None) -> float:
        """Optimistic seconds until ``sid``'s next ``n_segments`` pending
        segments land (all of them when None): each priced like a peer
        snapshot transfer (``snapshot_transfer_time`` over the nvlink/ICI
        link) — the signal ``predicted_ready_s`` surfaces to dispatch."""
        r = self.receivers.get(sid)
        if r is None:
            return 0.0
        pending = [s for s in range(len(r.seg_bytes)) if s not in r.have]
        if n_segments is not None:
            pending = pending[:max(0, n_segments)]
        return sum(snapshot_transfer_time(r.seg_bytes[s], self.cfg.hw,
                                          link="nvlink") for s in pending)

    def stats(self) -> Dict[str, float]:
        """Session accounting: bytes/segments by source kind, re-roots,
        retries, host fallbacks, receiver stall time."""
        return dict(self._stats)

    # ---- the fluid transfer model -----------------------------------------
    def _holders(self, seg: int, exclude: int) -> List[int]:
        """Live servers (receivers or warm sources) holding ``seg``."""
        out = [sid for sid, r in self.receivers.items()
               if sid != exclude and seg in r.have]
        out += [sid for sid, held in self.sources.items()
                if sid != exclude and seg in held]
        return sorted(set(out))

    def _in_flight(self, seg: int) -> bool:
        """Is some receiver already pulling ``seg`` (it will become a
        holder shortly — waiting beats stampeding to host)?"""
        return any(r.seg == seg for r in self.receivers.values())

    def _held_count(self, sid: int) -> int:
        """How many segments ``sid`` holds (source-preference signal)."""
        r = self.receivers.get(sid)
        if r is not None:
            return len(r.have)
        return len(self.sources.get(sid, ()))

    def _start(self, r: _Receiver, seg: int, source: Optional[int]) -> None:
        """Begin one segment transfer (peer when ``source`` is a sid,
        host when None); hop latency is paid before the first byte."""
        r.seg = seg
        r.source = source
        r.parent = source
        r.lat_left = self.cfg.hw.hop_latency
        r.bytes_left = float(r.seg_bytes[seg])
        r.retries = 0

    def _assign(self, r: _Receiver, t: float) -> bool:
        """Try to start ``r``'s next transfer at time ``t``.  Returns
        True when a transfer started; False when backing off, politely
        waiting on busy holders / an in-flight pull, or done."""
        if r.next_try > t + 1e-12:
            return False                       # backoff not elapsed
        head = r.head()
        if head is None:
            return False                       # done
        if self.cfg.topology == "host":
            self._start(r, head, None)
            return True
        holders = self._holders(head, exclude=r.sid)
        fanout = self.cfg.effective_fanout
        free = [h for h in holders if self.active_sends(h) < fanout]
        if free:
            # least-busy holder first, then the one holding the most
            # segments (a receiver can keep riding it for later segments),
            # then lowest sid for determinism
            src = min(free, key=lambda h: (self.active_sends(h),
                                           -self._held_count(h), h))
            self._start(r, head, src)
            return True
        if holders or self._in_flight(head):
            return False        # holders busy / pull landing soon: wait
        if head not in self._seeded:
            # bootstrap: nobody ever held this segment — someone must
            # read it from host once (this receiver becomes the root)
            self._start(r, head, None)
            return True
        # seeded but orphaned: its holders died.  Retry with backoff (a
        # peer mid-pull may still complete it), then degrade to host.
        r.retries += 1
        if r.retries > self.cfg.max_retries:
            self._stats["host_fallbacks"] += 1.0
            self._start(r, head, None)
            return True
        self._stats["retries"] += 1.0
        r.next_try = t + self.cfg.retry_backoff_s * 2 ** (r.retries - 1)
        return False

    def _bw(self, r: _Receiver) -> float:
        """Current inbound bandwidth for ``r``'s transfer: ICI for peer
        links, contended-aggregate host bandwidth for host pulls."""
        if r.source is not None:
            return self.cfg.hw.ici_bw
        n_host = sum(1 for x in self.receivers.values()
                     if x.seg is not None and x.source is None)
        return host_bw_effective(self.cfg.hw, max(1, n_host))

    def advance(self, now: float, dt: float) -> Dict[int, List[int]]:
        """Move every transfer forward ``dt`` seconds of modeled time;
        returns ``{sid: [segments completed this tick]}``.

        Receivers are processed in sid order and may complete several
        segments per tick (leftover budget rolls into the next transfer,
        including an immediate re-assignment) — so a fast ICI link fills
        many small segments in one tick, exactly like the engine's
        ``segments_per_round`` budget on the host path.  Deterministic:
        no randomness, no wall clock, stable iteration order.
        """
        delivered: Dict[int, List[int]] = {}
        # pre-assign every idle receiver first, so the host-contention
        # pricing below sees the tick's REAL concurrency (assigning lazily
        # inside the progress loop would let the first receiver pull a
        # whole tick at uncontended bandwidth before the others register)
        for sid in sorted(self.receivers):
            r = self.receivers[sid]
            if r.seg is None:
                self._assign(r, now)
        for sid in sorted(self.receivers):
            r = self.receivers[sid]
            left = dt
            while left > 1e-12:
                if r.seg is None:
                    if not self._assign(r, now + (dt - left)):
                        if not r.done:
                            self._stats["stalled_seconds"] += left
                        break
                if r.lat_left > 0.0:
                    pay = min(r.lat_left, left)
                    r.lat_left -= pay
                    left -= pay
                    if left <= 1e-12:
                        break
                bw = self._bw(r)
                need = r.bytes_left / bw
                key = "peer_bytes" if r.source is not None else "host_bytes"
                if need > left + 1e-12:
                    moved = bw * left
                    r.bytes_left -= moved
                    self._stats[key] += moved
                    break
                # segment completes inside this tick
                self._stats[key] += r.bytes_left
                skey = ("peer_segments" if r.source is not None
                        else "host_segments")
                self._stats[skey] += 1.0
                r.have.add(r.seg)
                self._seeded.add(r.seg)
                delivered.setdefault(sid, []).append(r.seg)
                r.seg = None
                r.source = None
                r.bytes_left = 0.0
                left -= need
        return delivered
