#!/usr/bin/env python
"""``pbcheck`` launcher: the PipeBoost static-analysis suite.

Thin wrapper so the tool runs without exporting PYTHONPATH::

    python tools/pbcheck.py src/repro --baseline tools/pbcheck_baseline.json

Equivalent to ``PYTHONPATH=src python -m repro.analysis ...``.  See
``docs/ANALYSIS.md`` for the rule catalogue (R1-R6), the inline
suppression syntax, and the baseline workflow.
"""
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.analysis.cli import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
