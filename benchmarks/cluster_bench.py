"""Cluster bench entry: bursty trace -> autoscaled PipeBoost fleet -> JSON.

    PYTHONPATH=src python benchmarks/cluster_bench.py \
        [--trace wave|poisson|gamma] [--requests 24] [--servers 2] \
        [--crash-at 4] [--out cluster_metrics.json]

Runs the functional cluster (real reduced models on CPU; the same router
drives real slices) and writes the full ``ClusterMetrics`` JSON —
per-request TTFT/TBT, queue-depth timeline, scale/crash events,
GPU-seconds — so the trajectory is trackable across PRs.  A compact
CSV summary also goes to stdout in the harness' ``name,us_per_call,derived``
contract.
"""
from __future__ import annotations

import argparse

import jax

from repro.cluster import (Autoscaler, AutoscalerConfig, ClusterConfig,
                           ClusterRouter, burst_wave_trace, gamma_trace,
                           make_dispatch, poisson_trace)
from repro.configs.base import get_arch
from repro.models import transformer as T


def make_trace(kind: str, n: int, seed: int):
    if kind == "wave":
        return burst_wave_trace(n, base_rate=2.0, wave_rate=16.0,
                                wave_at=0.5, wave_len=1.0, seed=seed)
    if kind == "poisson":
        return poisson_trace(rate=4.0, horizon=n / 4.0, seed=seed)
    if kind == "gamma":
        return gamma_trace(rate=4.0, horizon=n / 4.0, burstiness=6.0,
                           seed=seed)
    raise SystemExit(f"unknown trace kind {kind!r}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", choices=("wave", "poisson", "gamma"),
                    default="wave")
    ap.add_argument("--requests", type=int, default=24,
                    help="exact count for --trace wave; for poisson/gamma "
                         "it sets the horizon (count is rate-approximate)")
    ap.add_argument("--servers", type=int, default=2)
    ap.add_argument("--max-servers", type=int, default=6)
    ap.add_argument("--devices", type=int, default=2)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--crash-at", type=int, default=-1)
    ap.add_argument("--dispatch", default="least_loaded",
                    choices=("least_loaded", "slo_aware", "adapter_affine"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="cluster_metrics.json")
    args = ap.parse_args(argv)

    cfg = get_arch("qwen3-1.7b").reduced(n_layers=2 * args.devices)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    trace = make_trace(args.trace, args.requests, args.seed)
    router = ClusterRouter(
        cfg, params, n_servers=args.servers,
        ccfg=ClusterConfig(n_devices=args.devices, n_slots=args.slots),
        autoscaler=Autoscaler(AutoscalerConfig(
            target_queue_per_server=args.slots,
            max_servers=args.max_servers)),
        dispatch=make_dispatch(args.dispatch))
    crash = args.crash_at if args.crash_at >= 0 else None
    router.run(trace, crash_after_completions=crash,
               crash_server_id=min(1, args.servers - 1),
               rejoin_after_ticks=20 if crash is not None else None)
    s = router.metrics.summary()
    print("name,us_per_call,derived")
    for key in ("ttft_p50", "ttft_p99", "tbt_p50", "tbt_p99"):
        print(f"cluster_{args.trace}_{key},{s[key] * 1e6:.1f},")
    print(f"cluster_{args.trace}_completed,{s['n_completed']:.0f},"
          f"of={s['n_requests']:.0f} rerouted={s['n_rerouted']:.0f}")
    print(f"cluster_{args.trace}_gpu_seconds,{s['gpu_seconds'] * 1e6:.1f},"
          f"servers_max={s['servers_max']:.0f}")
    router.metrics.to_json(args.out)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
