"""Generate the EXPERIMENTS.md roofline table from experiments/dryrun/*.json.

    PYTHONPATH=src:. python -m benchmarks.roofline_report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from repro.launch.rooflines import HBM_BW, ICI_BW, PEAK_FLOPS

ARCH_ORDER = ["mamba2-780m", "qwen3-1.7b", "deepseek-coder-33b",
              "granite-3-8b", "qwen2.5-14b", "hubert-xlarge",
              "qwen2-vl-72b", "qwen2-moe-a2.7b", "phi3.5-moe-42b-a6.6b",
              "recurrentgemma-2b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirname: str, variants: bool = False) -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        base = os.path.basename(f)
        is_variant = any(t in base for t in
                         ("__pipeline", "__model", "__2dtp", "_seqchunk",
                          "__replicated"))
        if is_variant != variants:
            continue
        with open(f) as fh:
            r = json.load(fh)
            r["_file"] = base
            rows.append(r)
    return rows


def fmt_ms(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    return f"{s * 1e3:.1f}ms"


def table(rows: List[Dict], mesh_tag: str) -> str:
    out = ["| arch | shape | peak/dev (CPU-HLO) | compute | memory | "
           "collective | dominant | MODEL_FLOPs/HLO | step lower-bound |",
           "|---|---|---|---|---|---|---|---|---|"]
    index = {(r["arch"], r["shape"]): r for r in rows
             if "memory" in r and mesh_tag in r.get("mesh", "")
             and r.get("axes", [""])[0] == ("pod" if mesh_tag == "2x16x16"
                                            else "data")}
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = index.get((arch, shape))
            if r is None:
                continue
            m = r["memory"]["peak_per_device"] / 2**30
            if "cost" not in r:
                out.append(f"| {arch} | {shape} | {m:.2f}GiB | - | - | - | "
                           f"- | - | - |")
                continue
            c = r["cost"]["roofline"]
            ratio = r["cost"].get("useful_flops_ratio", 0.0)
            lb = max(c["compute_s"], c["memory_s"], c["collective_s"])
            out.append(
                f"| {arch} | {shape} | {m:.2f}GiB | {fmt_ms(c['compute_s'])}"
                f" | {fmt_ms(c['memory_s'])} | {fmt_ms(c['collective_s'])}"
                f" | **{c['dominant']}** | {ratio:.2f} | {fmt_ms(lb)} |")
    return "\n".join(out)


def summary(rows: List[Dict]) -> str:
    doms: Dict[str, int] = {}
    for r in rows:
        if "cost" in r and "singlepod" not in r.get("mesh", "x"):
            pass
    for r in rows:
        if "cost" in r:
            doms[r["cost"]["roofline"]["dominant"]] = \
                doms.get(r["cost"]["roofline"]["dominant"], 0) + 1
    return f"dominant-term histogram (all compiled cells): {doms}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    rows = load(args.dir)
    print(f"hardware: {PEAK_FLOPS/1e12:.0f} TF/s bf16, "
          f"{HBM_BW/1e9:.0f} GB/s HBM, {ICI_BW/1e9:.0f} GB/s ICI per chip\n")
    print("## Single-pod baseline (16x16 = 256 chips)\n")
    print(table(rows, "16x16"))
    print("\n## Multi-pod (2x16x16 = 512 chips)\n")
    print(table(rows, "2x16x16"))
    vrows = load(args.dir, variants=True)
    if vrows:
        print("\n## Optimized variants (§Perf hillclimbs)\n")
        print("| file | peak/dev | compute | memory | collective | dominant |")
        print("|---|---|---|---|---|---|")
        for r in vrows:
            if "memory" not in r:
                continue
            m = r["memory"]["peak_per_device"] / 2**30
            if "cost" in r:
                c = r["cost"]["roofline"]
                print(f"| {r['_file'].replace('.json','')} | {m:.2f}GiB | "
                      f"{fmt_ms(c['compute_s'])} | {fmt_ms(c['memory_s'])} | "
                      f"{fmt_ms(c['collective_s'])} | {c['dominant']} |")
            else:
                print(f"| {r['_file'].replace('.json','')} | {m:.2f}GiB "
                      f"| - | - | - | - |")
    print()
    print(summary(rows))


if __name__ == "__main__":
    main()
