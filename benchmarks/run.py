"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).
Latency benches run on the byte/bandwidth-accurate cold-start simulator
calibrated to the paper's testbed (core/simulator.py, GPU_PAPER) plus the
TPU-v5e target constants; functional benches execute the real engine on
reduced models (CPU wall-clock).

Map (paper artifact -> bench):
  Fig. 1/9, Table 1  -> bench_cold_start_breakdown, bench_breakdown_lora
  Fig. 8             -> bench_ttft
  Fig. 6             -> bench_strategy_crossover
  Fig. 10            -> bench_ttft_lora
  Fig. 11/12         -> bench_scaling_shapes
  Fig. 13            -> bench_scaling_devices
  Fig. 14            -> bench_adapter_epochs
  Fig. 15/16         -> bench_recovery_loading
  Fig. 17            -> bench_recovery_inference
  (engine, CPU)      -> bench_engine_functional, bench_kernels
  (cluster, CPU)     -> bench_cluster_burst (see also cluster_bench.py for
                        the JSON-emitting trajectory entry)
  (hot path, CPU)    -> bench_decode_hotpath (steps/sec + compile counts
                        -> BENCH_decode_hotpath.json)
  (recovery, CPU)    -> bench_recovery (post-crash TTFT: KV migration vs
                        re-prefill -> BENCH_recovery.json)
  (cold start, CPU)  -> bench_coldstart (overlapped vs load-then-serve
                        TTFT -> BENCH_coldstart.json)
  (chaos, CPU)       -> bench_chaos (elastic repartition vs full
                        migration under seeded fault schedules
                        -> BENCH_chaos.json)
  (multicast, CPU)   -> bench_multicast (peer-to-peer burst scale-out vs
                        host-only cold starts, with a mid-propagation
                        source crash -> BENCH_multicast.json)
  (state tier, CPU)  -> bench_prefix (cross-request prefix-cache prefill
                        savings + spill/resurrect TTFT
                        -> BENCH_prefix.json)

Run ``python benchmarks/run.py [bench_name ...] [--small]`` to run a
subset (CI smoke uses ``bench_recovery --small``).  JSON trajectories are
keyed by (commit, config): re-running a bench on the same commit replaces
its entry in place instead of duplicating it.
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import subprocess
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.paper_models import (FALCON_7B, MISTRAL_7B, OPT_1_3B,
                                     OPT_2_7B, OPT_6_7B, OPT_13B,
                                     PAPER_MODELS)
from repro.configs.base import get_arch
from repro.core import simulator as sim
from repro.core.adapter_scheduler import (EagerPolicy, EpochSchedulerPolicy,
                                          simulate_adapter_serving)
from repro.core.simulator import GPU_PAPER, TPU_V5E

ROWS = []


def emit(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def _git_commit() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            stderr=subprocess.DEVNULL).decode().strip()
    except Exception:
        return "unknown"


def append_keyed_entry(path: str, entry: dict) -> int:
    """Append ``entry`` to a ``{"entries": [...]}`` trajectory file,
    replacing in place any existing entry with the same
    (``commit``, ``config``) key — re-running a bench on the same commit
    and configuration must update its row, not duplicate it.  Returns the
    entry count."""
    doc = {"entries": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                doc = json.load(f)
        except Exception:
            # never silently erase trajectory history: shelve the
            # unreadable file and start a fresh one
            corrupt = path + ".corrupt"
            os.replace(path, corrupt)
            print(f"# WARN: {path} was unreadable; moved to {corrupt}")
    entries = doc.setdefault("entries", [])
    for i, e in enumerate(entries):
        if (e.get("commit"), e.get("config")) == (entry.get("commit"),
                                                  entry.get("config")):
            entries[i] = entry
            break
    else:
        entries.append(entry)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return len(entries)


# ---------------------------------------------------------------------------
# Fig. 8 / Fig. 10: TTFT across models and systems
# ---------------------------------------------------------------------------

def bench_ttft(lora_rank: int = 0):
    """Fig. 8/10: modeled cold-start TTFT, PipeBoost vs ServerlessLLM
    and Transformers across the paper models."""
    tag = "lora_" if lora_rank else ""
    for cfg in PAPER_MODELS:
        rows = {}
        for strat in ("transformers", "serverlessllm", "pipeboost"):
            r = sim.simulate_cold_start(cfg, GPU_PAPER, 2, strat,
                                        lora_rank=lora_rank)
            rows[strat] = r.ttft
            emit(f"ttft_{tag}{cfg.name}_{strat}", r.ttft * 1e6)
        red_sl = 100 * (1 - rows["pipeboost"] / rows["serverlessllm"])
        red_tr = 100 * (1 - rows["pipeboost"] / rows["transformers"])
        emit(f"ttft_{tag}{cfg.name}_reduction", 0.0,
             f"vs_sllm={red_sl:.1f}% vs_transformers={red_tr:.1f}%")


def bench_ttft_lora():
    """Fig. 10: the TTFT comparison with rank-16 LoRA stages enabled."""
    bench_ttft(lora_rank=16)


# ---------------------------------------------------------------------------
# Fig. 1/9, Table 1: startup breakdown
# ---------------------------------------------------------------------------

def bench_cold_start_breakdown():
    """Fig. 1/9, Table 1: cold-start stage breakdown (load vs compute
    share of TTFT) per system."""
    for cfg in (MISTRAL_7B, OPT_13B):
        for strat in ("serverlessllm", "pipeboost"):
            r = sim.simulate_cold_start(cfg, GPU_PAPER, 2, strat)
            for stage, t in r.breakdown.items():
                if stage == "total":
                    continue
                emit(f"breakdown_{cfg.name}_{strat}_{stage}", t * 1e6,
                     f"{100 * t / r.ttft:.1f}%_of_ttft")
            load = r.breakdown["load_ckpt_dram"] + r.breakdown["load_params"]
            emit(f"breakdown_{cfg.name}_{strat}_load_share", 0.0,
                 f"{100 * load / r.ttft:.1f}%")


def bench_breakdown_lora():
    """Table 1: LoRA stages add negligible overhead."""
    for cfg in (MISTRAL_7B, OPT_13B):
        base = sim.simulate_cold_start(cfg, GPU_PAPER, 2, "pipeboost")
        lora = sim.simulate_cold_start(cfg, GPU_PAPER, 2, "pipeboost",
                                       lora_rank=16)
        over = 100 * (lora.ttft - base.ttft) / base.ttft
        emit(f"lora_overhead_{cfg.name}", (lora.ttft - base.ttft) * 1e6,
             f"{over:.2f}%_ttft_increase")


# ---------------------------------------------------------------------------
# Fig. 6: strategy crossover
# ---------------------------------------------------------------------------

def bench_strategy_crossover():
    """Fig. 6: mean request latency, pipeline vs per-device single
    strategy, across request rates (the switch crossover)."""
    for rps in (0.5, 2.0, 8.0, 20.0, 40.0):
        p = sim.simulate_request_latency(OPT_1_3B, GPU_PAPER, 4, rps,
                                         strategy="pipeline")
        s = sim.simulate_request_latency(OPT_1_3B, GPU_PAPER, 4, rps,
                                         strategy="single")
        emit(f"crossover_rps{rps}_pipeline", p["mean"] * 1e6)
        emit(f"crossover_rps{rps}_single", s["mean"] * 1e6,
             f"single_wins={s['mean'] < p['mean']}")


# ---------------------------------------------------------------------------
# Fig. 11/12: input length & batch scaling
# ---------------------------------------------------------------------------

def bench_scaling_shapes():
    """Fig. 11/12: TTFT reduction across input lengths and batch
    sizes."""
    for prompt in (200, 500):
        sl = sim.simulate_cold_start(MISTRAL_7B, GPU_PAPER, 2,
                                     "serverlessllm", prompt=prompt)
        pb = sim.simulate_cold_start(MISTRAL_7B, GPU_PAPER, 2, "pipeboost",
                                     prompt=prompt)
        emit(f"inputlen{prompt}_mistral7b_sllm", sl.ttft * 1e6)
        emit(f"inputlen{prompt}_mistral7b_pipeboost", pb.ttft * 1e6,
             f"reduction={100 * (1 - pb.ttft / sl.ttft):.1f}%")
    for batch in (64, 256):
        sl = sim.simulate_cold_start(FALCON_7B, GPU_PAPER, 2,
                                     "serverlessllm", batch=batch)
        pb = sim.simulate_cold_start(FALCON_7B, GPU_PAPER, 2, "pipeboost",
                                     batch=batch)
        emit(f"batch{batch}_falcon7b_sllm", sl.ttft * 1e6)
        emit(f"batch{batch}_falcon7b_pipeboost", pb.ttft * 1e6,
             f"reduction={100 * (1 - pb.ttft / sl.ttft):.1f}%")


# ---------------------------------------------------------------------------
# Fig. 13: device-count scaling
# ---------------------------------------------------------------------------

def bench_scaling_devices():
    """Fig. 13: TTFT scaling with device count (more devices -> less
    model per device -> faster first token)."""
    base = None
    for n in (1, 2, 4, 8):
        pb = sim.simulate_cold_start(MISTRAL_7B, GPU_PAPER, n, "pipeboost")
        sl = sim.simulate_cold_start(MISTRAL_7B, GPU_PAPER, n,
                                     "serverlessllm")
        base = base or pb.ttft
        emit(f"devices{n}_mistral7b_pipeboost", pb.ttft * 1e6,
             f"vs_1dev={100 * (1 - pb.ttft / base):.1f}% "
             f"vs_sllm={100 * (1 - pb.ttft / sl.ttft):.1f}%")


# ---------------------------------------------------------------------------
# Fig. 14: epoch-based adapter switching
# ---------------------------------------------------------------------------

def bench_adapter_epochs():
    """Fig. 14: epoch-based adapter scheduling vs eager switching
    (latency mean/variance and merge counts across rates)."""
    for rps in (5.0, 10.0, 15.0, 20.0, 25.0):
        ep = simulate_adapter_serving(
            EpochSchedulerPolicy(epoch_budget=8, max_batch=8), rps=rps,
            horizon=30.0, switch_prob=0.2)
        eg = simulate_adapter_serving(EagerPolicy(max_batch=8), rps=rps,
                                      horizon=30.0, switch_prob=0.2)
        emit(f"adapter_rps{rps}_epoch", ep["mean"] * 1e6,
             f"var={ep['var']:.4f} merges={ep['merges']:.0f}")
        emit(f"adapter_rps{rps}_eager", eg["mean"] * 1e6,
             f"var={eg['var']:.4f} merges={eg['merges']:.0f} "
             f"epoch_cut={100 * (1 - ep['mean'] / max(eg['mean'], 1e-9)):.1f}%")


# ---------------------------------------------------------------------------
# Fig. 15/16: recovery during loading
# ---------------------------------------------------------------------------

def bench_recovery_loading():
    """Fig. 15/16: modeled recovery from device failure during loading
    (pipeline-parallel reassignment vs full reload)."""
    pp = sim.simulate_loading_failure(MISTRAL_7B, GPU_PAPER, 4,
                                      failed=[1, 2], mode="pp")
    fl = sim.simulate_loading_failure(MISTRAL_7B, GPU_PAPER, 4,
                                      failed=[1, 2], mode="full")
    norm = sim.simulate_cold_start(MISTRAL_7B, GPU_PAPER, 4, "pipeboost")
    emit("recovery_load_pp", pp.recovery_time * 1e6,
         f"ttft={pp.ttft:.2f}s")
    emit("recovery_load_full", fl.recovery_time * 1e6,
         f"ttft={fl.ttft:.2f}s cut={100 * (1 - pp.recovery_time / fl.recovery_time):.1f}%")
    emit("recovery_load_normal_ttft", norm.ttft * 1e6, "no-crash baseline")
    for n in (2, 3, 4):
        r = sim.simulate_loading_failure(MISTRAL_7B, GPU_PAPER, n,
                                         failed=[0], mode="pp")
        emit(f"recovery_devices{n}_ttft", r.ttft * 1e6)


# ---------------------------------------------------------------------------
# Fig. 17: recovery during inference
# ---------------------------------------------------------------------------

def bench_recovery_inference():
    """Fig. 17: throughput halt and dip when devices fail mid-inference
    (pipeline-parallel recovery vs full restart)."""
    for mode in ("pp", "full"):
        tl = sim.simulate_inference_failure(MISTRAL_7B, GPU_PAPER, 4,
                                            mode=mode)
        post = [thr for t, thr in tl if t > 6.0]
        halt = sum(1 for x in post if x == 0.0) * 0.25
        dip = min(post)
        emit(f"recovery_infer_{mode}_halt", halt * 1e6,
             f"min_thr={dip:.0f}tok/s steady={tl[-1][1]:.0f}tok/s")


# ---------------------------------------------------------------------------
# Functional benches: the real engine on reduced models (CPU wall-clock)
# ---------------------------------------------------------------------------

def bench_engine_functional():
    """Real-engine wall-clock on a reduced model: cold prefill off one
    load round, 8 decode steps, and crash+recover with KV reuse."""
    from repro.core.engine import PipeBoostEngine, generate
    from repro.models import transformer as T
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=8)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, cfg.vocab_size)}
    eng = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    t0 = time.perf_counter()
    eng.load_round()
    logits = eng.prefill(batch)
    t1 = time.perf_counter()
    emit("engine_cold_prefill_reduced", (t1 - t0) * 1e6,
         f"segments_loaded=1/4_per_device ready={eng.ready}")
    t0 = time.perf_counter()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(8):
        tok = jnp.argmax(eng.decode(tok), -1).astype(jnp.int32)
    t1 = time.perf_counter()
    emit("engine_decode8_reduced", (t1 - t0) * 1e6)
    # crash + recover wall-clock (functional)
    eng.crash([1, 2])
    t0 = time.perf_counter()
    stats = eng.recover()
    t1 = time.perf_counter()
    emit("engine_recover_reduced", (t1 - t0) * 1e6,
         f"kv_reused={stats['reconstruct']['kv_reused']} "
         f"full_prefill={stats['reconstruct']['full_prefill']}")


def bench_cluster_burst():
    """Serverless cluster (functional): burst wave over 2 servers with a
    mid-burst whole-server crash + re-route; TTFT/TBT percentiles."""
    from repro.cluster import (Autoscaler, AutoscalerConfig, ClusterConfig,
                               ClusterRouter, burst_wave_trace)
    from repro.models import transformer as T
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=4)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    trace = burst_wave_trace(16, base_rate=2.0, wave_rate=16.0, wave_at=0.5,
                             wave_len=1.0, seed=0)
    router = ClusterRouter(
        cfg, params, n_servers=2,
        ccfg=ClusterConfig(n_devices=2, n_slots=4),
        autoscaler=Autoscaler(AutoscalerConfig(target_queue_per_server=4,
                                               max_servers=4)))
    t0 = time.perf_counter()
    router.run(trace, crash_after_completions=4, crash_server_id=1,
               rejoin_after_ticks=20)
    wall = time.perf_counter() - t0
    s = router.metrics.summary()
    emit("cluster_burst_ttft_p50", s["ttft_p50"] * 1e6)
    emit("cluster_burst_ttft_p99", s["ttft_p99"] * 1e6,
         f"completed={s['n_completed']:.0f}/{s['n_requests']:.0f} "
         f"rerouted={s['n_rerouted']:.0f}")
    emit("cluster_burst_tbt_p50", s["tbt_p50"] * 1e6)
    emit("cluster_burst_tbt_p99", s["tbt_p99"] * 1e6,
         f"gpu_seconds={s['gpu_seconds']:.1f}")
    emit("cluster_burst_wall", wall * 1e6,
         f"servers_max={s['servers_max']:.0f}")


def bench_decode_hotpath():
    """Zero-copy decode hot path vs the pre-PR batcher (functional, CPU).

    Steady-state decode steps/sec: the donated fused decode+sample step
    (in-place cache update, one host transfer) against a faithful replica
    of the legacy loop (non-donated decode jit returning a fresh cache,
    eager host-side sampler, tokens rebuilt on host every step).  Also
    runs a mixed-length burst of 16 prompts through the bucketed prefill
    and reports compile counts.  Results append to the
    ``BENCH_decode_hotpath.json`` trajectory.
    """
    from repro.serving.engine import (ContinuousBatcher, ServeRequest,
                                      ServingEngine, bucket_sizes,
                                      quantized_greedy)
    from repro.models import transformer as T

    cfg = get_arch("qwen3-1.7b").reduced(n_layers=2, head_dim=64)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    n_slots, max_len, steps = 8, 2048, 30
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 250, size=8 + i) for i in range(n_slots)]

    # -- legacy replica: the pre-PR ContinuousBatcher hot loop -------------
    class _LegacyBatcher:
        def __init__(self):
            self.cache = T.init_cache(cfg, n_slots, max_len,
                                      jnp.dtype(cfg.dtype))
            self.cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
            self._decode = jax.jit(
                lambda p, t, c: T.decode_step(cfg, p, {"tokens": t}, c))

        def admit(self, slot, prompt):
            logits, c1 = T.forward(cfg, params,
                                   {"tokens": jnp.asarray(prompt)[None]},
                                   mode="prefill", max_len=max_len)
            for k in ("attn", "ssm", "rec"):          # per-leaf host loop
                if k in c1:
                    for leaf in c1[k]:
                        self.cache[k][leaf] = \
                            self.cache[k][leaf].at[:, slot].set(
                                c1[k][leaf][:, 0])
            self.cache["pos"] = self.cache["pos"].at[slot].set(
                int(c1["pos"][0]))
            return int(np.asarray(quantized_greedy(logits))[0])

        def step(self, toks):
            logits, self.cache = self._decode(params, jnp.asarray(toks),
                                              self.cache)
            return np.asarray(quantized_greedy(logits))

    legacy = _LegacyBatcher()
    toks = np.zeros((n_slots,), np.int32)
    for s, p in enumerate(prompts):
        toks[s] = legacy.admit(s, p)
    legacy.step(toks)                                  # compile
    t0 = time.perf_counter()
    for _ in range(steps):
        toks = legacy.step(toks)
    legacy_sps = steps / (time.perf_counter() - t0)
    emit("hotpath_legacy_steps_per_s", 1e6 / legacy_sps,
         f"{legacy_sps:.1f}steps/s")

    # -- fused donated path ------------------------------------------------
    cb = ContinuousBatcher(cfg, params, n_slots=n_slots, max_len=max_len,
                           sampler=quantized_greedy)
    for i, p in enumerate(prompts):
        cb.admit(ServeRequest(i, p, max_new_tokens=steps + 64))
    cb.step()                                          # compile
    cb.n_decode_steps, cb.decode_time_s = 0, 0.0
    t0 = time.perf_counter()
    for _ in range(steps):
        cb.step()
    fused_sps = steps / (time.perf_counter() - t0)
    speedup = fused_sps / legacy_sps
    emit("hotpath_fused_steps_per_s", 1e6 / fused_sps,
         f"{fused_sps:.1f}steps/s speedup={speedup:.2f}x "
         f"tokens_per_s={fused_sps * n_slots:.1f}")

    # -- bucketed prefill compile counts on a mixed-length burst -----------
    eng = ServingEngine(cfg, params, n_slots=4, max_len=128)
    eng.batcher.sampler = quantized_greedy
    burst_lens = rng.permutation(np.arange(5, 121))[:16]
    for i, L in enumerate(burst_lens):
        eng.submit(ServeRequest(100 + i, rng.integers(0, 250, size=int(L)),
                                max_new_tokens=3))
    eng.run()
    cs = eng.batcher.compile_stats()
    n_buckets = len(bucket_sizes(128))
    emit("hotpath_prefill_compiles", float(cs["prefill_compiles"]),
         f"buckets={n_buckets} lengths=16 "
         f"decode_compiles={cs['decode_compiles']}")

    # -- JSON trajectory (keyed: re-runs replace, never duplicate) ---------
    path = "BENCH_decode_hotpath.json"
    n = append_keyed_entry(path, {
        "commit": _git_commit(),
        "config": {"arch": cfg.name, "n_slots": n_slots, "max_len": max_len,
                   "steps": steps},
        "ts": time.time(),
        "fused_steps_per_s": fused_sps,
        "legacy_steps_per_s": legacy_sps,
        "speedup": speedup,
        "tokens_per_s": fused_sps * n_slots,
        "prefill_compiles": cs["prefill_compiles"],
        "decode_compiles": cs["decode_compiles"],
        "n_buckets": n_buckets,
    })
    print(f"# wrote {path} ({n} entries)")


def bench_recovery(small: bool = False):
    """Crash recovery: KV-snapshot migration vs re-prefill (functional).

    Drains mid-decode requests off a "crashed" serving engine (exporting
    their KV snapshots) and hands them to two identical warmed survivors.
    Post-crash TTFT = wall-clock from the hand-off until every displaced
    request has produced its next token: the migrated survivor imports
    snapshots (zero re-prefilled prompt tokens — asserted via its prefill
    counters), the baseline re-submits and re-prefills prompt+prefix.
    Asserts the migrated path is strictly faster AND that both finish
    with identical greedy continuations (the equivalence oracle).  The
    full lane also times a partial crash routed through
    ``reconstruct_cache``.  Appends to ``BENCH_recovery.json`` keyed by
    commit+config (the CI fast-lane smoke runs this with ``--small``).
    """
    from repro.cluster import ClusterConfig
    from repro.models import transformer as T
    from repro.serving.engine import (ServeRequest, ServingEngine,
                                      quantized_greedy)

    n_layers = 2 if small else 4
    n_victims = 3
    prompt_len, max_len = 72, 96
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=n_layers)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 250, size=prompt_len)
               for _ in range(n_victims)]

    def make_engine():
        e = ServingEngine(cfg, params, n_slots=4, max_len=max_len)
        e.batcher.sampler = quantized_greedy
        return e

    # "crashed" server: victims mid-decode, drained with their snapshots
    crashed = make_engine()
    victims = [ServeRequest(i, p, max_new_tokens=30)
               for i, p in enumerate(prompts)]
    for r in victims:
        crashed.submit(r)
    for _ in range(6):
        crashed.step()
    drained = crashed.drain_inflight()
    assert len(drained) == n_victims \
        and all(r.snapshot is not None for r in drained)

    def clone(r):
        c = ServeRequest(r.rid, r.tokens, r.max_new_tokens, r.adapter,
                         r.arrival, generated=list(r.generated))
        c.snapshot = r.snapshot       # numpy rows: shared read-only
        return c

    def make_survivor():
        # warm every post-crash code path OUTSIDE the timed window: the
        # prefill bucket the victims land in, the decode step, and the
        # snapshot-import jit — so the window measures steady-state
        # recovery work, not XLA compiles
        b = make_engine()
        b.submit(ServeRequest(999, prompts[0], max_new_tokens=2))
        b.run()
        b.batcher.warm_import()
        return b

    def time_to_next_token(survivor, reqs, *, migrate: bool) -> float:
        """Post-crash TTFT: hand the displaced requests to the survivor
        and run until each has produced its next token."""
        before = {r.rid: len(r.generated) for r in reqs}
        t0 = time.perf_counter()
        for r in reqs:
            if migrate:
                assert survivor.admit_with_state(r), "import refused"
            else:
                survivor.submit(r)
        while not all(len(r.generated) > before[r.rid] or r.done
                      for r in reqs):
            survivor.step()
        return time.perf_counter() - t0

    def median_window(survivor, *, migrate: bool, reps: int = 5):
        """Median over repeated hand-off windows (the displaced requests
        are re-cloned and the survivor re-drained between reps, so each
        window measures the same steady-state recovery work)."""
        ts = []
        for _ in range(reps):
            batch = [clone(r) for r in drained]
            if not migrate:
                for r in batch:
                    r.snapshot = None     # the state died with the server
            ts.append(time_to_next_token(survivor, batch, migrate=migrate))
            survivor.drain_inflight(export_state=False)
        # a final untimed admission rides to completion for the
        # equivalence check below
        final = [clone(r) for r in drained]
        for r in final:
            if migrate:
                assert survivor.admit_with_state(r), "import refused"
            else:
                r.snapshot = None
                survivor.submit(r)
        return float(np.median(ts)), final

    b_mig, b_rep = make_survivor(), make_survivor()

    # tokens the baseline recomputes = prompt + generated prefix at
    # re-submission; migration moves their state instead (pos = that - 1)
    reprefill_tokens = sum(len(r.tokens) + len(r.generated) for r in drained)
    migrated_tokens = sum(r.snapshot.pos for r in drained)

    prefills_before = b_mig.batcher.n_prefill_reqs
    t_mig, mig_reqs = median_window(b_mig, migrate=True)
    t_rep, rep_reqs = median_window(b_rep, migrate=False)
    assert b_mig.batcher.n_prefill_reqs == prefills_before, \
        "migration re-prefilled — zero-re-prefill invariant broken"
    assert b_mig.batcher.n_migrated_in > 0
    assert t_mig < t_rep, (
        f"post-crash TTFT regression: migrate {t_mig * 1e3:.1f}ms is not "
        f"faster than re-prefill {t_rep * 1e3:.1f}ms")
    # equivalence oracle: both recovery modes must finish with identical
    # greedy continuations
    b_mig.run()
    b_rep.run()
    for m, p in zip(mig_reqs, rep_reqs):
        assert m.generated == p.generated, (m.rid, m.generated, p.generated)
    emit("recovery_migrate_post_crash_ttft", t_mig * 1e6,
         f"migrated={n_victims} migrated_tokens={migrated_tokens} "
         f"reprefilled_tokens=0")
    emit("recovery_reprefill_post_crash_ttft", t_rep * 1e6,
         f"rerouted={n_victims} reprefilled_tokens={reprefill_tokens} "
         f"speedup={t_rep / t_mig:.2f}x")

    # modeled snapshot-transfer cost (satellite to the measured numbers):
    # the byte payload each migration moves, priced over the paper
    # testbed's two links — NVLink-class device P2P vs PCIe-class
    # host-link (core/simulator.py estimator, GPU_PAPER bandwidths)
    model_bytes = sum(sim.kv_snapshot_bytes(cfg, r.snapshot.pos, max_len)
                      for r in drained)
    actual_bytes = sum(r.snapshot.nbytes() for r in drained)
    t_nvlink = sim.snapshot_transfer_time(model_bytes, GPU_PAPER, "nvlink")
    t_pcie = sim.snapshot_transfer_time(model_bytes, GPU_PAPER, "pcie")
    emit("recovery_snapshot_xfer_nvlink", t_nvlink * 1e6,
         f"payload={model_bytes}B (in-memory rows {actual_bytes}B)")
    emit("recovery_snapshot_xfer_pcie", t_pcie * 1e6,
         f"vs_measured_migrate={t_pcie / max(t_mig, 1e-9):.3f}x")

    # partial crash: in-place per-layer reconstruction (full lane only)
    recon = {}
    if not small:
        from repro.cluster import ClusterServer
        ccfg = ClusterConfig(n_devices=4, n_slots=4)
        server = ClusterServer(0, cfg, params, ccfg)
        while server.state == "loading":
            server.tick(0.0)
        for i in range(3):
            server.submit(ServeRequest(i, rng.integers(0, 250, size=32),
                                       max_new_tokens=16))
        # two serving ticks: requests decode while the chain still spans
        # several devices (full load would collapse ownership onto one)
        for _ in range(2):
            server.tick(0.0)
        # pick a device whose death loses SOME layers (partial, not total)
        cands = [d for d in range(ccfg.n_devices)
                 if 0 < sum(server.engine.lost_state_layers([d]))
                 < cfg.n_layers]
        assert cands, "no partial-loss device — chain collapsed early"
        # fewest lost layers = most surviving KV for the Q-only reuse path
        cands.sort(key=lambda d: sum(server.engine.lost_state_layers([d])))
        t0 = time.perf_counter()
        server.crash([cands[0]])
        t_recon = time.perf_counter() - t0
        recon = dict(server.last_recovery)
        assert recon.get("reconstructed_reqs", 0) > 0
        assert recon.get("layers_skipped", 0) + recon.get("kv_reused", 0) > 0
        emit("recovery_partial_reconstruct", t_recon * 1e6,
             f"kv_reused={recon.get('kv_reused', 0):.0f} "
             f"full_prefill={recon.get('full_prefill', 0):.0f} "
             f"layers_skipped={recon.get('layers_skipped', 0):.0f}")

    path = "BENCH_recovery.json"
    n = append_keyed_entry(path, {
        "commit": _git_commit(),
        "config": {"arch": cfg.name, "n_layers": n_layers,
                   "n_victims": n_victims, "prompt_len": prompt_len,
                   "small": small},
        "ts": time.time(),
        "migrate_post_crash_ttft_s": t_mig,
        "reprefill_post_crash_ttft_s": t_rep,
        "speedup": t_rep / t_mig,
        "migrated_reqs": n_victims,
        "migrated_tokens": migrated_tokens,
        "reprefill_tokens_baseline": reprefill_tokens,
        "snapshot_payload_bytes": model_bytes,
        "snapshot_rows_bytes": actual_bytes,
        "snapshot_xfer_nvlink_s": t_nvlink,
        "snapshot_xfer_pcie_s": t_pcie,
        "partial_reconstruct": recon,
    })
    print(f"# wrote {path} ({n} entries)")


def bench_coldstart(small: bool = False):
    """Overlapped cold start vs load-then-serve TTFT (the tentpole claim).

    Runs the REAL engine twice on the same reduced model and prompts:

    * **overlapped** — fill rounds advance via the engine's generator-step
      driver; the prefill dispatches the moment ``ready`` flips (each
      device holds ~1/N of the model) and decoding continues while the
      remaining segments stream in, strategy-switching when full.
    * **load-then-serve** — every segment loads before the first prefill
      (the ServerlessLLM-style baseline sequencing).

    Time is discrete-event hybrid: compute (prefill/decode) is measured
    wall-clock on the functional model; the load channel is the paper's
    A100 testbed constants (``GPU_PAPER.host_link_bw``) applied to the
    FULL architecture's per-segment bytes — devices transfer in parallel,
    so a round costs its slowest device.  Asserts the paper's §4.3
    invariants: overlapped and fully-loaded token streams are
    BIT-IDENTICAL, the decode step compiles exactly once across the
    strategy switch, and overlapped TTFT beats the baseline.  Appends to
    ``BENCH_coldstart.json`` keyed by commit+config.
    """
    from repro.core.engine import PipeBoostEngine
    from repro.core import analytic
    from repro.core.planner import make_plan
    from repro.models import transformer as T

    n_layers = 2 if small else 8
    n_devices = 2 if small else 4
    n_tokens = 4 if small else 12
    full_cfg = get_arch("qwen3-1.7b")
    cfg = full_cfg.reduced(n_layers=n_layers)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, min(cfg.vocab_size, 250))}

    # load-channel model: the full arch's bytes on the reduced plan's
    # segment ring (same ring topology, paper-scale transfer times)
    full_plan = make_plan(analytic.layer_bytes_list(full_cfg), n_devices)
    seg_bytes = {s.idx: s.bytes for s in full_plan.segments}
    bw = GPU_PAPER.host_link_bw

    def round_load_s(round_):
        per_dev = {}
        for dev, seg in round_.segments:
            per_dev[dev] = per_dev.get(dev, 0) + seg_bytes[seg % len(seg_bytes)]
        return max(per_dev.values()) / bw if per_dev else 0.0

    def run_engine(overlap: bool):
        eng = PipeBoostEngine(cfg, params, n_devices=n_devices, max_len=64)
        # warm the XLA compiles outside the timed window: the bench
        # measures cold-start *serving* latency (load channel + compute),
        # not compilation — a real fleet reuses the compile cache
        lg_w, c_w = eng._prefill_jit(eng._merged_params, batch)
        tok_w = jnp.argmax(lg_w, -1).astype(jnp.int32)
        jax.block_until_ready(eng._decode_jit(eng._merged_params, tok_w, c_w))
        fill = eng.fill_steps()
        t_load = 0.0                      # load-channel clock
        if overlap:
            while not eng.ready:
                t_load += round_load_s(next(fill))
        else:
            for r in fill:
                t_load += round_load_s(r)
            assert eng.fully_loaded
        t0 = time.perf_counter()
        logits = eng.prefill(batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(tok)
        prefill_wall = time.perf_counter() - t0
        ttft = t_load + prefill_wall
        toks = [tok]
        for i in range(1, n_tokens):
            if overlap:
                # background fill: one round rides alongside each decode
                # step (its time is on the load channel, not the TTFT path)
                for r in fill:
                    break
                eng.maybe_switch_strategy(request_rate=1.0)
            tok = jnp.argmax(eng.decode(tok), -1).astype(jnp.int32)
            toks.append(tok)
        while overlap and not eng.fully_loaded:
            next(fill)
        if overlap:
            eng.maybe_switch_strategy(request_rate=1.0)
        return ttft, np.asarray(jnp.stack(toks, axis=1)), eng

    ttft_ov, toks_ov, eng_ov = run_engine(overlap=True)
    ttft_ser, toks_ser, eng_ser = run_engine(overlap=False)

    # the paper's correctness invariant: serving mid-load changes NOTHING
    np.testing.assert_array_equal(toks_ov, toks_ser)
    cs = eng_ov.compile_stats()
    if cs["decode_compiles"] >= 0:
        assert cs["decode_compiles"] == 1, (
            f"decode compiled {cs['decode_compiles']}x across the strategy "
            "switch (must be 1)")
    assert eng_ov.strategy == "single" and eng_ov.fully_loaded
    assert ttft_ov < ttft_ser, (
        f"overlapped TTFT {ttft_ov * 1e3:.1f}ms not better than "
        f"load-then-serve {ttft_ser * 1e3:.1f}ms")

    stats = eng_ov.cold_start_stats()
    emit("coldstart_overlapped_ttft", ttft_ov * 1e6,
         f"ready_after={stats['round_bytes'][0]}B_of_"
         f"{stats['total_bytes']}B rounds={stats['n_rounds']}")
    emit("coldstart_load_then_serve_ttft", ttft_ser * 1e6,
         f"speedup={ttft_ser / ttft_ov:.2f}x tokens_identical=True "
         f"decode_compiles={cs['decode_compiles']}")

    path = "BENCH_coldstart.json"
    n = append_keyed_entry(path, {
        "commit": _git_commit(),
        "config": {"arch": cfg.name, "n_layers": n_layers,
                   "n_devices": n_devices, "n_tokens": n_tokens,
                   "small": small},
        "ts": time.time(),
        "overlapped_ttft_s": ttft_ov,
        "load_then_serve_ttft_s": ttft_ser,
        "speedup": ttft_ser / ttft_ov,
        "tokens_identical": True,
        "decode_compiles": cs["decode_compiles"],
        "time_to_ready_wall_s": stats["time_to_ready"],
        "time_to_fully_loaded_wall_s": stats["time_to_fully_loaded"],
        "loaded_bytes": stats["loaded_bytes"],
        "total_bytes": stats["total_bytes"],
    })
    print(f"# wrote {path} ({n} entries)")


def bench_fleet(small: bool = False):
    """Multi-model fleet scheduling: SLO-aware vs least-loaded dispatch.

    Two model pools ("chat" / "code") over SHARED base params, two servers
    each plus a per-pool autoscaler, replaying a bursty multi-model trace
    that mixes long adapter-tuned requests with a wave of short
    tight-deadline base requests — the regime where *which server gets
    the request* decides TTFT: least-loaded happily queues a short
    request behind a long merged-LoRA epoch (the batch must drain before
    the adapter can switch), while SLO-aware dispatch prices that drain
    stall, the cold-start progress of warming servers, and the in-flight
    decode load, and routes around it (deadline-priority picks the most
    urgent queued request first).

    Runs the SAME trace through three fleets differing only in the
    injected ``DispatchPolicy`` and asserts SLO-aware p99 TTFT strictly
    beats least-loaded (the tentpole claim); adapter-affine rides along
    as the third point.  Appends ``BENCH_fleet.json`` keyed by
    commit+config.
    """
    from repro.cluster import (AdapterAffine, Autoscaler, AutoscalerConfig,
                               ClusterConfig, Fleet, LeastLoaded, PoolSpec,
                               SloAware, burst_wave_trace, merge_traces,
                               poisson_trace)
    from repro.lora.adapters import init_lora, merge_lora, randomize_lora
    from repro.models import transformer as T

    cfg = get_arch("qwen3-1.7b").reduced(n_layers=2)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    adapters = {}
    for name in ("a", "b"):
        lora = randomize_lora(jax.random.fold_in(jax.random.PRNGKey(7),
                                                 ord(name)),
                              init_lora(jax.random.PRNGKey(7), cfg, rank=4))
        adapters[name] = merge_lora(params, lora)

    n_short = 6 if small else 12
    long_toks = 10 if small else 18
    horizon = 1.2 if small else 2.5
    ccfg = ClusterConfig(n_devices=2, n_slots=4, epoch_budget=4)

    def pool_trace(pool: str, seed: int):
        # adapter "b": long generations; adapter "a": short, tight TTFT
        # deadline.  Least-loaded interleaves both classes across both
        # servers, so every wave admission crosses the epoch barrier
        # behind a long "b" batch; SLO-aware prices that drain and
        # de-facto partitions the adapters across the pool.
        longs = poisson_trace(1.2, horizon, seed=seed,
                              max_new_tokens=long_toks, adapters=("b",),
                              adapter_prob=1.0, model=pool,
                              ttft_deadline_s=1.5)
        shorts = burst_wave_trace(n_short, base_rate=2.0, wave_rate=16.0,
                                  wave_at=0.4, wave_len=0.8, seed=seed + 1,
                                  max_new_tokens=4, adapters=("a",),
                                  adapter_prob=1.0, model=pool,
                                  ttft_deadline_s=0.4)
        return merge_traces(longs, shorts)

    trace = merge_traces(pool_trace("chat", 0), pool_trace("code", 10))

    def run_fleet(make_dispatch_policy):
        pools = {
            name: PoolSpec(
                cfg, params, n_servers=2, ccfg=ccfg,
                adapter_params=dict(adapters),
                dispatch=make_dispatch_policy(),
                autoscaler=Autoscaler(AutoscalerConfig(
                    target_queue_per_server=6.0, ttft_slo_s=0.6,
                    max_servers=3, scale_up_cooldown_ticks=5)))
            for name in ("chat", "code")}
        fleet = Fleet(pools)
        t0 = time.perf_counter()
        done = fleet.run(trace)
        wall = time.perf_counter() - t0
        assert len(done) == len(trace), (len(done), len(trace))
        return fleet.metrics.summary(), fleet.metrics.summary_by_model(), \
            wall

    # deterministic scoring: pin the per-step cost to the logical tick so
    # the comparison is replayable (the default policy consults the
    # measured predicted_step_cost_s hook instead)
    slo = lambda: SloAware(step_cost_s=ccfg.tick_s)
    policies = {
        "least_loaded": LeastLoaded,
        "slo_aware": slo,
        "adapter_affine": lambda: AdapterAffine(slo=slo()),
    }
    results = {}
    for name, mk in policies.items():
        s, by_model, wall = run_fleet(mk)
        results[name] = s
        emit(f"fleet_{name}_ttft_p99", s["ttft_p99"] * 1e6,
             f"p50={s['ttft_p50']:.3f}s mean={s['ttft_mean']:.3f}s "
             f"completed={s['n_completed']:.0f} wall={wall:.1f}s")
        for model, ms in by_model.items():
            emit(f"fleet_{name}_{model}_ttft_p99", ms["ttft_p99"] * 1e6,
                 f"n={ms['n_completed']:.0f}")
    ll, sa = results["least_loaded"], results["slo_aware"]
    assert sa["ttft_p99"] < ll["ttft_p99"], (
        f"SLO-aware p99 TTFT {sa['ttft_p99']:.3f}s not better than "
        f"least-loaded {ll['ttft_p99']:.3f}s on the bursty trace")
    emit("fleet_slo_vs_least_loaded", 0.0,
         f"p99_cut={100 * (1 - sa['ttft_p99'] / ll['ttft_p99']):.1f}% "
         f"mean_cut={100 * (1 - sa['ttft_mean'] / ll['ttft_mean']):.1f}%")

    path = "BENCH_fleet.json"
    n = append_keyed_entry(path, {
        "commit": _git_commit(),
        "config": {"arch": cfg.name, "pools": 2, "n_short": n_short,
                   "long_toks": long_toks, "horizon": horizon,
                   "small": small},
        "ts": time.time(),
        "n_requests": len(trace),
        **{f"{name}_ttft_p99_s": results[name]["ttft_p99"]
           for name in policies},
        **{f"{name}_ttft_mean_s": results[name]["ttft_mean"]
           for name in policies},
        "slo_p99_cut_vs_least_loaded":
            1 - sa["ttft_p99"] / ll["ttft_p99"],
    })
    print(f"# wrote {path} ({n} entries)")


def _synth_azure_day_csv(path: str, *, n_functions: int, total: int,
                         n_minutes: int = 1440, seed: int = 0) -> int:
    """Write a synthetic Azure-Functions-shape CSV (one row per function,
    per-minute invocation counts over a day) whose counts sum to ~``total``.

    Shape matches the public dataset's findings: heavy-tailed per-function
    popularity (lognormal weights) and a diurnal curve — near-silent night,
    morning ramp, two daytime peaks — so the replay exercises both the
    dense daytime regime and the quiescent-gap jumps of the event engine.
    Deterministic in ``seed``.  Returns the written total invocation count.
    """
    import csv

    rng = np.random.default_rng(seed)
    day = (np.arange(n_minutes) + 0.5) / n_minutes
    gauss = lambda mu, sig: np.exp(-0.5 * ((day - mu) / sig) ** 2)
    shape = gauss(0.42, 0.09) + 0.85 * gauss(0.78, 0.11) \
        + 0.25 * gauss(0.60, 0.22)
    weights = rng.lognormal(0.0, 1.0, size=n_functions)
    jitter = rng.lognormal(0.0, 0.3, size=(n_functions, n_minutes))
    raw = weights[:, None] * shape[None, :] * jitter
    counts = np.rint(raw * (total / raw.sum())).astype(int)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["HashOwner", "HashApp", "HashFunction", "Trigger"]
                   + [str(m) for m in range(1, n_minutes + 1)])
        for fi in range(n_functions):
            w.writerow([f"owner{fi:04d}", f"app{fi:04d}", f"fn{fi:04d}",
                        "http"] + counts[fi].tolist())
    return int(counts.sum())


def bench_azure_day(small: bool = False):
    """Full-day Azure-shape trace replay through the event engine.

    The tentpole gate for the discrete-event refactor: synthesize a
    deterministic day of Azure-Functions-shape arrivals (~10⁶ full /
    ~5·10⁴ small), stream it through ``ClusterRouter.run`` (never
    materialized — ``iter_azure_trace`` generates minute-by-minute) over
    modeled ``SimServer`` backends, and demand the whole day replays in
    under 5 minutes of CPU wall time.  Everything above the server —
    dispatch, autoscaler, event engine, metrics — is the real code; only
    token generation is modeled (see cluster/simserver.py).

    Appends TTFT percentile curves and SLO attainment to
    ``BENCH_fleet.json`` keyed by commit+config.  ``--small`` additionally
    replays the same trace through the dense tick engine and reports the
    event-engine speedup (small only: the tick oracle polls every tick of
    the day, which at full scale is exactly the cost this refactor
    removes).
    """
    import tempfile

    from repro.cluster import (Autoscaler, AutoscalerConfig, ClusterConfig,
                               ClusterMetrics, ClusterRouter, LeastLoaded,
                               iter_azure_trace, sim_server_factory)

    total = 50_000 if small else 1_000_000
    n_functions = 16 if small else 64
    minute_s = 3.0                  # time-compress: 1440 min day -> 4320 s
    ccfg = ClusterConfig(n_devices=1, n_slots=16, tick_s=0.05)

    def replay(csv_path: str, engine: str):
        router = ClusterRouter(
            None, None, n_servers=2, ccfg=ccfg,
            autoscaler=Autoscaler(AutoscalerConfig(
                target_queue_per_server=8.0, ttft_slo_s=0.6,
                max_servers=24, min_servers=2, scale_up_cooldown_ticks=3,
                max_warming=4, idle_seconds_before_retire=10.0)),
            dispatch=LeastLoaded(), metrics=ClusterMetrics(),
            server_factory=sim_server_factory(),
            materialize_prompts=False)
        trace = iter_azure_trace(csv_path, minute_s=minute_s,
                                 ttft_deadline_s=0.5, seed=1)
        t0 = time.perf_counter()
        router.run(trace, max_ticks=200_000, engine=engine,
                   collect_finished=False)
        return router, time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as td:
        csv_path = os.path.join(td, "azure_day.csv")
        written = _synth_azure_day_csv(csv_path, n_functions=n_functions,
                                       total=total)
        router, wall = replay(csv_path, "event")
        m = router.metrics
        s = m.summary()
        curve = m.ttft_curve()
        slo_att, slo_n = m.slo_stats()
        n_req = int(s["n_requests"])
        emit("azure_day_replay", wall * 1e6,
             f"n={n_req} completed={s['n_completed']:.0f} "
             f"ttft_p50={curve['ttft_p50']:.3f}s "
             f"ttft_p99={curve['ttft_p99']:.3f}s slo={slo_att:.4f} "
             f"gpu_s={s['gpu_seconds']:.0f}")
        assert s["n_completed"] == n_req, (s["n_completed"], n_req)
        if not small:
            assert n_req >= 990_000, f"day synthesized only {n_req} arrivals"
            assert wall < 300.0, (
                f"full-day replay took {wall:.1f}s (gate: < 300 s CPU)")
        tick_wall = None
        if small:
            router_t, tick_wall = replay(csv_path, "tick")
            st = router_t.metrics.summary()
            assert st["n_completed"] == s["n_completed"], (
                st["n_completed"], s["n_completed"])
            assert abs(st["ttft_p99"] - s["ttft_p99"]) < 1e-9, (
                st["ttft_p99"], s["ttft_p99"])
            emit("azure_day_tick_oracle", tick_wall * 1e6,
                 f"event_speedup={tick_wall / max(wall, 1e-9):.2f}x")

    path = "BENCH_fleet.json"
    n = append_keyed_entry(path, {
        "commit": _git_commit(),
        "config": {"bench": "azure_day", "n_functions": n_functions,
                   "total": total, "minute_s": minute_s,
                   "n_slots": ccfg.n_slots, "small": small},
        "ts": time.time(),
        "n_requests": n_req,
        "n_completed": int(s["n_completed"]),
        "wall_s": wall,
        "tick_wall_s": tick_wall,
        "slo_attainment": slo_att,
        "slo_n": int(slo_n),
        "gpu_seconds": s["gpu_seconds"],
        **curve,
    })
    print(f"# wrote {path} ({n} entries)")


def bench_kernels():
    """Pallas kernel wall-clock (interpret mode on CPU; TPU target):
    flash attention and the fused LoRA merge."""
    from repro.kernels import ops
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 256, 8, 64), jnp.float32)
    k = jax.random.normal(key, (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(key, (1, 256, 2, 64), jnp.float32)
    o = ops.flash_attention(q, k, v)  # compile
    t0 = time.perf_counter()
    for _ in range(3):
        o = ops.flash_attention(q, k, v)
    jax.block_until_ready(o)
    emit("kernel_flash_attn_256_interp", (time.perf_counter() - t0) / 3 * 1e6,
         "interpret-mode (TPU target)")
    W = jax.random.normal(key, (4, 256, 256), jnp.float32)
    A = jax.random.normal(key, (4, 256, 8), jnp.float32)
    Bm = jax.random.normal(key, (4, 8, 256), jnp.float32)
    o = ops.lora_merge(W, A, Bm, 0.5)
    t0 = time.perf_counter()
    for _ in range(3):
        o = ops.lora_merge(W, A, Bm, 0.5)
    jax.block_until_ready(o)
    emit("kernel_lora_merge_interp", (time.perf_counter() - t0) / 3 * 1e6)


def bench_chaos(small: bool = False):
    """Elastic repartition vs full migration under partial crashes, plus
    seeded chaos-schedule replay (functional).

    Headline: a device of a 4-device server dies mid-decode.  Repartition
    re-splits the pipeline over the survivors in place — reload only the
    dead device's layers, re-lay live KV in one donated scatter, requests
    never leave the server — vs FULL migration, which abandons the warm
    server: drain with snapshots, cold-start a fresh server (pipelined
    load + first-use compiles, the honest price of standing up capacity),
    import, resume.  Post-crash TTFT = wall-clock from the crash until
    every victim has its next token.  Asserts repartition is strictly
    faster AND both paths finish with identical greedy continuations with
    ZERO re-prefilled tokens (batcher prefill counters pinned).

    Also replays a seeded ``ChaosSchedule`` (crash/partial_crash/rejoin)
    twice on the modeled fleet — same seed must reproduce identical token
    streams under BOTH the tick and event engines — and once against real
    servers with ``partial_recovery="repartition"``, asserting every
    request survives the fault sequence token-exact with
    ``reprefill_tokens == 0``.  Appends to ``BENCH_chaos.json`` (the CI
    fast-lane smoke runs this with ``--small``).
    """
    from repro.cluster import (Autoscaler, AutoscalerConfig, ChaosEvent,
                               ClusterConfig, ClusterRouter, ClusterServer,
                               LeastLoaded, SimProfile, poisson_trace,
                               random_chaos, sim_server_factory)
    from repro.models import transformer as T
    from repro.serving.engine import ServeRequest, quantized_greedy

    n_layers, n_devices = 4, 4        # need partial KV loss on one device
    n_victims = 2 if small else 3
    reps = 2 if small else 3
    prompt_len, max_new = 48, 16
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=n_layers)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 250, size=prompt_len)
               for _ in range(n_victims)]
    ccfg = ClusterConfig(n_devices=n_devices, n_slots=4,
                         partial_recovery="repartition")

    def make_victim_server():
        # victims submitted BEFORE ready so they admit the same tick the
        # chain becomes viable — KV ownership still spans devices
        s = ClusterServer(0, cfg, params, ccfg)
        vs = [ServeRequest(i, p, max_new_tokens=max_new)
              for i, p in enumerate(prompts)]
        for r in vs:
            s.submit(r)
        while s.state == "loading":
            s.tick(0.0)
        cands = sorted(
            (d for d in range(n_devices)
             if 0 < sum(s.engine.lost_state_layers([d])) < cfg.n_layers),
            key=lambda d: sum(s.engine.lost_state_layers([d])))
        assert cands, "no partial-loss device — chain collapsed early"
        return s, vs, cands[0]

    def next_token_wait(server, vs, before):
        now = 1.0
        while not all(len(r.generated) > before[r.rid] or r.done
                      for r in vs):
            server.tick(now)
            now += ccfg.tick_s

    def repartition_window(s, vs, dev):
        before = {r.rid: len(r.generated) for r in vs}
        n_pref = s.srv.batcher.n_prefill_reqs
        t0 = time.perf_counter()
        s.crash([dev])
        next_token_wait(s, vs, before)
        dt = time.perf_counter() - t0
        assert s.srv.batcher.n_prefill_reqs == n_pref, \
            "repartition re-prefilled — zero-re-prefill invariant broken"
        return dt

    def migration_window(s, vs):
        before = {r.rid: len(r.generated) for r in vs}
        t0 = time.perf_counter()
        drained = s.crash()           # whole-server loss: snapshots out
        surv = ClusterServer(1, cfg, params, ccfg)
        while surv.state == "loading":
            surv.tick(0.0)
        for r in drained:
            assert surv.srv.admit_with_state(r), "import refused"
        next_token_wait(surv, vs, before)
        dt = time.perf_counter() - t0
        assert surv.srv.batcher.n_prefill_reqs == 0
        return dt, surv

    # untimed warmup pair: first-use eager-dispatch caches (the relay's
    # reconstruct path is un-jitted) land outside the timed windows
    s, vs, dev = make_victim_server()
    repartition_window(s, vs, dev)
    s, vs, _ = make_victim_server()
    migration_window(s, vs)

    t_rep, t_mig = [], []
    for _ in range(reps):
        s_r, vs_r, dev = make_victim_server()
        lost = sum(s_r.engine.lost_state_layers([dev]))
        t_rep.append(repartition_window(s_r, vs_r, dev))
        s_m, vs_m, _ = make_victim_server()
        dt, surv = migration_window(s_m, vs_m)
        t_mig.append(dt)
    t_r, t_m = float(np.median(t_rep)), float(np.median(t_mig))
    relayed = dict(s_r.last_recovery)
    # equivalence oracle: the last rep's two paths ride to completion and
    # must agree token-for-token (the bit-identical-streams claim)
    now = 2.0
    while any(not r.done for r in vs_r):
        s_r.tick(now)
        now += ccfg.tick_s
    while any(not r.done for r in vs_m):
        surv.tick(now)
        now += ccfg.tick_s
    for a, b in zip(vs_r, vs_m):
        assert a.generated == b.generated, (a.rid, a.generated, b.generated)
    assert t_r < t_m, (
        f"post-crash TTFT regression: repartition {t_r * 1e3:.1f}ms is not "
        f"faster than full migration {t_m * 1e3:.1f}ms")
    emit("chaos_repartition_post_crash_ttft", t_r * 1e6,
         f"lost_layers={lost} relayed={n_victims} reprefilled_tokens=0 "
         f"speedup={t_m / t_r:.2f}x")
    emit("chaos_full_migration_post_crash_ttft", t_m * 1e6,
         f"migrated={n_victims} cold_survivor_included")

    # seeded chaos replay on the modeled fleet: same seed => identical
    # replay, and the tick and event engines execute the schedule the same
    chaos_seed = 11
    chaos = random_chaos(2 if small else 4, horizon=4.0, n_servers=2,
                         seed=chaos_seed, rejoin_delay_s=1.0)
    again = random_chaos(2 if small else 4, horizon=4.0, n_servers=2,
                         seed=chaos_seed, rejoin_delay_s=1.0)
    assert [(e.time, e.kind, e.server, e.devices) for e in chaos] == \
        [(e.time, e.kind, e.server, e.devices) for e in again], \
        "random_chaos is not deterministic by seed"
    sim_trace = poisson_trace(30.0, 2.0, seed=7, max_new_tokens=4)

    def sim_run(engine):
        r = ClusterRouter(
            None, None, n_servers=2,
            ccfg=ClusterConfig(n_devices=1, n_slots=4),
            autoscaler=Autoscaler(AutoscalerConfig(
                target_queue_per_server=4.0, max_servers=4, min_servers=1,
                idle_seconds_before_retire=1.0)),
            dispatch=LeastLoaded(),
            server_factory=sim_server_factory(SimProfile(ready_ticks=2,
                                                         full_ticks=6)),
            materialize_prompts=False)
        t0 = time.perf_counter()
        done = r.run(list(sim_trace), engine=engine, chaos=chaos)
        return r, done, time.perf_counter() - t0

    runs = {name: sim_run(eng) for name, eng in
            (("event", "event"), ("tick", "tick"), ("event2", "event"))}
    streams = {name: {r.rid: tuple(r.generated) for r in done}
               for name, (_, done, _) in runs.items()}
    assert streams["event"] == streams["tick"] == streams["event2"], \
        "chaos replay diverged across engines / identical seeds"
    s_evt = runs["event"][0].metrics.summary()
    s_tick = runs["tick"][0].metrics.summary()
    for k in ("n_completed", "gpu_seconds", "degraded_seconds",
              "recovery_mode_repartition", "recovery_reprefill_tokens"):
        assert abs(s_evt[k] - s_tick[k]) < 1e-9, (k, s_evt[k], s_tick[k])
    emit("chaos_sim_replay", runs["event"][2] * 1e6,
         f"n_events={len(chaos)} n_reqs={len(sim_trace)} "
         f"tick==event seed={chaos_seed}")

    # real servers under a chaos schedule: a partial crash + device rejoin
    # mid-trace, elastic repartition recovery — every request survives the
    # fault sequence token-exact, with zero re-prefilled tokens
    real_trace = poisson_trace(8.0, 0.7, seed=3, max_new_tokens=4)
    real_chaos = [ChaosEvent(0.313, "partial_crash", 0, (1,)),
                  ChaosEvent(0.913, "rejoin", 0, (1,))]
    router = ClusterRouter(cfg, params, n_servers=1, ccfg=ccfg)
    t0 = time.perf_counter()
    done = router.run(list(real_trace), chaos=real_chaos)
    t_real = time.perf_counter() - t0
    assert len(done) == len(real_trace)
    summ = router.metrics.summary()
    assert summ["recovery_reprefill_tokens"] == 0.0

    def _solo(prompt, n):
        lg, cache = T.forward(cfg, params,
                              {"tokens": jnp.asarray(prompt)[None]},
                              mode="prefill", max_len=96)
        toks = [int(quantized_greedy(lg)[0])]
        for _ in range(n - 1):
            lg, cache = T.decode_step(
                cfg, params, {"tokens": jnp.asarray([toks[-1]], jnp.int32)},
                cache)
            toks.append(int(quantized_greedy(lg)[0]))
        return toks

    for r in done:
        assert r.generated == _solo(r.tokens, len(r.generated)), r.rid
    emit("chaos_real_router_replay", t_real * 1e6,
         f"reqs={len(done)} reprefill_tokens=0 "
         f"mode_repartition={summ['recovery_mode_repartition']:.0f} "
         f"degraded_s={summ['degraded_seconds']:.3f}")

    path = "BENCH_chaos.json"
    n = append_keyed_entry(path, {
        "commit": _git_commit(),
        "config": {"arch": cfg.name, "n_layers": n_layers,
                   "n_devices": n_devices, "n_victims": n_victims,
                   "prompt_len": prompt_len, "chaos_seed": chaos_seed,
                   "small": small},
        "ts": time.time(),
        "repartition_post_crash_ttft_s": t_r,
        "full_migration_post_crash_ttft_s": t_m,
        "speedup": t_m / t_r,
        "lost_layers": int(lost),
        "relay": relayed,
        "reprefill_tokens": 0,
        "sim_replay": {"n_chaos_events": len(chaos),
                       "n_completed": int(s_evt["n_completed"]),
                       "tick_event_equal": True},
        "real_replay": {
            "n_reqs": len(done),
            "mode_repartition": summ["recovery_mode_repartition"],
            "degraded_seconds": summ["degraded_seconds"],
        },
    })
    print(f"# wrote {path} ({n} entries)")


def bench_multicast(small: bool = False):
    """Peer-to-peer multicast scale-out vs host-only cold starts, with a
    seeded mid-propagation source crash (modeled fleet).

    Headline: an N-server burst spawn.  Host-only, every server reads its
    own model copy from DRAM and the streams contend for ``host_agg_bw``
    (throttled to 2 host links so contention bites at small N, priced via
    ``host_bw_effective``).  Under tree multicast one root reads from host
    at full link speed and every receiver relays segments onward over
    ``ici_bw`` — asserts burst TTFT and fill makespan strictly beat
    host-only and that aggregate host traffic stays ~one model copy
    instead of N.

    Robustness: the propagation root is crashed mid-transfer
    (``source_crash``).  Survivors re-root onto the warmest holders,
    resume from their last fully-received segment, and bootstrap the
    never-seeded tail from host — asserts every surviving spawn completes
    its copy, zero tokens are re-prefilled, the token streams are
    bit-identical to a crash-free run, and the same chaos script replays
    token-exactly under the tick and event engines.  Appends to
    ``BENCH_multicast.json`` (the CI fast-lane smoke runs ``--small``).
    """
    from dataclasses import replace

    from repro.cluster import (Arrival, ChaosEvent, ClusterConfig,
                               ClusterRouter, MulticastConfig, SimProfile,
                               sim_server_factory)

    n_spawn = 4 if small else 8
    n_segments, bytes_total = 8, 1 << 30
    host_agg_links = 1            # host_agg_bw = 1 host link: N streams
    # share one link's worth of DRAM read (contention bites at N >= 4)
    hw = replace(GPU_PAPER, host_agg_bw=host_agg_links * GPU_PAPER.host_link_bw)
    prof = SimProfile(ready_ticks=2, full_ticks=10, bytes_total=bytes_total,
                      n_segments=n_segments)

    def build(topology):
        ccfg = ClusterConfig(
            n_devices=1, n_slots=4, tick_s=0.05,
            multicast=MulticastConfig(topology=topology, hw=hw))
        return ClusterRouter(None, None, n_servers=n_spawn, ccfg=ccfg,
                             server_factory=sim_server_factory(prof),
                             materialize_prompts=False)

    def makespan(router):
        fulls = [r["time_to_fully_loaded"]
                 for r in router.metrics.coldstart.values()
                 if r.get("time_to_fully_loaded") is not None]
        return max(fulls, default=0.0)

    # -- burst TTFT: requests land while every server is still cold; the
    # sentinel arrival keeps the replay alive until the fills complete
    # (run() otherwise returns the moment the burst drains, mid-fill)
    burst = [Arrival(0.001 * i, prompt_len=8, max_new_tokens=4)
             for i in range(2 * n_spawn)]
    sentinel = [Arrival(5.0, prompt_len=8, max_new_tokens=1)]
    stats = {}
    for topo in ("tree", "host"):
        r = build(topo)
        t0 = time.perf_counter()
        done = r.run(burst + sentinel, engine="event")
        wall = time.perf_counter() - t0
        assert len(done) == len(burst) + 1, topo
        assert all(s.fully_loaded for s in r.servers), topo
        summ = r.metrics.summary()
        stats[topo] = {"ttft_mean": summ["ttft_mean"],
                       "host_bytes": summ["multicast_host_bytes"],
                       "makespan": makespan(r)}
        emit(f"multicast_burst_{topo}_n{n_spawn}", wall * 1e6,
             f"ttft_mean={summ['ttft_mean']:.3f}s "
             f"fill_makespan={makespan(r):.3f}s "
             f"host_bytes={summ['multicast_host_bytes']:.2e}")
    mc, ho = stats["tree"], stats["host"]
    assert mc["ttft_mean"] < ho["ttft_mean"], (
        f"multicast burst TTFT {mc['ttft_mean']:.3f}s is not strictly "
        f"faster than host-only {ho['ttft_mean']:.3f}s at N={n_spawn}")
    assert mc["makespan"] < ho["makespan"], (mc["makespan"], ho["makespan"])
    # ~one host read of aggregate traffic vs N full copies
    assert mc["host_bytes"] <= 1.25 * bytes_total, mc["host_bytes"]
    assert ho["host_bytes"] >= 0.99 * n_spawn * bytes_total, ho["host_bytes"]
    emit(f"multicast_ttft_speedup_n{n_spawn}", 0.0,
         f"{ho['ttft_mean'] / max(mc['ttft_mean'], 1e-9):.2f}x "
         f"host_read_ratio={mc['host_bytes'] / bytes_total:.2f}")

    # -- mid-propagation source crash: kill the root while it is sourcing
    # peer transfers; arrivals land after the fills so completions isolate
    # the load-stage fault (zero re-prefill is structural AND asserted)
    chaos_t = 0.0685              # off-grid, ~2 ticks into propagation
    chaos = [ChaosEvent(chaos_t, "source_crash", 0)]
    late = [Arrival(2.0 + 0.01 * i, prompt_len=8, max_new_tokens=4)
            for i in range(2 * n_spawn)]
    runs = {}
    for name, eng in (("event", "event"), ("tick", "tick"),
                      ("event2", "event")):
        r = build("tree")
        t0 = time.perf_counter()
        done = r.run(late + sentinel, chaos=list(chaos), engine=eng)
        runs[name] = (r, done, time.perf_counter() - t0)
    streams = {name: {q.rid: tuple(q.generated) for q in done}
               for name, (_, done, _) in runs.items()}
    assert streams["event"] == streams["tick"] == streams["event2"], \
        "source-crash replay diverged across engines / identical scripts"
    r_ref = build("tree")
    ref = r_ref.run(late + sentinel, engine="event")
    assert streams["event"] == {q.rid: tuple(q.generated) for q in ref}, \
        "token streams changed vs the crash-free run"
    r_evt, done_evt, wall_evt = runs["event"]
    s_evt = runs["event"][0].metrics.summary()
    s_tick = runs["tick"][0].metrics.summary()
    for k in ("n_completed", "multicast_reroots", "multicast_host_bytes",
              "multicast_host_fallbacks", "recovery_reprefill_tokens"):
        assert abs(s_evt[k] - s_tick[k]) < 1e-9, (k, s_evt[k], s_tick[k])
    assert s_evt["n_completed"] == len(late) + 1
    assert s_evt["multicast_reroots"] >= 1, \
        "the crash did not abort any in-flight transfer (bad chaos_t?)"
    assert s_evt["recovery_reprefill_tokens"] == 0.0
    # every SURVIVING spawn completed its copy despite losing the root
    assert all(s.fully_loaded for s in r_evt.servers
               if s.state not in ("down", "retired"))
    # resume-not-restart: the re-pulled tail stays bounded (<= ~2 copies
    # of host traffic total; restart-from-zero would approach N copies)
    assert s_evt["multicast_host_bytes"] <= 2.0 * bytes_total
    emit(f"multicast_source_crash_n{n_spawn}", wall_evt * 1e6,
         f"reroots={s_evt['multicast_reroots']:.0f} "
         f"host_fallbacks={s_evt['multicast_host_fallbacks']:.0f} "
         f"retries={s_evt['multicast_retries']:.0f} "
         f"reprefill_tokens=0 tick==event")

    path = "BENCH_multicast.json"
    n = append_keyed_entry(path, {
        "commit": _git_commit(),
        "config": {"bench": "multicast", "n_spawn": n_spawn,
                   "n_segments": n_segments, "bytes_total": bytes_total,
                   "topology": "tree", "host_agg_links": host_agg_links,
                   "chaos_t": chaos_t, "small": small},
        "ts": time.time(),
        "n_spawn": n_spawn,
        "mc_ttft_mean_s": mc["ttft_mean"],
        "host_ttft_mean_s": ho["ttft_mean"],
        "ttft_speedup": ho["ttft_mean"] / max(mc["ttft_mean"], 1e-9),
        "mc_fill_makespan_s": mc["makespan"],
        "host_fill_makespan_s": ho["makespan"],
        "mc_host_bytes": mc["host_bytes"],
        "host_only_host_bytes": ho["host_bytes"],
        "host_read_ratio": mc["host_bytes"] / bytes_total,
        "crash": {
            "reroots": s_evt["multicast_reroots"],
            "retries": s_evt["multicast_retries"],
            "host_fallbacks": s_evt["multicast_host_fallbacks"],
            "host_bytes": s_evt["multicast_host_bytes"],
            "reprefill_tokens": 0,
            "n_completed": int(s_evt["n_completed"]),
            "tick_event_equal": True,
        },
    })
    print(f"# wrote {path} ({n} entries)")


def bench_prefix(small: bool = False):
    """Fleet state tier: cross-request prefix cache + spill/resurrect.

    Part 1 (real engine, CPU): serve a population of prompts sharing a
    long prefix through a ``ContinuousBatcher`` with and without a
    ``PrefixCache`` attached.  Asserts the cached run's token streams are
    bit-identical to cold prefill, post-warmup prefill tokens drop to
    <= 2%% of the no-cache run (only the per-request suffix is walked),
    and the compile guard shows zero new decode/prefill compiles — the
    import rides the existing donated scatter + fused decode.

    Part 2 (real engine, CPU): wall-clock TTFT of a "resurrected" spawn —
    a fresh batcher whose cache was seeded from another server's
    ``export_entries`` bundle (what an idle retirement spills to the
    ``StateTier``) — vs a genuinely cold spawn serving the same prompt.
    Asserts resurrect strictly beats cold, and prices the bundle pull
    with the modeled ``state_resurrect_time``.

    Part 3 (modeled fleet): a two-wave repeated-prefix trace with an idle
    gap long enough for the autoscaler to retire the fleet down to one
    server; wave 2's burst respawns it.  With a ``StateTier`` wired in,
    the retirement spills the prefix cache and the respawn resurrects it
    warm.  Asserts >= 1 resurrection, prefix hits in both waves, and that
    the tick and event engines replay streams and every state-tier
    summary key identically.  Appends to ``BENCH_prefix.json`` (the CI
    fast-lane smoke runs ``--small``).
    """
    from repro.models import transformer as T
    from repro.serving.engine import (ContinuousBatcher, ServeRequest,
                                      quantized_greedy)
    from repro.serving.prefix_cache import PrefixCache

    cfg = get_arch("qwen3-1.7b").reduced(n_layers=2, head_dim=64)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    pre_len, suf_len = 192, 2
    n_reqs = 4 if small else 8
    rng = np.random.default_rng(0)
    pre = rng.integers(0, 250, size=pre_len)
    prompts = [np.concatenate([pre, rng.integers(0, 250, size=suf_len)])
               for _ in range(n_reqs)]

    def batcher(cache=None):
        cb = ContinuousBatcher(cfg, params, n_slots=4, max_len=256,
                               sampler=quantized_greedy)
        if cache is not None:
            cb.attach_prefix_cache(cache)
        return cb

    def serve(cb, ps, n_new=4):
        out = []
        for i, p in enumerate(ps):
            r = ServeRequest(1000 + i, p, max_new_tokens=n_new)
            cb.admit(r)
            while cb.n_active:
                cb.step()
            out.append(tuple(r.generated))
        return out

    # -- part 1: prefill savings, bit-identity, compile guard --------------
    cb_cold = batcher()
    t0 = time.perf_counter()
    cold = serve(cb_cold, prompts)
    cold_wall = time.perf_counter() - t0
    cold_stats = cb_cold.hotpath_stats()

    pc = PrefixCache()
    cb_warm = batcher(cache=pc)
    warm_first = serve(cb_warm, prompts[:1])   # warmup: miss + deposit
    warmup_tokens = cb_warm.n_prefill_tokens
    t0 = time.perf_counter()
    warm_rest = serve(cb_warm, prompts[1:])
    warm_wall = time.perf_counter() - t0
    warm_stats = cb_warm.hotpath_stats()
    assert warm_first + warm_rest == cold, \
        "prefix-cache streams diverged from cold prefill"
    post_cache = warm_stats["n_prefill_tokens"] - warmup_tokens
    post_cold = (n_reqs - 1) * (pre_len + suf_len)
    ratio = post_cache / max(post_cold, 1)
    assert ratio <= 0.02, (
        f"post-warmup prefill tokens {post_cache} are "
        f"{100 * ratio:.1f}% of the no-cache run (gate: <= 2%)")
    for k in ("decode_compiles", "prefill_compiles"):
        assert warm_stats[k] <= cold_stats[k], (
            k, warm_stats[k], cold_stats[k],
            "prefix import triggered a fresh compile")
    assert warm_stats["prefix_hits"] == n_reqs - 1
    emit(f"prefix_serve_cached_n{n_reqs}", warm_wall * 1e6,
         f"prefill_tokens={post_cache}/{post_cold} "
         f"ratio={100 * ratio:.2f}% hits={warm_stats['prefix_hits']:.0f} "
         f"hit_tokens={warm_stats['prefix_hit_tokens']:.0f}")
    emit(f"prefix_serve_cold_n{n_reqs}", cold_wall * 1e6,
         f"speedup={cold_wall / max(warm_wall, 1e-9):.2f}x "
         f"streams_identical=True compiles_unchanged=True")

    # -- part 2: resurrect-from-spill TTFT vs cold spawn -------------------
    bundle = pc.export_entries()
    bundle_bytes = sum(e.nbytes for _, e in bundle)
    probe_prompt = np.concatenate([pre, rng.integers(0, 250, size=suf_len)])

    # pre-warm prompt: same length, guaranteed 0-token overlap with the
    # cached prefix, so timed admissions measure prefill/import work, not
    # tracing
    warm_prompt = np.full(pre_len + suf_len, (int(pre[0]) + 1) % 250,
                          np.int64)

    def ttft(cb, repeats=3):
        cb.admit(ServeRequest(1, warm_prompt, max_new_tokens=2))
        while cb.n_active:
            cb.step()
        best = float("inf")
        for i in range(repeats):
            r = ServeRequest(10 + i, probe_prompt, max_new_tokens=1)
            t0 = time.perf_counter()
            cb.admit(r)
            while not r.generated:
                cb.step()
            best = min(best, time.perf_counter() - t0)
            while cb.n_active:
                cb.step()
        return best

    cold_ttft = ttft(batcher())
    pc_res = PrefixCache()
    assert pc_res.import_entries(bundle) >= 1
    res_ttft = ttft(batcher(cache=pc_res))
    assert res_ttft < cold_ttft, (
        f"resurrect TTFT {res_ttft * 1e3:.1f}ms did not beat cold spawn "
        f"{cold_ttft * 1e3:.1f}ms")
    modeled_pull = sim.state_resurrect_time(bundle_bytes, GPU_PAPER)
    emit("prefix_resurrect_ttft", res_ttft * 1e6,
         f"cold={cold_ttft * 1e3:.1f}ms speedup="
         f"{cold_ttft / max(res_ttft, 1e-9):.2f}x "
         f"bundle={bundle_bytes / 1e6:.1f}MB "
         f"modeled_pull={modeled_pull:.3f}s")

    # -- part 3: modeled fleet spill/resurrect, tick == event --------------
    import dataclasses
    import types

    from repro.cluster import (Autoscaler, AutoscalerConfig, ClusterConfig,
                               ClusterMetrics, ClusterRouter, LogicalClock,
                               SimProfile, SloAware, StateTier,
                               repeated_prefix_trace, sim_server_factory)

    n_w1 = 8 if small else 16
    n_w2 = 6 if small else 12

    def fleet_run(engine):
        ccfg = ClusterConfig(tick_s=0.05, n_slots=4,
                             prefix_cache_bytes=64 << 20)
        auto = Autoscaler(AutoscalerConfig(min_servers=1, max_servers=2,
                                           idle_ticks_before_retire=20))
        # gaps sit OFF the tick grid (see repeated_prefix_trace docstring)
        wave1 = repeated_prefix_trace(n_w1, prefix_len=24, suffix_len=4,
                                      gap_s=0.021, seed=0)
        wave2 = repeated_prefix_trace(n_w2, prefix_len=24, suffix_len=4,
                                      gap_s=0.011, seed=100)
        trace = wave1 + [dataclasses.replace(a, time=a.time + 8.003)
                         for a in wave2]
        mcfg = types.SimpleNamespace(vocab_size=250, name="m")
        r = ClusterRouter(mcfg, None, n_servers=2, ccfg=ccfg,
                          autoscaler=auto,
                          dispatch=SloAware(step_cost_s=0.05,
                                            prefix_bonus_s_per_token=0.001),
                          clock=LogicalClock(), metrics=ClusterMetrics(),
                          server_factory=sim_server_factory(SimProfile()),
                          state_tier=StateTier())
        done = r.run(trace, engine=engine)
        return {q.rid: tuple(q.generated) for q in done}, r.metrics.summary()

    t0 = time.perf_counter()
    runs = {name: fleet_run(eng) for name, eng in
            (("event", "event"), ("tick", "tick"), ("event2", "event"))}
    fleet_wall = time.perf_counter() - t0
    s_evt = runs["event"][1]
    assert runs["event"][0] == runs["tick"][0] == runs["event2"][0], \
        "state-tier fleet replay diverged across engines"
    for k in ("n_completed", "prefix_hits", "prefix_hit_tokens",
              "prefix_evictions", "spill_resurrections", "spilled_bytes"):
        assert abs(s_evt[k] - runs["tick"][1][k]) < 1e-9, \
            (k, s_evt[k], runs["tick"][1][k])
    assert s_evt["n_completed"] == n_w1 + n_w2
    assert s_evt["spill_resurrections"] >= 1, \
        "idle retirement never spilled / respawn never resurrected"
    assert s_evt["prefix_hits"] > 0
    emit(f"prefix_fleet_n{n_w1 + n_w2}", fleet_wall * 1e6,
         f"hits={s_evt['prefix_hits']:.0f} "
         f"hit_tokens={s_evt['prefix_hit_tokens']:.0f} "
         f"resurrections={s_evt['spill_resurrections']:.0f} "
         f"spilled_bytes={s_evt['spilled_bytes']:.0f} tick==event")

    path = "BENCH_prefix.json"
    n = append_keyed_entry(path, {
        "commit": _git_commit(),
        "config": {"bench": "prefix", "arch": cfg.name, "pre_len": pre_len,
                   "suf_len": suf_len, "n_reqs": n_reqs, "n_w1": n_w1,
                   "n_w2": n_w2, "small": small},
        "ts": time.time(),
        "prefill_tokens_nocache": int(post_cold),
        "prefill_tokens_cache": int(post_cache),
        "prefill_token_ratio": ratio,
        "tokens_identical": True,
        "prefix_hits": int(warm_stats["prefix_hits"]),
        "prefix_hit_tokens": int(warm_stats["prefix_hit_tokens"]),
        "decode_compiles": int(warm_stats["decode_compiles"]),
        "prefill_compiles": int(warm_stats["prefill_compiles"]),
        "cold_ttft_s": cold_ttft,
        "resurrect_ttft_s": res_ttft,
        "resurrect_speedup": cold_ttft / max(res_ttft, 1e-9),
        "bundle_bytes": int(bundle_bytes),
        "modeled_pull_s": modeled_pull,
        "fleet": {
            "n_completed": int(s_evt["n_completed"]),
            "prefix_hits": s_evt["prefix_hits"],
            "prefix_hit_tokens": s_evt["prefix_hit_tokens"],
            "spill_resurrections": s_evt["spill_resurrections"],
            "spilled_bytes": s_evt["spilled_bytes"],
            "tick_event_equal": True,
        },
    })
    print(f"# wrote {path} ({n} entries)")


# ---------------------------------------------------------------------------

BENCHES = [
    bench_ttft, bench_ttft_lora, bench_cold_start_breakdown,
    bench_breakdown_lora, bench_strategy_crossover, bench_scaling_shapes,
    bench_scaling_devices, bench_adapter_epochs, bench_recovery_loading,
    bench_recovery_inference, bench_engine_functional, bench_cluster_burst,
    bench_decode_hotpath, bench_recovery, bench_coldstart, bench_fleet,
    bench_azure_day, bench_chaos, bench_multicast, bench_prefix,
    bench_kernels,
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("benches", nargs="*",
                    help="bench function names to run (default: all)")
    ap.add_argument("--small", action="store_true",
                    help="reduced sizes for benches that support it "
                         "(CI fast-lane smoke)")
    ap.add_argument("--list", action="store_true",
                    help="print the bench registry (name, --small "
                         "support, one-line description) and exit")
    args = ap.parse_args(argv)
    if args.list:
        for b in BENCHES:
            doc = (inspect.getdoc(b) or "").split("\n")[0]
            small = ("--small"
                     if "small" in inspect.signature(b).parameters else "")
            print(f"{b.__name__:28s} {small:7s} {doc}")
        return
    sel = BENCHES
    if args.benches:
        by_name = {b.__name__: b for b in BENCHES}
        unknown = [n for n in args.benches if n not in by_name]
        if unknown:
            raise SystemExit(f"unknown benches {unknown}; "
                             f"available: {sorted(by_name)}")
        sel = [by_name[n] for n in args.benches]
    print("name,us_per_call,derived")
    for b in sel:
        if "small" in inspect.signature(b).parameters:
            b(small=args.small)
        else:
            b()


if __name__ == "__main__":
    main()
