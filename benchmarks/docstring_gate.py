"""Docstring-coverage gate (stdlib-only ``interrogate`` stand-in).

**Superseded in CI** by rule R6 of the ``pbcheck`` suite
(``src/repro/analysis/``, see ``docs/ANALYSIS.md``), which reports the
same walk per missing item instead of as a percentage — fixable,
suppressible, and baselinable like any other finding.  This module
stays as the standalone percentage reporter (and its walk remains
under test in ``tests/test_bench_guards.py``).

Walks Python files, counts docstring-carrying definitions — modules,
public classes, and public functions/methods — and fails (exit 1) when
coverage drops below ``--fail-under``.

"Public" means the name has no leading underscore.  Mirroring
``interrogate``'s defaults: dunders (incl. ``__init__`` — constructors
are documented by their class docstring), ``@property`` getters (their
name is the doc), and functions nested inside functions (implementation
detail) are all excluded.  No third-party deps — the container image has
no ``interrogate``, and the gate must run in the fast CI lane.

Usage::

    python benchmarks/docstring_gate.py src/repro/cluster --fail-under 95
    python benchmarks/docstring_gate.py src/repro --fail-under 80 -v
"""
from __future__ import annotations

import argparse
import ast
import os
import sys
from typing import Iterator, List, Tuple

# (path, qualname, kind, has_docstring)
Entry = Tuple[str, str, str, bool]


def _is_public(name: str) -> bool:
    return not name.startswith("_")


def _is_property(node: ast.AST) -> bool:
    for dec in getattr(node, "decorator_list", []):
        if isinstance(dec, ast.Name) and dec.id == "property":
            return True
        if isinstance(dec, ast.Attribute) and dec.attr in ("getter",
                                                           "setter",
                                                           "deleter"):
            return True
    return False


def _walk_defs(tree: ast.Module, path: str) -> Iterator[Entry]:
    """Yield one entry per checkable definition in a parsed module."""
    yield path, "<module>", "module", ast.get_docstring(tree) is not None
    stack: List[Tuple[ast.AST, str]] = [(tree, "")]
    while stack:
        node, prefix = stack.pop()
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                qual = f"{prefix}{child.name}"
                if _is_public(child.name) and not _is_property(child):
                    kind = ("class" if isinstance(child, ast.ClassDef)
                            else "function")
                    yield (path, qual, kind,
                           ast.get_docstring(child) is not None)
                # descend into classes only: functions nested inside
                # functions are implementation detail, and anything under
                # a private scope is private by construction
                if isinstance(child, ast.ClassDef) \
                        and _is_public(child.name):
                    stack.append((child, f"{qual}."))


def iter_python_files(roots: List[str]) -> Iterator[str]:
    for root in roots:
        if os.path.isfile(root):
            yield root
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__", ".git"))
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def collect(roots: List[str]) -> List[Entry]:
    entries: List[Entry] = []
    for path in iter_python_files(roots):
        with open(path, "rb") as f:
            source = f.read()
        try:
            tree = ast.parse(source, filename=path)
        except SyntaxError as e:
            raise SystemExit(f"{path}: not parseable: {e}")
        entries.extend(_walk_defs(tree, path))
    return entries


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail when public-API docstring coverage drops")
    ap.add_argument("roots", nargs="+",
                    help="files or directories to scan (recursively)")
    ap.add_argument("--fail-under", type=float, default=95.0,
                    help="minimum coverage percent (default 95)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="list every missing docstring, not just the tally")
    args = ap.parse_args(argv)

    entries = collect(args.roots)
    if not entries:
        raise SystemExit(f"no Python definitions under {args.roots}")
    missing = [e for e in entries if not e[3]]
    covered = len(entries) - len(missing)
    pct = 100.0 * covered / len(entries)

    for path, qual, kind, _ in missing if args.verbose else missing[:20]:
        print(f"MISSING {kind:8s} {path}:{qual}")
    if not args.verbose and len(missing) > 20:
        print(f"... and {len(missing) - 20} more (-v for all)")
    print(f"docstring coverage: {covered}/{len(entries)} = {pct:.1f}% "
          f"(gate: {args.fail_under:.1f}%)")
    if pct < args.fail_under:
        print("FAIL: coverage below gate")
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
