"""The paper's evaluation model set (§5.1: OPT series, Mistral-7B,
Falcon-7B) as ArchConfigs — used by the paper-table benchmarks only."""
from repro.configs.base import ArchConfig

OPT_1_3B = ArchConfig(
    name="opt-1.3b", family="dense", n_layers=24, d_model=2048, n_heads=32,
    n_kv_heads=32, head_dim=64, d_ff=8192, vocab_size=50272, act="gelu",
    gated_mlp=False, tie_embeddings=True, rope_theta=1e4,
    source="[arXiv:2205.01068; hf]")

OPT_2_7B = ArchConfig(
    name="opt-2.7b", family="dense", n_layers=32, d_model=2560, n_heads=32,
    n_kv_heads=32, head_dim=80, d_ff=10240, vocab_size=50272, act="gelu",
    gated_mlp=False, tie_embeddings=True, rope_theta=1e4,
    source="[arXiv:2205.01068; hf]")

OPT_6_7B = ArchConfig(
    name="opt-6.7b", family="dense", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=32, head_dim=128, d_ff=16384, vocab_size=50272, act="gelu",
    gated_mlp=False, tie_embeddings=True, rope_theta=1e4,
    source="[arXiv:2205.01068; hf]")

OPT_13B = ArchConfig(
    name="opt-13b", family="dense", n_layers=40, d_model=5120, n_heads=40,
    n_kv_heads=40, head_dim=128, d_ff=20480, vocab_size=50272, act="gelu",
    gated_mlp=False, tie_embeddings=True, rope_theta=1e4,
    source="[arXiv:2205.01068; hf]")

MISTRAL_7B = ArchConfig(
    name="mistral-7b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab_size=32000,
    attn_window=4096, rope_theta=1e4, source="[arXiv:2310.06825; hf]")

FALCON_7B = ArchConfig(
    name="falcon-7b", family="dense", n_layers=32, d_model=4544,
    n_heads=71, n_kv_heads=71, head_dim=64, d_ff=18176, vocab_size=65024,
    act="gelu", gated_mlp=False, rope_theta=1e4,
    source="[arXiv:2311.16867; hf]")

PAPER_MODELS = [OPT_1_3B, OPT_2_7B, OPT_6_7B, OPT_13B, MISTRAL_7B, FALCON_7B]
