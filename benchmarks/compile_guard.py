"""Compile-count regression guard (CI fast lane).

Runs a short mixed-length serving burst on a tiny model and asserts the
hot path's XLA compile counts stay at their designed bounds:

* prefill: one compilation per length *bucket* actually hit (never one per
  unique prompt length) — catches accidental shape leaks into the padded
  prefill;
* decode: exactly ONE compilation for the engine's lifetime, across
  admissions, completions, and adapter epoch switches — catches accidental
  retraces (e.g. rebuilding the jit on adapter switch, or baking a Python
  value into the traced step).

Exits non-zero on violation so CI fails fast.

    PYTHONPATH=src python benchmarks/compile_guard.py
"""
from __future__ import annotations

import sys

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.core.adapter_scheduler import EpochSchedulerPolicy
from repro.lora.adapters import init_lora, merge_lora, randomize_lora
from repro.models import transformer as T
from repro.serving.engine import (ServeRequest, ServingEngine, bucket_sizes,
                                  quantized_greedy)


def main() -> int:
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=2)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    lora = randomize_lora(jax.random.fold_in(key, 1),
                          init_lora(key, cfg, rank=4))
    merged = merge_lora(params, lora)

    max_len = 128
    eng = ServingEngine(cfg, params, n_slots=4, max_len=max_len,
                        policy=EpochSchedulerPolicy(epoch_budget=2,
                                                    max_batch=4),
                        adapter_params={"a": merged})
    eng.batcher.sampler = quantized_greedy

    rng = np.random.default_rng(0)
    lengths = rng.permutation(np.arange(5, max_len - 8))[:16]
    assert len(set(lengths.tolist())) == 16, "want 16 unique lengths"
    for i, L in enumerate(lengths):
        eng.submit(ServeRequest(i, rng.integers(0, 250, size=int(L)),
                                max_new_tokens=3,
                                adapter="a" if i % 2 else None))
    done = eng.run()

    cs = eng.batcher.compile_stats()
    n_buckets = len(bucket_sizes(max_len))
    print(f"completed={len(done)} adapter_switches={eng.n_adapter_switches} "
          f"prefill_compiles={cs['prefill_compiles']} (buckets={n_buckets}, "
          f"unique_lengths=16) decode_compiles={cs['decode_compiles']}")

    if cs["prefill_compiles"] < 0 or cs["decode_compiles"] < 0:
        # compile_stats reports -1 when jax's private cache-size API is
        # gone — that is a tooling gap, not a retrace; don't fail red with
        # a wrong diagnosis
        print("SKIP: compile-count API unavailable in this jax version "
              "(jitted-fn _cache_size missing); guard not enforced")
        return 0

    ok = True
    if len(done) != 16:
        print(f"FAIL: only {len(done)}/16 requests completed")
        ok = False
    if eng.n_adapter_switches < 2:
        print("FAIL: adapter epochs never switched — guard lost coverage")
        ok = False
    if not 0 < cs["prefill_compiles"] <= n_buckets:
        print(f"FAIL: prefill compiled {cs['prefill_compiles']}x for 16 "
              f"unique lengths (bound: {n_buckets} buckets) — bucketing "
              "regressed")
        ok = False
    if cs["decode_compiles"] != 1:
        print(f"FAIL: decode compiled {cs['decode_compiles']}x (must be 1 "
              "for the engine's lifetime) — a retrace crept in")
        ok = False
    print("compile guard:", "OK" if ok else "FAILED")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
