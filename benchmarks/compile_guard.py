"""Compile-count regression guard (CI fast lane).

Runs a short mixed-length serving burst on a tiny model and asserts the
hot path's XLA compile counts stay at their designed bounds:

* prefill: one compilation per length *bucket* actually hit (never one per
  unique prompt length) — catches accidental shape leaks into the padded
  prefill;
* decode: exactly ONE compilation for the engine's lifetime, across
  admissions, completions, and adapter epoch switches — catches accidental
  retraces (e.g. rebuilding the jit on adapter switch, or baking a Python
  value into the traced step).

Exits non-zero on violation so CI fails fast.

    PYTHONPATH=src python benchmarks/compile_guard.py
"""
from __future__ import annotations

import sys

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.core.adapter_scheduler import EpochSchedulerPolicy
from repro.lora.adapters import init_lora, merge_lora, randomize_lora
from repro.models import transformer as T
from repro.serving.engine import (ServeRequest, ServingEngine, bucket_sizes,
                                  quantized_greedy)


def evaluate(cs, n_done: int, n_switches: int, n_buckets: int,
             n_expected: int = 16):
    """Judge one guard run.  Returns (verdict, messages) with verdict one
    of "ok" | "skip" | "fail".

    The -1 sentinel (``compile_stats`` reporting the private jit
    cache-size API as unavailable) must map to "skip" — NEVER "ok": a
    sentinel that slipped into the bound comparison would satisfy
    ``-1 <= n_buckets`` vacuously and green-light a regressed build.
    The coverage checks (requests completed, epochs switched) don't
    depend on that API and still fail even when the counts are skipped.
    """
    msgs = []
    if n_done != n_expected:
        msgs.append(f"FAIL: only {n_done}/{n_expected} requests completed")
    if n_switches < 2:
        msgs.append("FAIL: adapter epochs never switched — guard lost "
                    "coverage")
    if cs["prefill_compiles"] < 0 or cs["decode_compiles"] < 0:
        # tooling gap, not a retrace; don't fail red with a wrong diagnosis
        msgs.append("WARN: compile-count API unavailable in this jax "
                    "version (jitted-fn _cache_size missing); compile "
                    "bounds not enforced")
        return ("fail" if any(m.startswith("FAIL") for m in msgs)
                else "skip"), msgs
    if not 0 < cs["prefill_compiles"] <= n_buckets:
        msgs.append(f"FAIL: prefill compiled {cs['prefill_compiles']}x for "
                    f"{n_expected} unique lengths (bound: {n_buckets} "
                    "buckets) — bucketing regressed")
    if cs["decode_compiles"] != 1:
        msgs.append(f"FAIL: decode compiled {cs['decode_compiles']}x (must "
                    "be 1 for the engine's lifetime) — a retrace crept in")
    return ("ok" if not msgs else "fail"), msgs


def evaluate_repartition(cs, n_stage_counts: int, n_crash_events: int,
                         chain_ok: bool):
    """Judge the elastic-repartition guard run.  Returns (verdict,
    messages) with verdict "ok" | "skip" | "fail".

    The compile discipline under repartition: the fused decode step stays
    at exactly ONE compile across every crash/rejoin cycle (the re-laid
    cache keeps the original lowering's shapes), and the pipeline prefill
    lowers at most once per DISTINCT stage count actually pipelined —
    NEVER once per crash event.  The -1 sentinel (cache-size API missing)
    skips the bounds, same contract as ``evaluate``.
    """
    msgs = []
    if not chain_ok:
        msgs.append("FAIL: engine lost its serving chain across "
                    "repartition cycles — guard lost coverage")
    if cs["decode_compiles"] < 0:
        msgs.append("WARN: compile-count API unavailable in this jax "
                    "version; repartition compile bounds not enforced")
        return ("fail" if any(m.startswith("FAIL") for m in msgs)
                else "skip"), msgs
    if cs["decode_compiles"] != 1:
        msgs.append(f"FAIL: decode compiled {cs['decode_compiles']}x "
                    f"across {n_crash_events} crash/rejoin events (must "
                    "stay 1) — a repartition retrace crept in")
    if cs["pipeline_prefill_compiles"] > n_stage_counts:
        msgs.append(f"FAIL: pipeline prefill compiled "
                    f"{cs['pipeline_prefill_compiles']}x for "
                    f"{n_stage_counts} distinct stage counts over "
                    f"{n_crash_events} crash/rejoin events — repartition "
                    "recompiles per event instead of per stage count")
    return ("ok" if not msgs else "fail"), msgs


def repartition_guard() -> int:
    """Crash/rejoin cycles through ``PipeBoostEngine.repartition`` with a
    fixed prefill shape: compiles must track DISTINCT stage counts, not
    fault events.  On a single-XLA-device host the pipeline never engages
    (0 stage counts, 0 pipeline compiles) and the decode bound still
    guards."""
    import jax.numpy as jnp

    from repro.core.engine import PipeBoostEngine

    cfg = get_arch("qwen3-1.7b").reduced(n_layers=8)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    while eng.load_round():
        pass
    eng.enable_pipeline_prefill()
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, cfg.vocab_size)}
    tok = jnp.argmax(eng.prefill(batch), axis=-1).astype(jnp.int32)
    eng.decode(tok)
    stage_counts = {eng._pipe_n_stages} if eng._pipe_enabled else set()
    n_crash_events = 0
    chain_ok = True
    for _ in range(3):
        for dead, revive in (([3], []), ([], [3])):
            st = eng.repartition(dead=dead, revive=revive)
            n_crash_events += 1
            if st["n_stages"] > 0:
                stage_counts.add(st["n_stages"])
            eng.decode(tok)                 # resumed stream, same lowering
            tok = jnp.argmax(eng.prefill(batch),  # fresh admission through
                             axis=-1).astype(jnp.int32)  # the new plan
            chain_ok = chain_ok and eng.chain() is not None

    cs = eng.compile_stats()
    print(f"repartition: crash_events={n_crash_events} "
          f"stage_counts={sorted(stage_counts)} "
          f"pipeline_prefill_compiles={cs['pipeline_prefill_compiles']} "
          f"decode_compiles={cs['decode_compiles']}")
    verdict, msgs = evaluate_repartition(cs, len(stage_counts),
                                         n_crash_events, chain_ok)
    for m in msgs:
        print(m)
    print("repartition compile guard:", verdict.upper())
    return 1 if verdict == "fail" else 0


def main() -> int:
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=2)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    lora = randomize_lora(jax.random.fold_in(key, 1),
                          init_lora(key, cfg, rank=4))
    merged = merge_lora(params, lora)

    max_len = 128
    eng = ServingEngine(cfg, params, n_slots=4, max_len=max_len,
                        policy=EpochSchedulerPolicy(epoch_budget=2,
                                                    max_batch=4),
                        adapter_params={"a": merged})
    eng.batcher.sampler = quantized_greedy

    rng = np.random.default_rng(0)
    lengths = rng.permutation(np.arange(5, max_len - 8))[:16]
    assert len(set(lengths.tolist())) == 16, "want 16 unique lengths"
    for i, L in enumerate(lengths):
        eng.submit(ServeRequest(i, rng.integers(0, 250, size=int(L)),
                                max_new_tokens=3,
                                adapter="a" if i % 2 else None))
    done = eng.run()

    cs = eng.batcher.compile_stats()
    n_buckets = len(bucket_sizes(max_len))
    print(f"completed={len(done)} adapter_switches={eng.n_adapter_switches} "
          f"prefill_compiles={cs['prefill_compiles']} (buckets={n_buckets}, "
          f"unique_lengths=16) decode_compiles={cs['decode_compiles']}")

    verdict, msgs = evaluate(cs, len(done), eng.n_adapter_switches,
                             n_buckets)
    for m in msgs:
        print(m)
    print("compile guard:", verdict.upper())
    return 1 if verdict == "fail" else repartition_guard()


if __name__ == "__main__":
    sys.exit(main())
