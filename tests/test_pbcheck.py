"""pbcheck static-analysis suite tests.

Every rule R1-R6 has a fixture trio under ``tests/fixtures/pbcheck/``:
a *violation* file the rule must flag, a *clean* file it must pass, and
a *suppressed* file whose inline ``# pbcheck: disable=Rn (reason)``
comments neutralize the findings.  On top of the per-rule matrix:
suppressions without a reason are invalid, shipped baseline entries
must be justified, the repo itself must scan clean, and the BENCH
trajectory files must satisfy their schemas.
"""
import json
import os

import pytest

from repro.analysis.baseline import TODO, Baseline, load_baseline
from repro.analysis.bench_schema import validate_file
from repro.analysis.cli import CheckConfig, run_check

HERE = os.path.dirname(os.path.abspath(__file__))
FIXTURES = os.path.join(HERE, "fixtures", "pbcheck")
REPO = os.path.dirname(HERE)
RULES = ["R1", "R2", "R3", "R4", "R5", "R6"]

# fixture paths don't look like src/repro, so scope R2/R6 by fixture
# file prefix instead of the default hot/docstring path lists
_MIN_VIOLATIONS = {"R1": 1, "R2": 3, "R3": 1, "R4": 4, "R5": 4, "R6": 1}


def _cfg(rule):
    return CheckConfig(rules=(rule,), hot_paths=("r2_",),
                       docstring_paths=("r6_",))


def _run(rule, name):
    return run_check([os.path.join(FIXTURES, name)], _cfg(rule),
                     root=FIXTURES)


@pytest.mark.parametrize("rule", RULES)
def test_violation_fixture_is_flagged(rule):
    res = _run(rule, f"{rule.lower()}_violation.py")
    assert len(res.findings) >= _MIN_VIOLATIONS[rule], \
        f"{rule} missed its violation fixture: {res.findings}"
    assert all(f.rule == rule for f in res.findings)
    assert not res.invalid_suppressions


@pytest.mark.parametrize("rule", RULES)
def test_clean_fixture_passes(rule):
    res = _run(rule, f"{rule.lower()}_clean.py")
    assert res.ok, [f.render() for f in res.findings]
    assert not res.suppressed    # clean means clean, not suppressed


@pytest.mark.parametrize("rule", RULES)
def test_suppressed_fixture_passes_with_reasons(rule):
    res = _run(rule, f"{rule.lower()}_suppressed.py")
    assert res.ok, [f.render() for f in res.findings]
    assert res.suppressed, f"{rule} suppression never matched a finding"
    assert all(reason for _, reason in res.suppressed)


def test_r5_violation_details_are_exact():
    """R5 names the typo, both unhandled kinds, and the bad mode."""
    res = _run("R5", "r5_violation.py")
    details = {f.detail for f in res.findings}
    assert details == {"unknown-kind:partial_cras",
                       "unhandled-kind:partial_crash",
                       "unhandled-kind:rejoin",
                       "unknown-mode:replay"}


def test_r5_ignores_layer_kind_vocabularies():
    """`.kind` comparisons against non-chaos vocabularies (layer kinds
    like 'prefill'/'decode') must not make a module a chaos handler."""
    src = ("CHAOS_KINDS = ('crash', 'partial_crash', 'rejoin')\n"
           "def pick(layer):\n"
           "    if layer.kind == 'prefill':\n"
           "        return 1\n"
           "    return 0\n")
    path = os.path.join(FIXTURES, "_r5_layer_kinds.py")
    with open(path, "w") as f:
        f.write(src)
    try:
        res = run_check([path], CheckConfig(rules=("R5",)), root=FIXTURES)
        assert res.ok, [f.render() for f in res.findings]
    finally:
        os.remove(path)


def test_suppression_without_reason_is_invalid(tmp_path):
    p = tmp_path / "bad.py"
    p.write_text("x = 1  # pbcheck: disable=R2\n")
    res = run_check([str(p)], CheckConfig(rules=("R2",)),
                    root=str(tmp_path))
    assert res.invalid_suppressions and not res.ok


def test_baseline_todo_justification_blocks():
    bl = Baseline({"R3|a.py|C|attr:x": {
        "fingerprint": "R3|a.py|C|attr:x", "rule": "R3",
        "justification": TODO}})
    assert bl.unjustified()


def test_baseline_matches_by_fingerprint_not_line():
    """Baseline entries key on rule|path|symbol|detail, so moving a
    finding to another line must not un-baseline it."""
    res = _run("R3", "r3_violation.py")
    f = res.findings[0]
    bl = Baseline({f.fingerprint: {"fingerprint": f.fingerprint,
                                   "rule": f.rule,
                                   "justification": "known racy read"}})
    res2 = run_check([os.path.join(FIXTURES, "r3_violation.py")],
                     _cfg("R3"), bl, root=FIXTURES)
    assert res2.ok and res2.baselined and not res2.findings


def test_shipped_baseline_is_justified():
    bl = load_baseline(os.path.join(REPO, "tools",
                                    "pbcheck_baseline.json"))
    assert not bl.unjustified()


def test_repo_scans_clean():
    """The gate CI enforces: src/repro has no unsuppressed findings."""
    bl = load_baseline(os.path.join(REPO, "tools",
                                    "pbcheck_baseline.json"))
    res = run_check([os.path.join(REPO, "src", "repro")],
                    CheckConfig(), bl, root=REPO)
    assert res.ok, [f.render() for f in res.findings] + \
        [f"invalid suppression {p}:{ln}: {m}"
         for p, ln, m in res.invalid_suppressions]
    # and every inline suppression in the tree carries a reason
    assert all(reason for _, reason in res.suppressed)


# ---------------------------------------------------------------------
# BENCH_*.json schema validation
# ---------------------------------------------------------------------

def _write_bench(tmp_path, name, entries):
    p = tmp_path / name
    p.write_text(json.dumps({"entries": entries}))
    return str(p)


_GOOD_COLDSTART = {
    "ts": 1.0, "commit": "abc", "config": {"small": True},
    "overlapped_ttft_s": 0.5, "load_then_serve_ttft_s": 1.5,
    "speedup": 3.0, "time_to_ready_wall_s": 0.2,
    "time_to_fully_loaded_wall_s": 0.9, "loaded_bytes": 10,
    "total_bytes": 40, "decode_compiles": 1, "tokens_identical": True,
}


def test_bench_schema_accepts_valid_entry(tmp_path):
    p = _write_bench(tmp_path, "BENCH_coldstart.json", [_GOOD_COLDSTART])
    errors, _ = validate_file(p)
    assert not errors


def test_bench_schema_rejects_missing_metric(tmp_path):
    bad = {k: v for k, v in _GOOD_COLDSTART.items() if k != "speedup"}
    p = _write_bench(tmp_path, "BENCH_coldstart.json", [bad])
    errors, _ = validate_file(p)
    assert any("speedup" in e for e in errors)


def test_bench_schema_rejects_bool_as_number(tmp_path):
    bad = dict(_GOOD_COLDSTART, speedup=True)
    p = _write_bench(tmp_path, "BENCH_coldstart.json", [bad])
    errors, _ = validate_file(p)
    assert any("speedup" in e for e in errors)


def test_bench_schema_rejects_unkeyed_entry(tmp_path):
    """Every entry must carry the (commit, config) trajectory key — the
    one pre-PR-6 unkeyed row was backfilled, so the tolerance is gone."""
    unkeyed = {k: v for k, v in _GOOD_COLDSTART.items()
               if k not in ("commit", "config")}
    p = _write_bench(tmp_path, "BENCH_coldstart.json", [unkeyed])
    errors, _ = validate_file(p)
    assert any("commit" in e for e in errors)
    assert any("config" in e for e in errors)
    # half a key is equally an error
    half = {k: v for k, v in _GOOD_COLDSTART.items() if k != "config"}
    p2 = _write_bench(tmp_path, "BENCH_coldstart.json", [half])
    errors2, _ = validate_file(p2)
    assert any("config" in e for e in errors2)


def test_bench_schema_checked_in_files_validate():
    import glob
    files = sorted(glob.glob(os.path.join(REPO, "BENCH_*.json")))
    assert files, "no checked-in BENCH files found"
    for p in files:
        errors, _ = validate_file(p)
        assert not errors, errors
