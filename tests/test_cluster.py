"""Serverless cluster layer: traces, metrics, routing, autoscaling, and
cross-server crash re-routing exactness.

The load-bearing invariant (mirrors the single-server recovery tests): a
whole-server crash mid-decode re-routes its in-flight requests, and every
request still produces EXACTLY the greedy tokens of a crash-free run —
resumption is a re-prefill over prompt+generated, which the continuous
batcher already proves equal to uninterrupted decoding.
"""
import json

import jax
import numpy as np
import pytest

from repro.cluster import (Arrival, Autoscaler, AutoscalerConfig,
                           ClusterConfig, ClusterRouter, burst_wave_trace,
                           gamma_trace, load_trace, percentile,
                           poisson_trace, save_trace)
from repro.cluster.traces import prompt_tokens
from repro.configs.base import get_arch
from repro.models import transformer as T
from repro.serving.engine import quantized_greedy

KEY = jax.random.PRNGKey(3)


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=4)
    params = T.init_params(cfg, KEY)
    return cfg, params


def _solo(cfg, params, prompt, n):
    """Uninterrupted single-request greedy reference."""
    import jax.numpy as jnp
    lg, cache = T.forward(cfg, params, {"tokens": jnp.asarray(prompt)[None]},
                          mode="prefill", max_len=96)
    toks = [int(quantized_greedy(lg)[0])]
    for _ in range(n - 1):
        lg, cache = T.decode_step(
            cfg, params, {"tokens": jnp.asarray([toks[-1]], jnp.int32)},
            cache)
        toks.append(int(quantized_greedy(lg)[0]))
    return toks


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------

def test_traces_deterministic_and_sorted():
    for make in (lambda s: poisson_trace(5.0, 4.0, seed=s),
                 lambda s: gamma_trace(5.0, 4.0, burstiness=6.0, seed=s),
                 lambda s: burst_wave_trace(20, seed=s)):
        a, b = make(7), make(7)
        assert a == b                       # same seed -> same trace
        assert a != make(8)                 # different seed -> different
        times = [x.time for x in a]
        assert times == sorted(times)
        assert all(t >= 0 for t in times)


def test_gamma_burstier_than_poisson():
    """CV² of inter-arrivals: gamma(burstiness=8) >> poisson ~ 1."""
    def cv2(trace):
        gaps = np.diff([a.time for a in trace])
        return float(np.var(gaps) / np.mean(gaps) ** 2)
    p = cv2(poisson_trace(10.0, 200.0, seed=0))
    g = cv2(gamma_trace(10.0, 200.0, burstiness=8.0, seed=0))
    assert g > 2.0 * p


def test_trace_roundtrip(tmp_path):
    trace = burst_wave_trace(12, seed=4, adapters=("lora0",))
    path = str(tmp_path / "trace.json")
    save_trace(path, trace)
    assert load_trace(path) == trace
    # prompt content is seed-addressed, so replay reproduces the tokens
    np.testing.assert_array_equal(prompt_tokens(trace[0], 1000),
                                  prompt_tokens(load_trace(path)[0], 1000))


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_percentile_nearest_rank():
    """Ceil-based nearest rank: smallest 1-based rank k with k/n >= q/100.
    The old round((n-1)*q/100) index interpolation mis-ranked even-n
    medians and high percentiles (it reported p50 of 100 samples as the
    51st value)."""
    assert percentile([], 99) == 0.0
    # n = 1: every percentile is the single sample
    for q in (0, 1, 50, 99, 100):
        assert percentile([7.0], q) == 7.0
    # n = 2: p50 must be the FIRST sample (rank ceil(0.5*2) = 1), anything
    # above 50 the second
    assert percentile([1.0, 2.0], 50) == 1.0
    assert percentile([2.0, 1.0], 50) == 1.0    # sorts first
    assert percentile([1.0, 2.0], 51) == 2.0
    assert percentile([1.0, 2.0], 99) == 2.0
    assert percentile([1.0, 2.0], 0) == 1.0
    # n = 100 over 1..100: nearest-rank percentile q is the value q itself
    xs = [float(i) for i in range(1, 101)]
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 50) == 50.0
    assert percentile(xs, 99) == 99.0
    assert percentile(xs, 100) == 100.0
    # monotone in q, and never out of range
    vals = [percentile(xs, q) for q in range(0, 101)]
    assert vals == sorted(vals)
    assert min(vals) >= 1.0 and max(vals) <= 100.0


def test_summary_metrics():
    from repro.cluster.metrics import ClusterMetrics
    m = ClusterMetrics()
    m.on_submit(0, 1.0)
    m.on_first_token(0, 1.5)
    m.on_finish(0, 3.5, n_tokens=5, server=0)
    m.on_tick(0.0, 3, 2, gpu_busy=4, tick_s=0.5)
    s = m.summary()
    assert s["ttft_p50"] == pytest.approx(0.5)
    assert s["tbt_p50"] == pytest.approx(0.5)   # (3.5-1.5)/(5-1)
    assert s["gpu_seconds"] == pytest.approx(2.0)
    doc = json.loads(m.to_json())
    assert doc["summary"]["n_completed"] == 1.0
    assert doc["requests"][0]["rid"] == 0


# ---------------------------------------------------------------------------
# router end-to-end
# ---------------------------------------------------------------------------

def test_bursty_trace_all_requests_complete(setup):
    cfg, params = setup
    trace = burst_wave_trace(10, base_rate=2.0, wave_rate=20.0, wave_at=0.3,
                             wave_len=0.5, seed=1, max_new_tokens=4)
    router = ClusterRouter(cfg, params, n_servers=2,
                           ccfg=ClusterConfig(n_devices=2, n_slots=2))
    done = router.run(trace)
    assert len(done) == len(trace)
    s = router.metrics.summary()
    assert s["n_completed"] == len(trace)
    assert s["ttft_p99"] > 0 and s["tbt_p50"] > 0
    # every request's output equals the uninterrupted solo reference
    for r in done:
        assert r.generated == _solo(cfg, params, r.tokens, 4), r.rid


def test_autoscaler_spins_up_and_serves_before_full_load(setup):
    cfg, params = setup
    # 4-device servers: viable chain after 1 round, full after 4 rounds —
    # a window in which the scaled-up server must take traffic.
    trace = burst_wave_trace(14, base_rate=4.0, wave_rate=50.0, wave_at=0.2,
                             wave_len=0.6, seed=2, max_new_tokens=4)
    scaler = Autoscaler(AutoscalerConfig(target_queue_per_server=2.0,
                                         ttft_slo_s=0.3, max_servers=3,
                                         scale_up_cooldown_ticks=3))
    router = ClusterRouter(cfg, params, n_servers=1,
                           ccfg=ClusterConfig(n_devices=4, n_slots=2),
                           autoscaler=scaler)
    done = router.run(trace)
    assert len(done) == len(trace)
    assert scaler.n_scale_ups >= 1
    assert len(router.servers) >= 2
    newcomers = router.servers[1:]
    # a mid-burst server admitted traffic while still background-loading
    assert any(s.served_while_loading for s in newcomers)
    # and it actually completed work
    assert any(r.finished_at is not None and r.rid >= 0
               for r in router.servers[1].srv.completed)


def test_crash_reroute_tokens_exact(setup):
    """Server 1 dies mid-decode; re-routed requests finish on survivors with
    tokens identical to a crash-free run of the same trace."""
    cfg, params = setup
    trace = burst_wave_trace(12, base_rate=2.0, wave_rate=30.0, wave_at=0.3,
                             wave_len=0.5, seed=5, max_new_tokens=5)

    def run(crash):
        router = ClusterRouter(cfg, params, n_servers=2,
                               ccfg=ClusterConfig(n_devices=2, n_slots=2))
        done = router.run(trace, crash_after_completions=3 if crash else None,
                          crash_server_id=1,
                          rejoin_after_ticks=15 if crash else None)
        return router, {r.rid: r.generated for r in done}

    r_crash, toks_crash = run(True)
    r_ref, toks_ref = run(False)
    assert set(toks_crash) == set(toks_ref) == set(range(len(trace)))
    for rid in toks_ref:
        assert toks_crash[rid] == toks_ref[rid], rid
    s = r_crash.metrics.summary()
    assert s["n_completed"] == len(trace)
    kinds = [k for _, k, _ in r_crash.metrics.events]
    assert "crash" in kinds and "rejoin" in kinds
    # the downed server rebooted through the pipelined loader and serves again
    assert r_crash.servers[1].state in ("loading", "serving")


def test_crash_migration_zero_reprefill_tokens_exact(setup):
    """With survivor capacity available, a whole-server crash migrates
    every in-flight request's KV snapshot: zero prompt tokens re-prefill
    anywhere, and outputs equal the crash-free run token-for-token (the
    equivalence oracle the re-prefill path already satisfies)."""
    cfg, params = setup
    trace = burst_wave_trace(10, base_rate=2.0, wave_rate=20.0, wave_at=0.3,
                             wave_len=0.5, seed=5, max_new_tokens=6)

    def run(crash):
        router = ClusterRouter(cfg, params, n_servers=3,
                               ccfg=ClusterConfig(n_devices=2, n_slots=6))
        done = router.run(trace, crash_after_completions=2 if crash else None,
                          crash_server_id=1,
                          rejoin_after_ticks=15 if crash else None)
        return router, {r.rid: r.generated for r in done}

    r_crash, toks_crash = run(True)
    _, toks_ref = run(False)
    s = r_crash.metrics.summary()
    assert s["recovery_mode_migrate"] >= 1          # migration actually ran
    assert s["recovery_migrated_tokens"] > 0
    assert s["recovery_reprefill_tokens"] == 0.0    # nothing re-prefilled
    assert s["recovery_mode_reprefill"] == 0.0
    assert set(toks_crash) == set(toks_ref)
    for rid in toks_ref:
        assert toks_crash[rid] == toks_ref[rid], rid
    # recovery counters ride into the JSON blob
    doc = json.loads(r_crash.metrics.to_json())
    assert doc["recovery"]["mode_migrate"] >= 1
    assert doc["summary"]["recovery_reprefill_tokens"] == 0.0


def test_crash_migration_falls_back_when_survivors_full(setup):
    """No admitting survivor capacity -> snapshots are dropped and the
    legacy re-prefill re-route still completes every request exactly."""
    cfg, params = setup
    trace = burst_wave_trace(12, base_rate=2.0, wave_rate=30.0, wave_at=0.3,
                             wave_len=0.5, seed=5, max_new_tokens=8)

    def run(crash):
        router = ClusterRouter(cfg, params, n_servers=2,
                               ccfg=ClusterConfig(n_devices=2, n_slots=2))
        arrivals = sorted(trace, key=lambda a: a.time)
        i, crashed, done = 0, False, []
        for _ in range(200_000):
            while i < len(arrivals) and arrivals[i].time <= router.clock:
                router.submit(arrivals[i])
                i += 1
            done.extend(router.tick())
            s0, s1 = router.servers[0], router.servers[1]
            if (crash and not crashed and s1.srv.batcher.n_active >= 1
                    and not s0.srv.batcher.free):
                router.crash_server(1)   # survivors full: must fall back
                crashed = True
            if i >= len(arrivals) and router.pending == 0:
                break
        assert not crash or crashed, "fallback scenario never armed"
        return router, {r.rid: r.generated for r in done}

    r_crash, toks_crash = run(True)
    _, toks_ref = run(False)
    s = r_crash.metrics.summary()
    # survivors were full: at least one displaced request re-prefilled
    assert s["recovery_mode_reprefill"] >= 1
    assert s["recovery_reprefill_tokens"] > 0
    assert set(toks_crash) == set(toks_ref)
    for rid in toks_ref:
        assert toks_crash[rid] == toks_ref[rid], rid


def test_partial_crash_reconstructs_only_lost_layers(setup):
    """Killing one device of a mid-load serving chain rebuilds ONLY the
    layers whose state lived there (Q-only recompute elsewhere); requests
    never leave the server and stay token-exact."""
    from repro.cluster import ClusterServer
    from repro.serving.engine import ServeRequest
    cfg, params = setup
    ccfg = ClusterConfig(n_devices=4, n_slots=2)
    server = ClusterServer(0, cfg, params, ccfg)
    while server.state == "loading":
        server.tick(0.0)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, 250, size=L) for L in (10, 13)]
    reqs = [ServeRequest(i, p, max_new_tokens=8)
            for i, p in enumerate(prompts)]
    for r in reqs:
        server.submit(r)
    server.tick(0.0)                       # admit + decode while chain
    assert server.srv.batcher.n_active == 2
    # pick a device owning SOME but not all layers' state
    cands = [d for d in range(ccfg.n_devices)
             if 0 < sum(server.engine.lost_state_layers([d]))
             < cfg.n_layers]
    assert cands, "chain collapsed to one device — can't test partial loss"
    n_lost = sum(server.engine.lost_state_layers([cands[0]]))
    drained = server.crash([cands[0]])
    assert drained == []                   # requests stay on the server
    assert server.state == "recovering"
    stats = server.last_recovery
    assert stats["reconstructed_reqs"] == 2
    assert stats["full_prefill"] == n_lost * 2
    assert stats["kv_reused"] + stats["layers_skipped"] > 0
    kinds = [e for e, _ in server.engine.events]
    assert "crash" in kinds
    now = 1.0
    while any(not r.done for r in reqs):
        server.tick(now)
        now += ccfg.tick_s
    assert "recover" in [e for e, _ in server.engine.events]
    for i, p in enumerate(prompts):
        assert reqs[i].generated == _solo(cfg, params, p, 8), i


def test_partial_crash_recovers_in_place(setup):
    """Killing one device of a 4-device server re-plans over survivors
    (engine.recover) instead of downing the whole server."""
    cfg, params = setup
    trace = poisson_trace(6.0, 1.5, seed=9, max_new_tokens=4)
    router = ClusterRouter(cfg, params, n_servers=2,
                           ccfg=ClusterConfig(n_devices=4, n_slots=2))
    done = router.run(trace, crash_after_completions=2, crash_server_id=1,
                      crash_devices=[0])
    assert len(done) == len(trace)
    srv1 = router.servers[1]
    assert srv1.state == "serving"
    kinds = [e for e, _ in srv1.engine.events]
    assert "crash" in kinds and "recover" in kinds
    for r in done:
        assert r.generated == _solo(cfg, params, r.tokens, 4), r.rid


def test_strategy_switch_fires_when_fully_loaded(setup):
    cfg, params = setup
    trace = poisson_trace(8.0, 2.0, seed=11, max_new_tokens=3)
    router = ClusterRouter(cfg, params, n_servers=1,
                           ccfg=ClusterConfig(n_devices=2, n_slots=4))
    router.run(trace)
    eng = router.servers[0].engine
    assert eng.fully_loaded and eng.strategy == "single"
    assert ("strategy_switch", "single") in eng.events


def test_autoscaler_slo_fires_on_server_side_queueing(setup):
    """The TTFT-SLO signal must see requests queued INSIDE servers — the
    router queue drains every tick, so with an absurdly high queue-depth
    threshold only head-of-line wait can trigger the scale-up."""
    cfg, params = setup
    trace = burst_wave_trace(10, base_rate=4.0, wave_rate=40.0, wave_at=0.2,
                             wave_len=0.4, seed=6, max_new_tokens=6)
    scaler = Autoscaler(AutoscalerConfig(target_queue_per_server=1000.0,
                                         ttft_slo_s=0.15, max_servers=3))
    router = ClusterRouter(cfg, params, n_servers=1,
                           ccfg=ClusterConfig(n_devices=2, n_slots=1),
                           autoscaler=scaler)
    done = router.run(trace)
    assert len(done) == len(trace)
    assert scaler.n_scale_ups >= 1


def test_unknown_trace_adapter_fails_fast(setup):
    cfg, params = setup
    router = ClusterRouter(cfg, params, n_servers=1)
    with pytest.raises(ValueError, match="ghost"):
        router.submit(Arrival(0.1, adapter="ghost"))


def test_cluster_serves_adapters_exactly(setup):
    """Adapter-tagged arrivals route through the fleet and produce the
    same tokens as a solo run on the merged weights."""
    from repro.lora.adapters import init_lora, merge_lora, randomize_lora
    cfg, params = setup
    lora = randomize_lora(jax.random.fold_in(KEY, 9),
                          init_lora(KEY, cfg, rank=4))
    merged = merge_lora(params, lora)
    trace = poisson_trace(6.0, 1.5, seed=13, max_new_tokens=3,
                          adapters=("a",))
    assert any(a.adapter for a in trace)
    router = ClusterRouter(cfg, params, n_servers=2,
                           ccfg=ClusterConfig(n_devices=2, n_slots=2),
                           adapter_params={"a": merged})
    done = router.run(trace)
    assert len(done) == len(trace)
    for r in done:
        p = merged if r.adapter == "a" else params
        assert r.generated == _solo(cfg, p, r.tokens, 3), r.rid


def test_engine_revive_rejoins_ring(setup):
    """core engine: a crashed device revived with empty HBM re-enters the
    segment ring and the engine reaches fully_loaded again."""
    from repro.core.engine import PipeBoostEngine, generate
    import jax.numpy as jnp
    cfg, params = setup
    batch = {"tokens": jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)}
    eng = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    eng.load_round()
    eng.crash([1])
    eng.recover()
    eng.revive([1])
    assert eng.devices[1].alive and not eng.devices[1].loaded
    while eng.load_round():
        pass
    assert eng.fully_loaded and eng.ready
    out = generate(eng, batch, 4)
    ref_eng = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    ref_eng.load_round()
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(generate(ref_eng, batch, 4)))


def test_resubmission_matches_uninterrupted(setup):
    """The serving-engine re-submission hook alone (no router): drain a
    half-decoded request, resubmit it, outputs match the solo run."""
    from repro.serving.engine import ServeRequest, ServingEngine
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 250, size=8)
    srv = ServingEngine(cfg, params, n_slots=2, max_len=96)
    srv.batcher.sampler = quantized_greedy
    req = ServeRequest(0, prompt, max_new_tokens=6)
    srv.submit(req)
    srv.step()                      # prefill + 1 decode: 2 tokens
    srv.step()
    drained = srv.drain_inflight()
    assert drained == [req] and 1 < len(req.generated) < 6
    srv2 = ServingEngine(cfg, params, n_slots=2, max_len=96)
    srv2.batcher.sampler = quantized_greedy
    srv2.submit(req)
    srv2.run()
    assert req.done
    assert req.generated == _solo(cfg, params, prompt, 6)


def test_coldstart_metrics_and_same_tick_serving(setup):
    """Overlapped cold start at cluster level: time_to_ready stamps the
    moment a server can admit (NOT time_to_fully_loaded), the cold-start
    records ride the metrics JSON, and a ready flip serves the same tick."""
    cfg, params = setup
    trace = burst_wave_trace(8, base_rate=4.0, wave_rate=20.0, wave_at=0.2,
                             wave_len=0.5, seed=9, max_new_tokens=4)
    router = ClusterRouter(cfg, params, n_servers=1,
                           ccfg=ClusterConfig(n_devices=4, n_slots=2))
    done = router.run(trace)
    assert len(done) == len(trace)
    s = router.metrics.summary()
    # 4 devices: ready after round 1 (the very spawn tick: logical
    # time_to_ready 0), full only after 3 more background rounds —
    # scale-up latency is time-to-admittable, NOT time-to-fully-loaded
    assert 0 <= s["coldstart_time_to_ready_mean"] \
        < s["coldstart_time_to_fully_loaded_mean"]
    assert s["coldstart_n_servers"] == 1
    assert s["coldstart_loaded_bytes"] > 0
    kinds = [k for _, k, _ in router.metrics.events]
    assert "ready" in kinds
    doc = json.loads(router.metrics.to_json())
    rec = doc["coldstart"][0]
    assert rec["time_to_ready"] < rec["time_to_fully_loaded"]
    assert rec["n_rounds"] == 4 and rec["loaded_bytes"] == rec["total_bytes"]
    assert rec["wall_time_to_ready"] is not None
    srv0 = router.servers[0]
    assert srv0.ready_at is not None and srv0.fully_loaded_at is not None
    # the ready flip and the first serving step share a tick: the server
    # was stamped ready at some tick and srv.clock advanced that same tick
    assert srv0.ready_at <= srv0.fully_loaded_at
