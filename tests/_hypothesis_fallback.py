"""Deterministic stand-in for `hypothesis` when the real package is absent.

CI installs real hypothesis (see pyproject / .github/workflows/ci.yml) and
gets full shrinking property testing; this fallback keeps the suite
collectable and meaningfully exercised in minimal environments (e.g. the
bare container) by replaying a seeded random sample of each strategy space.

Only the API surface the tests use is implemented:
  given, settings, strategies.{integers, floats, booleans, sampled_from,
  lists}.  No shrinking, no database, no assume().

``install()`` registers the shim as ``hypothesis`` / ``hypothesis.strategies``
in ``sys.modules`` — tests/conftest.py calls it only when the real import
fails, so an installed hypothesis always wins.
"""
from __future__ import annotations

import inspect
import os
import random
import sys
import types
import zlib

#: examples per property in fallback mode (real hypothesis honours the
#: test's own max_examples).  Overridable for quick smoke runs.
FALLBACK_MAX_EXAMPLES = int(os.environ.get("REPRO_FALLBACK_EXAMPLES", "12"))


class _Strategy:
    def __init__(self, sample, describe):
        self.sample = sample            # rng -> value
        self.describe = describe

    def __repr__(self):
        return self.describe


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value),
                     f"integers({min_value}, {max_value})")


def floats(min_value, max_value):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                     f"floats({min_value}, {max_value})")


def booleans():
    return _Strategy(lambda rng: bool(rng.getrandbits(1)), "booleans()")


def sampled_from(elements):
    elems = list(elements)
    return _Strategy(lambda rng: elems[rng.randrange(len(elems))],
                     f"sampled_from({elems!r})")


def lists(elements, min_size=0, max_size=10):
    def sample(rng):
        n = rng.randint(min_size, max_size)
        return [elements.sample(rng) for _ in range(n)]
    return _Strategy(sample, f"lists({elements!r}, {min_size}, {max_size})")


def settings(max_examples=100, deadline=None, **_ignored):
    """Record max_examples on the decorated test (fallback caps it)."""
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(**strategy_kw):
    """Run the test over a seeded deterministic sample of the strategies.

    The seed derives from the test's qualified name, so every run (and every
    machine) replays the same examples; a failure reports the drawn values.
    """
    def deco(fn):
        def wrapper(*args, **kwargs):
            cap = getattr(wrapper, "_fallback_max_examples", 100)
            n = max(1, min(cap, FALLBACK_MAX_EXAMPLES))
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            rng = random.Random(seed)
            for i in range(n):
                drawn = {k: s.sample(rng) for k, s in strategy_kw.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (fallback draw {i}): {drawn!r}"
                    ) from e
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._fallback_max_examples = getattr(fn, "_fallback_max_examples",
                                                 100)
        # hide the strategy params from pytest's fixture resolution
        sig = inspect.signature(fn)
        keep = [p for name, p in sig.parameters.items()
                if name not in strategy_kw]
        wrapper.__signature__ = sig.replace(parameters=keep)
        return wrapper
    return deco


def install():
    """Register this shim as the ``hypothesis`` package in sys.modules."""
    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists"):
        setattr(st, name, globals()[name])
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.__fallback__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
    return hyp
