"""Distribution layer: sharding rules (pure metadata) + multi-device
numerical equivalence (subprocess with fake devices so the main test
process keeps seeing 1 CPU device, per the harness contract)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, get_arch
from repro.launch.mesh import data_axes, pipeline_stages_for


def test_pipeline_stage_counts():
    assert pipeline_stages_for(48) == 16
    assert pipeline_stages_for(28) == 4
    assert pipeline_stages_for(62) == 2
    assert pipeline_stages_for(40) == 8
    assert pipeline_stages_for(80) == 16
    assert pipeline_stages_for(24) == 8
    assert pipeline_stages_for(26) == 2
    assert pipeline_stages_for(32) == 16


def test_main_process_sees_one_device():
    # conftest/pyproject must NOT set the fake-device flag globally
    assert len(jax.devices()) == 1


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.configs.base import get_arch
    from repro.distributed import shardings as shd
    from repro.distributed.context import ShardingPolicy, use_policy
    from repro.models import transformer as T

    cfg = get_arch("qwen3-1.7b").reduced(n_layers=4, vocab_size=256)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key, jnp.float32)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, 256)}

    # single-device reference
    ref, _ = T.forward(cfg, params, batch, mode="train")

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    pol = ShardingPolicy(mesh, dp_axes=("data",), seq_axis="model")
    pspec = shd.param_specs(cfg, params, mesh, mode="fsdp")
    bspec = shd.batch_specs(cfg, batch, mesh, shard_seq=True)
    p_sh = jax.device_put(params, shd.named(mesh, pspec))
    b_sh = jax.device_put(batch, shd.named(mesh, bspec))

    def fwd(p, b):
        return T.forward(cfg, p, b, mode="train")[0]

    with use_policy(pol):
        out = jax.jit(fwd,
                      in_shardings=(shd.named(mesh, pspec),
                                    shd.named(mesh, bspec)))(p_sh, b_sh)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 2e-4, err

    # pipeline lowerings == plain forward (prefill logits)
    from repro.distributed.pipeline import (build_pipeline_prefill,
                                            build_pipeline_prefill_seqchunk)
    pmesh = jax.make_mesh((2, 4), ("data", "stage"))
    f = build_pipeline_prefill(cfg, n_stages=4, n_micro=2, mesh=pmesh,
                               seq_len=32)
    lg_pipe = f(params, batch)
    lg_ref, _ = T.forward(cfg, params, batch, mode="prefill", max_len=32)
    err2 = float(jnp.max(jnp.abs(lg_pipe - lg_ref)))
    assert err2 < 2e-3, err2
    # TeraPipe-style sequence-chunk belt (the §Perf hillclimb variant)
    f2 = build_pipeline_prefill_seqchunk(cfg, n_stages=4, n_chunks=8,
                                         mesh=pmesh, seq_len=32)
    lg_sc = f2(params, batch)
    err3 = float(jnp.max(jnp.abs(lg_sc - lg_ref)))
    assert err3 < 2e-3, err3
    print("OK", err, err2, err3)
""")


@pytest.mark.slow
def test_sharded_equals_single_device_and_pipeline():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_param_specs_divisible():
    """Every sharded dim must divide by its axis product (all archs)."""
    from repro.distributed import shardings as shd
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    from repro.configs.base import ARCH_IDS
    for arch in ARCH_IDS:
        cfg = get_arch(arch)
        struct = jax.eval_shape(
            lambda: __import__("repro.models.transformer",
                               fromlist=["x"]).init_params(
                cfg, jax.random.PRNGKey(0), jnp.bfloat16))
        specs = shd.param_specs(cfg, struct, FakeMesh(), mode="fsdp")

        def check(leaf, spec):
            for d, s in enumerate(spec):
                if s is None:
                    continue
                names = s if isinstance(s, tuple) else (s,)
                n = 1
                for a in names:
                    n *= FakeMesh.shape[a]
                assert leaf.shape[d] % n == 0, (arch, leaf.shape, spec)

        jax.tree.map(check, struct, specs,
                     is_leaf=lambda x: hasattr(x, "shape"))


_ELASTIC = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.configs.base import get_arch
    from repro.distributed import shardings as shd
    from repro.training.checkpoint import Checkpointer
    from repro.training.data import SyntheticLM
    from repro.training.optimizer import AdamWConfig
    from repro.training.train import init_train_state, make_train_step

    cfg = get_arch("qwen3-1.7b").reduced(n_layers=2, vocab_size=256)
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step = jax.jit(make_train_step(cfg, opt, remat=False))
    ds = SyntheticLM(vocab_size=256, seq_len=16, batch_size=8, seed=2)

    def run(state, n):
        for _ in range(n):
            b = ds.next_batch()
            state, _ = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        return state

    # train 2 steps on an 8-device mesh (FSDP-sharded state)
    devs = jax.devices()
    mesh8 = jax.make_mesh((4, 2), ("data", "model"), devices=devs[:8])
    state = init_train_state(cfg, jax.random.PRNGKey(0), jnp.float32)
    spec8 = shd.param_specs(cfg, state.params, mesh8, mode="fsdp")
    sspec8 = type(state)(spec8, type(state.opt)(
        __import__("jax").sharding.PartitionSpec(), spec8, spec8))
    state = jax.device_put(state, shd.named(mesh8, sspec8))
    state = run(state, 2)

    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(2, state, extra={"data": ds.state()})
        # ELASTIC RESTART: half the devices died -> new 4-device mesh
        mesh4 = jax.make_mesh((2, 2), ("data", "model"), devices=devs[:4])
        tmpl = init_train_state(cfg, jax.random.PRNGKey(0), jnp.float32)
        spec4 = shd.param_specs(cfg, tmpl.params, mesh4, mode="fsdp")
        sspec4 = type(tmpl)(spec4, type(tmpl.opt)(
            __import__("jax").sharding.PartitionSpec(), spec4, spec4))
        st2, extra = ck.restore(tmpl, shardings=shd.named(mesh4, sspec4))
    # values identical across meshes
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(st2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0)
    # and training continues on the survivor mesh
    ds2 = SyntheticLM(vocab_size=256, seq_len=16, batch_size=8, seed=2)
    ds2.restore(extra["data"])
    b = ds2.next_batch()
    st3, m = step(st2, {k: jnp.asarray(v) for k, v in b.items()})
    assert np.isfinite(float(m["loss"]))
    print("ELASTIC_OK")
""")


@pytest.mark.slow
def test_elastic_restart_onto_smaller_mesh():
    """Checkpoint on an 8-device mesh, restore + continue on 4 devices —
    the mesh-agnostic checkpointing claim (DESIGN.md §2 elasticity)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _ELASTIC], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "ELASTIC_OK" in r.stdout
