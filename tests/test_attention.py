"""Property tests for the blocked jnp attention (the XLA-lowered path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import (attention, attention_partial,
                                    attention_reference, decode_attention,
                                    finalize_partial, merge_partials)

KEY = jax.random.PRNGKey(3)


@settings(max_examples=30, deadline=None)
@given(
    b=st.integers(1, 3),
    sq=st.integers(1, 65),
    hkv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 3]),
    causal=st.booleans(),
    window=st.sampled_from([0, 5, 16]),
    block_k=st.sampled_from([7, 16, 64]),
)
def test_blocked_equals_reference(b, sq, hkv, g, causal, window, block_k):
    hd = 8
    q = jax.random.normal(jax.random.fold_in(KEY, sq * 7 + b),
                          (b, sq, hkv * g, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, sq * 13 + b),
                          (b, sq, hkv, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, sq * 17 + b),
                          (b, sq, hkv, hd))
    o1 = attention(q, k, v, causal=causal, window=window, block_k=block_k)
    o2 = attention_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=3e-5, rtol=3e-5)


@settings(max_examples=20, deadline=None)
@given(split=st.integers(1, 63), seed=st.integers(0, 100))
def test_partial_merge_associativity(split, seed):
    """Splitting the KV set anywhere and merging partials must equal
    attention over the full set — the invariant ring attention and
    sequence-parallel decode rely on."""
    B, S, H, hd = 1, 64, 2, 8
    q = jax.random.normal(jax.random.fold_in(KEY, seed), (B, 4, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, seed + 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, seed + 2), (B, S, H, hd))
    pa = attention_partial(q, k[:, :split], v[:, :split], causal=False,
                           k_offset=0)
    pb = attention_partial(q, k[:, split:], v[:, split:], causal=False,
                           k_offset=split)
    merged = finalize_partial(merge_partials(pa, pb), q.dtype)
    full = attention_reference(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full),
                               atol=3e-5, rtol=3e-5)


def test_decode_per_slot_valid_lengths():
    """Continuous batching: each slot's attention must respect its own
    cache length."""
    B, C, H, hd = 4, 32, 2, 8
    q = jax.random.normal(KEY, (B, 1, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, C, H, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, C, H, hd))
    lens = jnp.asarray([1, 7, 20, 32], jnp.int32)
    o = decode_attention(q, k, v, lens)
    for i, ln in enumerate(lens):
        oi = decode_attention(q[i:i + 1], k[i:i + 1, :int(ln)],
                              v[i:i + 1, :int(ln)],
                              jnp.asarray(int(ln), jnp.int32))
        np.testing.assert_allclose(np.asarray(o[i]), np.asarray(oi[0]),
                                   atol=3e-5, rtol=3e-5)


def test_q_offset_chunked_prefill():
    """Chunked prefill: attention of a later q chunk with q_offset equals
    the same rows of full attention (Sarathi-style chunked prefill)."""
    B, S, H, hd = 1, 48, 2, 8
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (B, S, H, hd))
    full = attention_reference(q, k, v, causal=True)
    off = 16
    part = attention(q[:, off:], k, v, causal=True, q_offset=off)
    np.testing.assert_allclose(np.asarray(part), np.asarray(full[:, off:]),
                               atol=3e-5, rtol=3e-5)
