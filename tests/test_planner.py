"""Planner unit + property tests (paper §4.2/§4.4 invariants)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.planner import (critical_path_bytes, make_plan,
                                make_segments, reassign, rotated_load_order,
                                viable_chain)


def test_rotated_order_paper_example():
    order = rotated_load_order(4)
    assert order == {0: [0, 1, 2, 3], 1: [1, 2, 3, 0],
                     2: [2, 3, 0, 1], 3: [3, 0, 1, 2]}


def test_first_loads_cover_model():
    for n in (2, 3, 4, 8, 16):
        order = rotated_load_order(n)
        firsts = {order[d][0] for d in range(n)}
        assert firsts == set(range(n))


def test_make_segments_partition():
    lb = [10, 20, 30, 40, 50, 60, 70, 80]
    segs = make_segments(lb, 4)
    assert segs[0].layer_start == 0 and segs[-1].layer_end == len(lb)
    for a, b in zip(segs, segs[1:]):
        assert a.layer_end == b.layer_start
    assert sum(s.bytes for s in segs) == sum(lb)


def test_reassign_paper_fig7a():
    """4 GPUs, GPUs 1&2 crash during loading (paper Fig. 7a)."""
    plan = make_plan([100] * 8, 4)
    newp = reassign(plan, {0: [0], 3: [3]}, [0, 3])
    assert newp.serve_assignment == {0: [0, 1], 3: [2, 3]}
    # device 0 continues 1,...; device 3 loads 2 next (it already has 3)
    assert newp.order[0][0] == 1
    assert newp.order[3][0] == 2


def test_viable_chain_prefers_contiguity():
    plan = make_plan([100] * 4, 4)
    loaded = {0: [0, 1, 2, 3], 1: [1]}
    chain = viable_chain(plan, loaded, [0, 1])
    assert chain == [(0, 0), (0, 1), (0, 2), (0, 3)]  # no hops needed


def test_viable_chain_none_when_missing():
    plan = make_plan([100] * 4, 4)
    assert viable_chain(plan, {0: [0, 1], 1: [3]}, [0, 1]) is None


def test_critical_path_is_1_over_n():
    lb = [100] * 16
    plan = make_plan(lb, 4)
    cp = critical_path_bytes(plan)
    assert all(v == sum(lb) // 4 for v in cp.values())


# ---------------------------------------------------------------------------
# Properties
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(
    n_layers=st.integers(8, 64),
    n_devices=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_reassign_completes(n_layers, n_devices, seed):
    """For ANY loading progress and ANY non-empty survivor set, the re-plan
    covers every segment, spans are contiguous and balanced, and finishing
    the new orders yields a viable chain."""
    import random
    rng = random.Random(seed)
    lb = [rng.randint(1, 1000) for _ in range(n_layers)]
    plan = make_plan(lb, n_devices)
    n_seg = len(plan.segments)
    # random progress along each device's rotated order
    loaded = {d: plan.order[d][:rng.randint(0, n_seg)]
              for d in range(n_devices)}
    survivors = sorted(rng.sample(range(n_devices),
                                  rng.randint(1, n_devices)))
    newp = reassign(plan, loaded, survivors)

    # spans partition 0..n_seg-1 contiguously
    all_segs = [s for d in survivors for s in newp.serve_assignment[d]]
    assert sorted(all_segs) == list(range(n_seg))
    sizes = [len(newp.serve_assignment[d]) for d in survivors]
    assert max(sizes) - min(sizes) <= 1          # Load Balance
    for d in survivors:
        span = newp.serve_assignment[d]
        assert span == list(range(span[0], span[-1] + 1))  # Layer Contiguity

    # each survivor's order contains exactly its missing segments
    for d in survivors:
        have = set(loaded.get(d, ()))
        assert sorted(newp.order[d] + sorted(have)) == list(range(n_seg))

    # simulate finishing the span loads -> chain must exist
    done = {d: set(loaded.get(d, ())) for d in survivors}
    for d in survivors:
        for s in newp.serve_assignment[d]:
            done[d].add(s)
    chain = viable_chain(newp, {d: sorted(v) for d, v in done.items()},
                         survivors)
    assert chain is not None
    assert [s for _, s in chain] == list(range(n_seg))
    for dev, seg in chain:
        assert seg in done[dev]


@settings(max_examples=100, deadline=None)
@given(
    n_layers=st.integers(4, 80),
    n_segments=st.integers(2, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_segments_balanced(n_layers, n_segments, seed):
    import random
    if n_layers < n_segments:
        return
    rng = random.Random(seed)
    lb = [rng.randint(1, 1000) for _ in range(n_layers)]
    segs = make_segments(lb, n_segments)
    assert len(segs) == n_segments
    assert all(s.n_layers >= 1 for s in segs)
    assert sum(s.bytes for s in segs) == sum(lb)
    # balance: every segment within (total/n) +/- max single layer
    target = sum(lb) / n_segments
    assert max(s.bytes for s in segs) <= target + max(lb)
