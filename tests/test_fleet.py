"""Fleet scheduler layer: pluggable dispatch/placement policies, injected
clocks, multi-model pools, and the Azure trace ingestion.

Load-bearing invariants:
* ``LeastLoaded`` reproduces the pre-refactor routing decision (min
  (load, sid) over admitting servers with capacity) — the behavioral
  regression gate for the extraction.
* Dispatch policy choice NEVER changes tokens — every policy serves the
  exact greedy outputs of a solo run (scheduling moves requests, the
  model math is untouched).
* ``WallClock`` and ``LogicalClock`` drive the SAME router/autoscaler
  code: the clock is injected, not branched on.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.cluster import (AdapterAffine, Arrival, Autoscaler,
                           AutoscalerConfig, ClusterConfig, ClusterRouter,
                           Fleet, HotAdapterPlacement, LeastLoaded,
                           LogicalClock, PoolSpec, PreloadAll, SloAware,
                           WallClock, burst_wave_trace, load_azure_trace,
                           load_trace, make_dispatch, merge_traces,
                           poisson_trace, save_trace)
from repro.cluster.scheduler import DISPATCH_POLICIES
from repro.configs.base import get_arch
from repro.models import transformer as T
from repro.serving.engine import ServeRequest, quantized_greedy

KEY = jax.random.PRNGKey(3)
FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=4)
    params = T.init_params(cfg, KEY)
    return cfg, params


def _solo(cfg, params, prompt, n):
    import jax.numpy as jnp
    lg, cache = T.forward(cfg, params, {"tokens": jnp.asarray(prompt)[None]},
                          mode="prefill", max_len=96)
    toks = [int(quantized_greedy(lg)[0])]
    for _ in range(n - 1):
        lg, cache = T.decode_step(
            cfg, params, {"tokens": jnp.asarray([toks[-1]], jnp.int32)},
            cache)
        toks.append(int(quantized_greedy(lg)[0]))
    return toks


# ---------------------------------------------------------------------------
# clocks
# ---------------------------------------------------------------------------

def test_logical_clock_ticks():
    c = LogicalClock()
    assert c.now() == 0.0
    c.advance(0.05)
    c.advance(0.05)
    assert c.now() == pytest.approx(0.1)


def test_wall_clock_monotonic_and_advance_noop():
    c = WallClock()
    t0 = c.now()
    c.advance(100.0)            # no-op: wall time flows on its own
    t1 = c.now()
    assert 0 <= t0 <= t1 < 10.0


# ---------------------------------------------------------------------------
# dispatch policies (pure, on fakes — no JAX)
# ---------------------------------------------------------------------------

class _FakeBatcher:
    def __init__(self, active=(), n_free=4):
        self.active = {i: r for i, r in enumerate(active)}
        self.free = list(range(len(self.active), len(self.active) + n_free))


class _FakeSrvEngine:
    """ServingEngine scheduling surface only."""

    def __init__(self, active=(), n_free=4, active_adapter=None,
                 adapter_params=(), queued=()):
        self.batcher = _FakeBatcher(active, n_free)
        self.active_adapter = active_adapter
        self.adapter_params = {a: None for a in adapter_params}
        self._queued = list(queued)

    def resident_adapters(self):
        if self.batcher.active:
            return {self.active_adapter}
        return set(self.adapter_params) | {None, self.active_adapter}

    def predicted_step_cost_s(self, default=0.05):
        return default

    def queued_requests(self):
        return self._queued


class _FakeServer:
    def __init__(self, sid, state="serving", srv=None, ready_s=0.0):
        self.sid = sid
        self.state = state
        self.srv = srv or _FakeSrvEngine()
        self._ready_s = ready_s

    @property
    def admitting(self):
        return self.state == "serving"

    @property
    def load(self):
        return len(self.srv.batcher.active) + len(self.srv.queued_requests())

    def can_serve(self, req):
        return req.adapter is None or req.adapter in self.srv.adapter_params

    def predicted_ready_s(self, now):
        return 0.0 if self.state == "serving" else self._ready_s


def _req(rid, adapter=None, deadline=None, max_new=8, n_gen=0):
    r = ServeRequest(rid, np.zeros(4, np.int64), max_new_tokens=max_new,
                     adapter=adapter, deadline=deadline)
    r.generated = [0] * n_gen
    return r


CCFG = ClusterConfig(n_slots=4)


def test_least_loaded_reproduces_pre_refactor_choice():
    """Regression gate: identical selection to the old inline loop —
    FIFO request, min (load, sid) over admitting servers with capacity."""
    servers = [
        _FakeServer(0, srv=_FakeSrvEngine(active=[_req(10), _req(11)])),
        _FakeServer(1, srv=_FakeSrvEngine(active=[_req(12)])),
        _FakeServer(2, state="loading"),
        _FakeServer(3, srv=_FakeSrvEngine(active=[_req(13)])),
        _FakeServer(4, srv=_FakeSrvEngine(                 # full: no capacity
            active=[_req(14), _req(15), _req(16), _req(17)], n_free=0)),
    ]
    queue = [_req(0), _req(1)]
    # pre-refactor logic, verbatim
    cands = [s for s in servers if s.admitting and s.load < CCFG.n_slots]
    expected = min(cands, key=lambda s: (s.load, s.sid))
    idx, got = LeastLoaded().select(queue, servers, 0.0, CCFG)
    assert (idx, got.sid) == (0, expected.sid) == (0, 1)
    # nothing admitting with capacity -> None (queue waits)
    idx_none = LeastLoaded().select(queue, [servers[2], servers[4]], 0.0,
                                    CCFG)
    assert idx_none is None


def test_dispatch_skips_unservable_head_of_line():
    """A request whose adapter no current server preloads must not block
    the queue: both policies skip it (it keeps feeding the autoscaler)
    and dispatch the next servable request."""
    servers = [_FakeServer(0, srv=_FakeSrvEngine(adapter_params=("a",)))]
    queue = [_req(0, adapter="ghost"), _req(1, adapter="a")]
    for pol in (LeastLoaded(), SloAware(step_cost_s=0.05)):
        idx, s = pol.select(queue, servers, 0.0, CCFG)
        assert (idx, s.sid) == (1, 0), type(pol).__name__
    # out of capacity entirely -> None, regardless of the queue
    full = _FakeServer(0, srv=_FakeSrvEngine(
        active=[_req(9), _req(10), _req(11), _req(12)], n_free=0,
        adapter_params=("a",)))
    assert LeastLoaded().select(queue, [full], 0.0, CCFG) is None


def test_slo_aware_deadline_priority():
    servers = [_FakeServer(0)]
    queue = [_req(0, deadline=None), _req(1, deadline=9.0),
             _req(2, deadline=2.0)]
    idx, s = SloAware(step_cost_s=0.05).select(queue, servers, 0.0, CCFG)
    assert idx == 2 and s.sid == 0          # earliest deadline first
    # equal deadlines: FIFO among equals
    queue = [_req(0, deadline=2.0), _req(1, deadline=2.0)]
    idx, _ = SloAware(step_cost_s=0.05).select(queue, servers, 0.0, CCFG)
    assert idx == 0


def test_slo_aware_avoids_epoch_drain_stall():
    """A busy-on-another-adapter server predicts a full drain before the
    request can admit; the emptier-looking server is the WRONG pick."""
    long_b = _req(10, adapter="b", max_new=30, n_gen=2)     # 28 tokens left
    busy = _FakeServer(0, srv=_FakeSrvEngine(
        active=[long_b], active_adapter="b", adapter_params=("a", "b")))
    idle = _FakeServer(1, srv=_FakeSrvEngine(
        active=[_req(11, adapter="a", max_new=4, n_gen=2)],
        active_adapter="a", adapter_params=("a", "b")))
    idle.srv._queued = [_req(12, adapter="a")]  # MORE loaded than `busy`
    pol = SloAware(step_cost_s=0.05)
    req = _req(0, adapter="a")
    assert busy.load < idle.load            # least-loaded would pick busy
    _, ll = LeastLoaded().select([req], [busy, idle], 0.0, CCFG)
    assert ll.sid == 0
    _, sa = pol.select([req], [busy, idle], 0.0, CCFG)
    assert sa.sid == 1                      # SLO-aware prices the drain
    t_busy = pol.predicted_first_token_s(busy, req, 0.0, CCFG)
    t_idle = pol.predicted_first_token_s(idle, req, 0.0, CCFG)
    assert t_busy > t_idle > 0


def test_slo_aware_scores_warming_servers():
    """Mid-burst, a server one load-round from viable can beat queueing
    behind a deep epoch on a serving one (cold-start progress term)."""
    long_b = _req(10, adapter="b", max_new=40, n_gen=0)
    busy = _FakeServer(0, srv=_FakeSrvEngine(active=[long_b],
                                             active_adapter="b",
                                             adapter_params=("b",)))
    warming = _FakeServer(1, state="loading", ready_s=0.1)
    _, s = SloAware(step_cost_s=0.05).select([_req(0)], [busy, warming],
                                             0.0, CCFG)
    assert s.sid == 1
    # with warming excluded, the busy server is the only candidate
    _, s = SloAware(step_cost_s=0.05, consider_warming=False).select(
        [_req(0)], [busy, warming], 0.0, CCFG)
    assert s.sid == 0


def test_adapter_affine_prefers_resident_adapter():
    a_srv = _FakeServer(0, srv=_FakeSrvEngine(
        active=[_req(10, adapter="a", max_new=6, n_gen=2)],
        active_adapter="a", adapter_params=("a", "b")))
    b_srv = _FakeServer(1, srv=_FakeSrvEngine(active_adapter="b",
                                              adapter_params=("a", "b")))
    pol = AdapterAffine(slo=SloAware(step_cost_s=0.05))
    _, s = pol.select([_req(0, adapter="a")], [a_srv, b_srv], 0.0, CCFG)
    assert s.sid == 0                       # affinity beats lower load
    # no affine server -> falls back to SLO-aware scoring
    _, s = pol.select([_req(1, adapter="b")], [a_srv], 0.0, CCFG)
    assert s.sid == 0


def test_dispatch_registry():
    for name in ("least_loaded", "slo_aware", "adapter_affine"):
        assert type(make_dispatch(name)) is DISPATCH_POLICIES[name]
    with pytest.raises(ValueError, match="unknown dispatch"):
        make_dispatch("ghost")


# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------

def test_placement_policies():
    all_a = {f"l{i}": object() for i in range(6)}
    assert PreloadAll().adapters_for(all_a, ["l0"]) == all_a
    hot = HotAdapterPlacement(k=2)
    recent = ["l1", "l2", "l1", "l3", "l3", "l3", "ghost"]
    picked = hot.adapters_for(all_a, recent)
    assert set(picked) == {"l3", "l1"}      # by count, unknown names ignored
    assert hot.adapters_for(all_a, []) == all_a   # no history: preload all


def test_hot_placement_limits_spawned_server_adapters(setup):
    """A scale-up under HotAdapterPlacement preloads only the hot set;
    requests for missing adapters never dispatch to it (can_serve)."""
    from repro.lora.adapters import init_lora, merge_lora, randomize_lora
    cfg, params = setup
    aps = {}
    for i in range(3):
        lora = randomize_lora(jax.random.fold_in(KEY, i),
                              init_lora(KEY, cfg, rank=4))
        aps[f"l{i}"] = merge_lora(params, lora)
    router = ClusterRouter(cfg, params, n_servers=1,
                           ccfg=ClusterConfig(n_devices=2, n_slots=2),
                           adapter_params=aps,
                           placement=HotAdapterPlacement(k=1))
    # seed server spawned with no history -> preloads everything
    assert set(router.servers[0].srv.adapter_params) == set(aps)
    for t in (0.0, 0.01, 0.02):
        router.submit(Arrival(t, adapter="l2"))
    s = router.spawn_server()
    assert set(s.srv.adapter_params) == {"l2"}
    assert s.can_serve(_req(0, adapter="l2"))
    assert not s.can_serve(_req(1, adapter="l0"))
    assert s.can_serve(_req(2, adapter=None))


def test_starved_request_surfaces_and_run_terminates(setup):
    """Liveness: when no provisioned server preloads a request's adapter
    (and none ever could), the router flags it (`unservable` event),
    serves everything servable, and run() gives up with a `starved`
    event instead of spinning to max_ticks."""
    from repro.lora.adapters import init_lora, merge_lora, randomize_lora
    cfg, params = setup
    aps = {}
    for i, name in enumerate(("a", "b")):
        lora = randomize_lora(jax.random.fold_in(KEY, 20 + i),
                              init_lora(KEY, cfg, rank=4))
        aps[name] = merge_lora(params, lora)
    router = ClusterRouter(cfg, params, n_servers=1,
                           ccfg=ClusterConfig(n_devices=2, n_slots=2),
                           adapter_params=aps,
                           placement=HotAdapterPlacement(k=1))
    trace = [Arrival(0.0, adapter="a", max_new_tokens=2),
             Arrival(0.01, adapter="b", max_new_tokens=2)]
    router._recent_adapters.append("a")
    router.spawn_server()                 # hot-set replacement: only "a"
    router.servers[0].retire()            # ...and the full seed retires
    done = router.run(trace)
    assert len(done) == 1 and done[0].adapter == "a"   # servable part ran
    kinds = [k for _, k, _ in router.metrics.events]
    assert "unservable" in kinds and "starved" in kinds
    # flagged exactly once despite hundreds of dispatch passes
    assert sum(1 for k in kinds if k == "unservable") == 1


# ---------------------------------------------------------------------------
# autoscaler edge cases
# ---------------------------------------------------------------------------

class _ScaleSrv:
    def __init__(self, sid, state="serving", idle_ticks=0):
        self.sid, self.state, self.idle_ticks = sid, state, idle_ticks

    @property
    def admitting(self):
        return self.state == "serving"


def test_autoscaler_cooldown_suppresses_back_to_back_spawns():
    cfg = AutoscalerConfig(target_queue_per_server=1.0, max_servers=8,
                           scale_up_cooldown_ticks=3, max_warming=8)
    sc = Autoscaler(cfg)
    servers = [_ScaleSrv(0)]
    d0 = sc.decide(0.0, pending=50, oldest_wait=0.0, servers=servers)
    assert d0.spawn == 1
    for tick in range(1, 3):                # still pressured, still cooling
        d = sc.decide(tick * 0.05, 50, 0.0, servers)
        assert d.spawn == 0, tick
    d3 = sc.decide(0.15, 50, 0.0, servers)  # cooldown expired
    assert d3.spawn == 1 and sc.n_scale_ups == 2


def test_autoscaler_max_warming_with_loading_server():
    cfg = AutoscalerConfig(target_queue_per_server=1.0, max_servers=8,
                           scale_up_cooldown_ticks=0, max_warming=1)
    sc = Autoscaler(cfg)
    servers = [_ScaleSrv(0), _ScaleSrv(1, state="loading")]
    d = sc.decide(0.0, pending=50, oldest_wait=9.0, servers=servers)
    assert d.spawn == 0                     # one cold start already in flight
    servers[1].state = "serving"
    d = sc.decide(0.05, pending=50, oldest_wait=9.0, servers=servers)
    assert d.spawn == 1


def test_autoscaler_retire_respects_min_servers():
    cfg = AutoscalerConfig(min_servers=2, idle_ticks_before_retire=10)
    sc = Autoscaler(cfg)
    servers = [_ScaleSrv(i, idle_ticks=99) for i in range(4)]
    d = sc.decide(0.0, pending=0, oldest_wait=0.0, servers=servers)
    # 4 idle candidates but the floor is 2: retire exactly 2, never more
    assert len(d.retire) == 2
    assert sc.n_retires == 2


def test_scale_decision_lists_are_independent():
    """The old ``retire: List = None`` + __post_init__ pattern is gone;
    default instances must not share one list."""
    from repro.cluster.autoscaler import ScaleDecision
    import dataclasses
    a, b = ScaleDecision(), ScaleDecision()
    a.retire.append(7)
    assert b.retire == []
    f = {x.name: x for x in dataclasses.fields(ScaleDecision)}["retire"]
    assert f.default is dataclasses.MISSING  # default_factory, not None


# ---------------------------------------------------------------------------
# traces: model/deadline threading, adapter_prob, azure ingestion
# ---------------------------------------------------------------------------

def test_trace_model_deadline_roundtrip(tmp_path):
    tr = poisson_trace(8.0, 1.0, seed=3, model="chat", ttft_deadline_s=0.4,
                       adapters=("x",), adapter_prob=1.0)
    assert tr and all(a.model == "chat" and a.ttft_deadline_s == 0.4
                      and a.adapter == "x" for a in tr)
    path = str(tmp_path / "t.json")
    save_trace(path, tr)
    assert load_trace(path) == tr


def test_adapter_prob_parameter():
    always = poisson_trace(20.0, 2.0, seed=0, adapters=("x",),
                           adapter_prob=1.0)
    never = poisson_trace(20.0, 2.0, seed=0, adapters=("x",),
                          adapter_prob=0.0)
    assert all(a.adapter == "x" for a in always)
    assert all(a.adapter is None for a in never)
    half = poisson_trace(20.0, 4.0, seed=0, adapters=("x",))
    frac = sum(1 for a in half if a.adapter) / len(half)
    assert 0.25 < frac < 0.75               # default stays ~0.5


def test_merge_traces_sorted_and_stable():
    a = poisson_trace(5.0, 2.0, seed=1, model="a")
    b = poisson_trace(5.0, 2.0, seed=2, model="b")
    m = merge_traces(a, b)
    assert len(m) == len(a) + len(b)
    assert [x.time for x in m] == sorted(x.time for x in m)


def test_load_azure_trace_fixture():
    path = os.path.join(FIXTURES, "azure_sample.csv")
    tr = load_azure_trace(path, models=("m0", "m1"), adapters=("x", None),
                          seed=0)
    # integer counts + rate_scale=1 -> arrival count == sum of the CSV
    assert len(tr) == 42
    assert tr == sorted(tr, key=lambda a: a.time)
    assert all(0 <= a.time < 5 * 60.0 for a in tr)
    # per-function -> (model, adapter) mapping is deterministic and
    # consistent: every (model, adapter) pair observed is a valid
    # round-robin cell and both models appear
    pairs = {(a.model, a.adapter) for a in tr}
    assert pairs <= {("m0", "x"), ("m1", None)}
    assert {m for m, _ in pairs} == {"m0", "m1"}
    assert load_azure_trace(path, models=("m0", "m1"),
                            adapters=("x", None), seed=0) == tr
    # time compression + scaling + truncation
    fast = load_azure_trace(path, minute_s=1.0, seed=0)
    assert all(a.time < 5.0 for a in fast)
    assert len(load_azure_trace(path, rate_scale=0.25, seed=0)) < 42
    assert len(load_azure_trace(path, max_requests=5, seed=0)) == 5


def test_load_azure_trace_honors_minute_gaps(tmp_path):
    """Minute columns are 1-based day minutes: a trimmed CSV with a gap
    keeps each count in ITS minute, not squeezed onto the header index."""
    p = tmp_path / "gap.csv"
    p.write_text("HashOwner,HashApp,HashFunction,Trigger,1,3\n"
                 "o,a,f,http,2,3\n")
    tr = load_azure_trace(str(p), seed=0)
    assert len(tr) == 5
    assert sum(1 for a in tr if 0 <= a.time < 60) == 2
    assert sum(1 for a in tr if 120 <= a.time < 180) == 3


def test_load_azure_trace_rejects_wrong_shape(tmp_path):
    bad = tmp_path / "bad.csv"
    bad.write_text("name,value\nf1,2\n")
    with pytest.raises(ValueError, match="per-minute"):
        load_azure_trace(str(bad))


def test_azure_trace_replays_through_router(setup):
    """The ingested trace drives the real cluster end to end."""
    cfg, params = setup
    tr = load_azure_trace(os.path.join(FIXTURES, "azure_sample.csv"),
                          minute_s=0.4, max_new_tokens=3, max_requests=12,
                          seed=0)
    router = ClusterRouter(cfg, params, n_servers=2,
                           ccfg=ClusterConfig(n_devices=2, n_slots=4))
    done = router.run(tr)
    assert len(done) == len(tr) == 12


# ---------------------------------------------------------------------------
# snapshot transfer cost model
# ---------------------------------------------------------------------------

def test_snapshot_transfer_cost_model(setup):
    from repro.core.simulator import (GPU_PAPER, kv_snapshot_bytes,
                                      snapshot_transfer_time)
    cfg, _ = setup
    b16 = kv_snapshot_bytes(cfg, 16, 96)
    b64 = kv_snapshot_bytes(cfg, 64, 96)
    assert 0 < b16 < b64                    # KV grows with position
    assert kv_snapshot_bytes(cfg, 500, 96) == kv_snapshot_bytes(cfg, 96, 96)
    t_nv = snapshot_transfer_time(b64, GPU_PAPER, "nvlink")
    t_pc = snapshot_transfer_time(b64, GPU_PAPER, "pcie")
    assert 0 < t_nv < t_pc                  # PCIe-class link is slower
    with pytest.raises(ValueError, match="unknown link"):
        snapshot_transfer_time(b64, GPU_PAPER, "carrier-pigeon")
    # SSM states are position-independent
    ssm = get_arch("mamba2-780m").reduced(n_layers=2)
    assert kv_snapshot_bytes(ssm, 8, 96) == kv_snapshot_bytes(ssm, 64, 96)
    # windowed attention: the ring holds at most attn_window rows, so the
    # payload stops growing at the window (not max_len)
    win = get_arch("recurrentgemma-2b").reduced(n_layers=4)
    assert win.attn_window > 0
    w = win.attn_window
    assert kv_snapshot_bytes(win, w // 2, 96) \
        < kv_snapshot_bytes(win, w, 96) \
        == kv_snapshot_bytes(win, w + 20, 96)


def test_snapshot_bytes_matches_real_export_order(setup):
    """The modeled payload is the true-window lower bound of the
    in-memory snapshot (which carries full max_len rows)."""
    from repro.core.simulator import kv_snapshot_bytes
    from repro.serving.engine import ServingEngine
    cfg, params = setup
    srv = ServingEngine(cfg, params, n_slots=2, max_len=96)
    srv.batcher.sampler = quantized_greedy
    req = ServeRequest(0, np.arange(8, dtype=np.int64) + 3,
                       max_new_tokens=6)
    srv.submit(req)
    srv.step()
    snap = srv.batcher.export_snapshot(req.slot)
    modeled = kv_snapshot_bytes(cfg, snap.pos, 96)
    assert 0 < modeled <= snap.nbytes()


# ---------------------------------------------------------------------------
# engine hooks
# ---------------------------------------------------------------------------

def test_rounds_to_ready_progression(setup):
    from repro.core.engine import PipeBoostEngine
    cfg, params = setup
    eng = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    r0 = eng.rounds_to_ready()
    assert r0 >= 1 and not eng.ready
    eng.load_round()
    assert eng.rounds_to_ready() == 0 and eng.ready
    eng.crash([d.idx for d in eng.devices])
    assert eng.rounds_to_ready() >= 1 << 20   # nothing alive: never ready


def test_resident_adapters_and_step_cost(setup):
    from repro.lora.adapters import init_lora, merge_lora, randomize_lora
    from repro.serving.engine import ServingEngine
    cfg, params = setup
    merged = merge_lora(params, randomize_lora(
        KEY, init_lora(KEY, cfg, rank=4)))
    srv = ServingEngine(cfg, params, n_slots=2, max_len=96,
                        adapter_params={"a": merged})
    assert srv.predicted_step_cost_s(default=0.123) == 0.123  # no steps yet
    assert srv.resident_adapters() == {"a", None}   # idle: all switchable
    srv.submit(ServeRequest(0, np.arange(6, dtype=np.int64),
                            max_new_tokens=8, adapter="a"))
    srv.step()
    assert srv.resident_adapters() == {"a"}         # busy: epoch pinned
    srv.step()
    assert srv.predicted_step_cost_s() > 0


# ---------------------------------------------------------------------------
# router integration: policies + clocks end to end
# ---------------------------------------------------------------------------

def test_slo_aware_router_tokens_exact(setup):
    """Dispatch policy choice changes WHERE requests run, never WHAT they
    produce: SLO-aware routing (including dispatch to warming servers)
    stays token-exact against the solo reference."""
    from repro.lora.adapters import init_lora, merge_lora, randomize_lora
    cfg, params = setup
    merged = merge_lora(params, randomize_lora(
        jax.random.fold_in(KEY, 9), init_lora(KEY, cfg, rank=4)))
    trace = burst_wave_trace(12, base_rate=3.0, wave_rate=24.0, wave_at=0.3,
                             wave_len=0.5, seed=5, max_new_tokens=4,
                             adapters=("a",), ttft_deadline_s=0.5)
    router = ClusterRouter(cfg, params, n_servers=2,
                           ccfg=ClusterConfig(n_devices=4, n_slots=2),
                           adapter_params={"a": merged},
                           dispatch=SloAware(step_cost_s=0.05),
                           autoscaler=Autoscaler(AutoscalerConfig(
                               target_queue_per_server=2.0, ttft_slo_s=0.3,
                               max_servers=3)))
    done = router.run(trace)
    assert len(done) == len(trace)
    for r in done:
        p = merged if r.adapter == "a" else params
        assert r.generated == _solo(cfg, p, r.tokens, 4), r.rid
    # deadlines were threaded through (absolute = arrival + budget)
    assert all(r.deadline == pytest.approx(r.arrival + 0.5) for r in done)


def test_wall_clock_runs_same_scheduler(setup):
    """Acceptance: the SAME router/autoscaler/policy code runs off the
    wall clock — only the injected Clock differs — and stays exact."""
    cfg, params = setup
    trace = poisson_trace(30.0, 0.25, seed=11, max_new_tokens=3)
    assert len(trace) >= 3
    router = ClusterRouter(cfg, params, n_servers=2,
                           ccfg=ClusterConfig(n_devices=2, n_slots=2),
                           dispatch=SloAware(),
                           clock=WallClock(),
                           autoscaler=Autoscaler(AutoscalerConfig(
                               max_servers=3)))
    t0 = router.clock
    done = router.run(trace)
    assert len(done) == len(trace)
    assert router.clock > t0                # wall time actually elapsed
    s = router.metrics.summary()
    assert s["n_completed"] == len(trace)
    assert s["ttft_p99"] > 0 and s["gpu_seconds"] > 0
    for r in done:                          # same tokens as any clock
        assert r.generated == _solo(cfg, params, r.tokens, 3), r.rid


# ---------------------------------------------------------------------------
# multi-model fleet
# ---------------------------------------------------------------------------

def test_fleet_multi_model_pools_exact(setup):
    """Two pools over SHARED base params serve a mixed-model trace: every
    request lands in its own pool, per-model metrics come out, global
    rids never collide, and tokens equal the solo reference."""
    cfg, params = setup
    ccfg = ClusterConfig(n_devices=2, n_slots=2)
    trace = merge_traces(
        poisson_trace(5.0, 1.2, seed=1, model="chat", max_new_tokens=4),
        poisson_trace(5.0, 1.2, seed=2, model="code", max_new_tokens=3))
    assert {a.model for a in trace} == {"chat", "code"}
    fleet = Fleet({
        "chat": PoolSpec(cfg, params, n_servers=1, ccfg=ccfg),
        "code": PoolSpec(cfg, params, n_servers=1, ccfg=ccfg,
                         dispatch=SloAware(step_cost_s=0.05)),
    })
    done = fleet.run(trace)
    assert len(done) == len(trace)
    assert len({r.rid for r in done}) == len(done)     # fleet-global rids
    by_model = fleet.metrics.summary_by_model()
    assert set(by_model) == {"chat", "code"}
    for m in ("chat", "code"):
        want = sum(1 for a in trace if a.model == m)
        assert by_model[m]["n_completed"] == want
        assert by_model[m]["ttft_p99"] > 0
    for r in done:
        n = 4 if r.model == "chat" else 3
        assert r.generated == _solo(cfg, params, r.tokens, n), r.rid
    doc = json.loads(fleet.metrics.to_json())
    assert set(doc["models"]) == {"chat", "code"}
    # pool-qualified cold-start records (both pools reported)
    assert {k.split("/")[0] for k in fleet.metrics.coldstart} \
        == {"chat", "code"}


def test_fleet_clock_advances_once_per_tick(setup):
    """N pools tick against the shared clock, which advances ONCE per
    fleet tick — not once per pool (same-tick semantics across pools)."""
    cfg, params = setup
    ccfg = ClusterConfig(n_devices=2, n_slots=2)
    fleet = Fleet({"a": PoolSpec(cfg, params, n_servers=1, ccfg=ccfg),
                   "b": PoolSpec(cfg, params, n_servers=1, ccfg=ccfg)})
    assert fleet.clock == 0.0
    fleet.tick()
    assert fleet.clock == pytest.approx(ccfg.tick_s)
    fleet.tick()
    assert fleet.clock == pytest.approx(2 * ccfg.tick_s)
    # every pool saw the same tick timestamps
    ts = sorted({t for t, _ in fleet.metrics.queue_depth})
    assert ts == pytest.approx([0.0, ccfg.tick_s])


def test_gauge_max_sums_same_timestamp_samples():
    """Fleet-wide gauges: per-pool samples at one shared tick timestamp
    sum before the max, so queue_depth_max/servers_max are fleet-wide."""
    from repro.cluster.metrics import ClusterMetrics
    m = ClusterMetrics()
    m.on_tick(0.0, 5, 2, 4, 0.05)    # pool A
    m.on_tick(0.0, 5, 2, 4, 0.05)    # pool B, same fleet tick
    m.on_tick(0.05, 1, 1, 2, 0.05)
    s = m.summary()
    assert s["queue_depth_max"] == 10.0
    assert s["servers_max"] == 4.0


def test_fleet_rejects_unknown_model(setup):
    cfg, params = setup
    fleet = Fleet({"chat": PoolSpec(cfg, params, n_servers=1,
                                    ccfg=ClusterConfig(n_devices=2,
                                                       n_slots=2))})
    with pytest.raises(ValueError, match="ghost"):
        fleet.submit(Arrival(0.0, model="ghost"))
    # model-less arrivals ride the default pool
    rid = fleet.submit(Arrival(0.0))
    assert rid == 0


def test_fleet_crash_migration_stays_in_pool(setup):
    """A pool-level crash re-routes within the pool and the fleet still
    completes everything exactly."""
    cfg, params = setup
    ccfg = ClusterConfig(n_devices=2, n_slots=4)
    trace = merge_traces(
        burst_wave_trace(8, base_rate=3.0, wave_rate=16.0, wave_at=0.2,
                         wave_len=0.4, seed=3, model="chat",
                         max_new_tokens=6),
        poisson_trace(4.0, 1.0, seed=4, model="code", max_new_tokens=3))
    fleet = Fleet({
        "chat": PoolSpec(cfg, params, n_servers=2, ccfg=ccfg),
        "code": PoolSpec(cfg, params, n_servers=1, ccfg=ccfg),
    })
    arrivals = sorted(trace, key=lambda a: a.time)
    i, crashed, done = 0, False, []
    for _ in range(200_000):
        while i < len(arrivals) and arrivals[i].time <= fleet.clock:
            fleet.submit(arrivals[i])
            i += 1
        done.extend(fleet.tick())
        chat = fleet.pools["chat"]
        if not crashed and chat.servers[1].srv.batcher.n_active >= 1:
            fleet.crash_server("chat", 1)
            crashed = True
        if i >= len(arrivals) and fleet.pending == 0:
            break
    assert crashed and len(done) == len(trace)
    kinds = [k for _, k, _ in fleet.metrics.events]
    assert "crash" in kinds
    for r in done:
        n = 6 if r.model == "chat" else 3
        assert r.generated == _solo(cfg, params, r.tokens, n), r.rid
