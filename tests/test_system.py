"""End-to-end behaviour of the PipeBoost system (paper §4 semantics).

These are the paper's claims as executable invariants:
  * inference can start after each device loads ~1/N of the model;
  * serving during background loading equals serving fully loaded;
  * crash + pipeline-parallel recovery is exact (same tokens);
  * strategy switching is seamless (same tokens before/after);
  * LoRA: merged adapters serve correctly, epoch switching preserved output.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core.engine import EngineError, PipeBoostEngine, generate
from repro.lora.adapters import init_lora, merge_lora, randomize_lora, unmerge_lora
from repro.models import transformer as T

KEY = jax.random.PRNGKey(11)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=8)
    params = T.init_params(cfg, KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)}
    return cfg, params, batch


def test_ready_after_one_round(dense_setup):
    cfg, params, _ = dense_setup
    eng = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    assert not eng.ready
    eng.load_round()           # each device loads its FIRST segment only
    assert eng.ready           # 1/N per device suffices (the paper's point)
    assert not eng.fully_loaded


def test_cannot_serve_before_ready(dense_setup):
    cfg, params, batch = dense_setup
    eng = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    with pytest.raises(EngineError):
        eng.prefill(batch)


def test_serving_during_loading_equals_full(dense_setup):
    cfg, params, batch = dense_setup
    e1 = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    e1.load_round()
    early = generate(e1, batch, 8)

    e2 = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    while e2.load_round():
        pass
    assert e2.fully_loaded
    full = generate(e2, batch, 8)
    np.testing.assert_array_equal(np.asarray(early), np.asarray(full))


@pytest.mark.parametrize("arch,layers", [
    ("qwen3-1.7b", 8), ("mamba2-780m", 8), ("recurrentgemma-2b", 6),
    ("qwen2-moe-a2.7b", 4),
])
def test_crash_recovery_exact(arch, layers):
    cfg = get_arch(arch).reduced(n_layers=layers)
    params = T.init_params(cfg, KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)}
    e1 = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    e1.load_round()
    ref = generate(e1, batch, 8)
    e2 = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    e2.load_round()
    out = generate(e2, batch, 8, crash_at=4, crash_devices=[1, 2])
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    kinds = [ev for ev, _ in e2.events]
    assert "crash" in kinds and "recover" in kinds


def test_crash_during_loading_reassigns(dense_setup):
    cfg, params, batch = dense_setup
    eng = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    eng.load_next_segment(0)   # only device 0 made progress
    eng.crash([1, 2])
    eng.recover()              # re-plan + finish loading on survivors
    assert eng.ready
    out = generate(eng, batch, 4)
    assert out.shape == (2, 4)


def test_all_dead_raises(dense_setup):
    cfg, params, _ = dense_setup
    eng = PipeBoostEngine(cfg, params, n_devices=2, max_len=64)
    eng.crash([0, 1])
    with pytest.raises(EngineError):
        eng.recover()


def test_strategy_switch_is_seamless(dense_setup):
    cfg, params, batch = dense_setup
    eng = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    eng.load_round()
    logits = eng.prefill(batch)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = [tok]
    for i in range(6):
        if i == 3:
            while eng.load_round():
                pass
            assert eng.maybe_switch_strategy(request_rate=100.0)
            assert eng.strategy == "single"
        logits = eng.decode(tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(tok)
    got = jnp.stack(outs, 1)

    e2 = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    e2.load_round()
    ref = generate(e2, batch, 7)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_lora_merge_serving(dense_setup):
    cfg, params, batch = dense_setup
    lora = randomize_lora(jax.random.fold_in(KEY, 5),
                          init_lora(KEY, cfg, rank=4))
    eng = PipeBoostEngine(cfg, params, n_devices=2, max_len=64,
                          adapters={"a": lora})
    eng.load_round()
    eng.switch_adapter("a")
    out_a = generate(eng, batch, 6)
    # reference: explicit merge
    merged = merge_lora(params, lora)
    e2 = PipeBoostEngine(cfg, merged, n_devices=2, max_len=64)
    e2.load_round()
    ref = generate(e2, batch, 6)
    np.testing.assert_array_equal(np.asarray(out_a), np.asarray(ref))
    # switch back to base == original weights
    eng2 = PipeBoostEngine(cfg, params, n_devices=2, max_len=64,
                           adapters={"a": lora})
    eng2.load_round()
    eng2.switch_adapter("a")
    eng2.switch_adapter(None)
    base = generate(eng2, batch, 6)
    e3 = PipeBoostEngine(cfg, params, n_devices=2, max_len=64)
    e3.load_round()
    np.testing.assert_array_equal(np.asarray(base),
                                  np.asarray(generate(e3, batch, 6)))


def test_merge_unmerge_inverse(dense_setup):
    cfg, params, _ = dense_setup
    lora = randomize_lora(jax.random.fold_in(KEY, 6),
                          init_lora(KEY, cfg, rank=8))
    back = unmerge_lora(merge_lora(params, lora), lora)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)
