"""Shared pytest config: marker registration + hypothesis gating.

The ``slow`` marker gates long-running tests (CI's fast lane runs
``-m "not slow"``; the full lane on main runs everything).

``hypothesis`` is a real dependency (pyproject ``[test]`` extra) but the
suite must stay collectable in minimal environments without it, so when the
import fails we install the deterministic fallback from
``tests/_hypothesis_fallback.py`` before test modules are imported.
"""
import gc
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401  (real package wins when installed)
except ImportError:
    import _hypothesis_fallback
    _hypothesis_fallback.install()


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (skipped in CI's fast lane)")


@pytest.fixture(autouse=True, scope="module")
def _bound_xla_executable_count():
    """XLA:CPU JITs every compiled executable into the one pytest process;
    the global jit caches keep them all alive, and a few hundred tests in
    the compiler itself segfaults on the next compile.  Dropping the jit
    caches at module teardown bounds the live-executable count — modules
    compile their own shapes anyway, so the cross-module hit rate this
    sacrifices is small."""
    yield
    try:
        import jax
        jax.clear_caches()
    except Exception:
        pass
    gc.collect()
