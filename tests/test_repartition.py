"""Elastic in-flight pipeline repartition + seeded chaos replay.

The robustness tentpole as executable invariants:

* ``PipeBoostEngine.repartition`` re-splits the stage plan over a CHANGED
  device set mid-generation (4→3 on a partial crash, back to 4 on rejoin)
  and the continued token stream is BIT-identical to an uncrashed run —
  only layers whose KV actually died are recomputed, zero tokens are
  re-prefilled;
* the serving-engine relay (``relay_inflight``) re-lays every live slot
  in ONE donated scatter, grouping equal-length slots into one batched
  ``reconstruct_cache`` call without changing any token;
* a ``ClusterServer`` under ``partial_recovery="repartition"`` keeps its
  in-flight requests (nothing drains, nothing re-routes) through crash
  AND device rejoin, token-exact against the solo reference;
* a seeded ``ChaosSchedule`` replays identically by seed and produces
  identical metrics under the tick and the event engines.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import (Arrival, Autoscaler, AutoscalerConfig, ChaosEvent,
                           ChaosSchedule, ClusterConfig, ClusterRouter,
                           ClusterServer, LeastLoaded, SimProfile, load_chaos,
                           poisson_trace, random_chaos, save_chaos,
                           sim_server_factory)
from repro.configs.base import get_arch
from repro.core.engine import PipeBoostEngine
from repro.models import transformer as T
from repro.serving.engine import (ServeRequest, ServingEngine,
                                  quantized_greedy)

KEY = jax.random.PRNGKey(3)


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=4)
    params = T.init_params(cfg, KEY)
    return cfg, params


@pytest.fixture(scope="module")
def setup8():
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=8)
    params = T.init_params(cfg, KEY)
    return cfg, params


def _solo(cfg, params, prompt, n, max_len=96):
    """Uninterrupted single-request greedy reference."""
    lg, cache = T.forward(cfg, params, {"tokens": jnp.asarray(prompt)[None]},
                          mode="prefill", max_len=max_len)
    toks = [int(quantized_greedy(lg)[0])]
    for _ in range(n - 1):
        lg, cache = T.decode_step(
            cfg, params, {"tokens": jnp.asarray([toks[-1]], jnp.int32)},
            cache)
        toks.append(int(quantized_greedy(lg)[0]))
    return toks


# ---------------------------------------------------------------------------
# engine-level elastic repartition
# ---------------------------------------------------------------------------

def _gen(eng, batch, n, faults=()):
    """Greedy-generate ``n`` tokens, applying ``{step: (dead, revive)}``
    repartitions mid-stream; returns (tokens, [stats])."""
    faults = dict(faults)
    tok = jnp.argmax(eng.prefill(batch), -1).astype(jnp.int32)
    out, stats = [tok], []
    for i in range(1, n):
        if i in faults:
            dead, revive = faults[i]
            stats.append(eng.repartition(dead=dead, revive=revive))
        tok = jnp.argmax(eng.decode(tok), -1).astype(jnp.int32)
        out.append(tok)
    return np.stack([np.asarray(t) for t in out], axis=1), stats


def test_engine_repartition_shrink_widen_bit_identical(setup8):
    """4→3→4 devices mid-generation: the stream equals an uncrashed run
    token-for-token, only the genuinely-lost layers are recomputed, and
    the stage plan actually changes size."""
    cfg, params = setup8
    batch = {"tokens": jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)}

    # ONE load round: the serving chain spans all 4 devices, so device 3
    # genuinely owns live KV when it dies (fully loaded, the chain
    # collapses onto device 0 and a crash of 3 would lose nothing)
    eng = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    eng.load_round()
    assert eng.ready and not eng.fully_loaded
    toks, stats = _gen(eng, batch, 10,
                       faults={3: ([3], []), 6: ([], [3])})
    shrink, widen = stats
    assert shrink["n_alive"] == 3 and widen["n_alive"] == 4
    # the dead device owned state: some layers were lost and recomputed,
    # but never the whole stack (surviving layers reused verbatim)
    assert 0 < shrink["lost_layers"] < cfg.n_layers
    assert shrink["reconstruct"]["kv_reused"] > 0
    # widening back loses nothing: device 3 rejoins EMPTY, KV lives on
    # the survivors' chain
    assert widen["lost_layers"] == 0

    ref = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    ref.load_round()
    ref_toks, _ = _gen(ref, batch, 10)
    np.testing.assert_array_equal(toks, ref_toks)
    kinds = [e for e, _ in eng.events]
    assert kinds.count("repartition") == 2


def test_engine_repartition_refuses_empty_device_set(setup8):
    cfg, params = setup8
    eng = PipeBoostEngine(cfg, params, n_devices=2, max_len=64)
    while eng.load_round():
        pass
    from repro.core.engine import EngineError
    with pytest.raises(EngineError, match="all devices dead"):
        eng.repartition(dead=[0, 1])


def test_engine_repartition_restarts_background_fill(setup8):
    """A repartition mid-background-fill hands the fill off to a fresh
    thread over the new plan (same cadence) and still fully loads."""
    cfg, params = setup8
    eng = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    eng.load_round()
    assert eng.ready and not eng.fully_loaded
    eng.start_fill(interval_s=0.01)
    eng.repartition(dead=[3])
    # either the handed-off thread is running or it already finished
    deadline = 200
    while not eng.fully_loaded and deadline:
        eng.load_round()
        deadline -= 1
    assert eng.fully_loaded
    eng.stop_fill()


# ---------------------------------------------------------------------------
# serving-engine relay (one donated scatter, grouped by length)
# ---------------------------------------------------------------------------

def test_relay_inflight_one_scatter_mixed_lengths_exact(setup):
    """Wipe some layers under live mixed-length requests; relay_inflight
    groups equal-length slots into batched reconstruct_cache calls, lands
    everything in ONE donated scatter, and decode continues token-exact."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, 250, size=L) for L in (10, 13, 13)]
    srv = ServingEngine(cfg, params, n_slots=4, max_len=96)
    srv.batcher.sampler = quantized_greedy
    reqs = [ServeRequest(i, p, max_new_tokens=8)
            for i, p in enumerate(prompts)]
    for r in reqs:
        srv.submit(r)
    for _ in range(3):
        srv.step()
    cache = srv.batcher.cache
    for leaf in ("k", "v"):
        z = cache["attn"][leaf]
        cache["attn"][leaf] = z.at[1:3].set(jnp.zeros_like(z[1:3]))
    stats = srv.relay_inflight([True, False, False, True])
    assert stats["relayed_reqs"] == 3
    assert srv.batcher.n_relay_scatters == 1       # ONE scatter dispatch
    # per-request work counts keep sum-over-requests semantics despite
    # the by-length grouping: layer 0 reused, layers 1-2 rebuilt, per req
    assert stats["kv_reused"] == 3
    assert stats["full_prefill"] == 6
    assert stats["layers_skipped"] == 3
    while srv.batcher.n_active:
        srv.step()
    assert srv.batcher.n_prefill_reqs == 3         # the 3 admissions only
    for i, p in enumerate(prompts):
        assert reqs[i].generated == _solo(cfg, params, p, 8), i


def test_relay_inflight_noop_when_state_survives(setup):
    cfg, params = setup
    srv = ServingEngine(cfg, params, n_slots=2, max_len=96)
    srv.batcher.sampler = quantized_greedy
    srv.submit(ServeRequest(0, np.arange(8), max_new_tokens=4))
    srv.step()
    assert srv.relay_inflight([True] * cfg.n_layers) == {}
    assert srv.batcher.n_relay_scatters == 0


# ---------------------------------------------------------------------------
# cluster server: crash -> repartition -> rejoin, requests never leave
# ---------------------------------------------------------------------------

def _partial_victim(server, n_layers):
    """A device owning SOME but not all layers' live state."""
    cands = [d for d in range(server.ccfg.n_devices)
             if 0 < sum(server.engine.lost_state_layers([d])) < n_layers]
    assert cands, "chain collapsed to one device — can't test partial loss"
    return cands[0]


def test_cluster_repartition_keeps_requests_token_exact(setup):
    """Partial crash under ``partial_recovery='repartition'``: requests
    stay on the server (nothing drains), the pause is repartition_ticks,
    a later device rejoin widens the plan back, and every request matches
    the solo reference with zero re-prefill."""
    cfg, params = setup
    ccfg = ClusterConfig(n_devices=4, n_slots=2,
                         partial_recovery="repartition")
    server = ClusterServer(0, cfg, params, ccfg)
    while server.state == "loading":
        server.tick(0.0)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, 250, size=L) for L in (10, 13)]
    reqs = [ServeRequest(i, p, max_new_tokens=8)
            for i, p in enumerate(prompts)]
    for r in reqs:
        server.submit(r)
    server.tick(0.0)
    assert server.srv.batcher.n_active == 2
    n_prefills = server.srv.batcher.n_prefill_reqs
    dev = _partial_victim(server, cfg.n_layers)
    drained = server.crash([dev])
    assert drained == []                    # requests never leave
    assert server.state == "recovering"
    assert server.recovery_mode == "repartition"
    assert server._recover_left == ccfg.repartition_ticks
    assert server.degraded_devices == 1
    assert server.last_recovery["relayed_reqs"] == 2
    assert server.srv.batcher.n_relay_scatters == 1
    now = 1.0
    for _ in range(3):
        server.tick(now)
        now += ccfg.tick_s
    assert server.state == "serving"
    # widen back mid-decode; the serving tick's background fill refills
    server.rejoin_devices([dev])
    assert server.degraded_devices == 0
    while any(not r.done for r in reqs):
        server.tick(now)
        now += ccfg.tick_s
    assert server.srv.batcher.n_prefill_reqs == n_prefills  # zero re-prefill
    for i, p in enumerate(prompts):
        assert reqs[i].generated == _solo(cfg, params, p, 8), i


def test_cluster_repartition_double_crash_consistent(setup):
    """Two partial crashes in a row (second while recovering): each
    re-splits over the remaining survivors; requests still finish
    token-exact and never drain."""
    cfg, params = setup
    ccfg = ClusterConfig(n_devices=4, n_slots=2,
                         partial_recovery="repartition")
    server = ClusterServer(0, cfg, params, ccfg)
    while server.state == "loading":
        server.tick(0.0)
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, 250, size=L) for L in (9, 12)]
    reqs = [ServeRequest(i, p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        server.submit(r)
    server.tick(0.0)
    d1 = _partial_victim(server, cfg.n_layers)
    assert server.crash([d1]) == []
    assert server.state == "recovering"
    # second fault lands before the first recovery window closes
    survivors = [d.idx for d in server.engine.devices if d.alive]
    assert server.crash([survivors[0]]) == []
    assert server.state == "recovering"
    assert server.degraded_devices == 2
    now = 1.0
    while any(not r.done for r in reqs):
        server.tick(now)
        now += ccfg.tick_s
    for i, p in enumerate(prompts):
        assert reqs[i].generated == _solo(cfg, params, p, 6), i


def test_router_partial_crash_repartition_zero_reprefill(setup):
    """Router-level: a partial crash under repartition mode books every
    live request as repartition-recovered (reprefill_tokens stays 0) and
    the run's outputs equal the solo reference."""
    cfg, params = setup
    trace = poisson_trace(6.0, 1.5, seed=9, max_new_tokens=4)
    router = ClusterRouter(
        cfg, params, n_servers=1,
        ccfg=ClusterConfig(n_devices=4, n_slots=2,
                           partial_recovery="repartition"))
    arrivals = sorted(trace, key=lambda a: a.time)
    i, crashed, done = 0, False, []
    for _ in range(200_000):
        while i < len(arrivals) and arrivals[i].time <= router.clock:
            router.submit(arrivals[i])
            i += 1
        done.extend(router.tick())
        srv1 = router.servers[0]
        # crash only once the server is verifiably mid-decode, so the
        # repartition has live requests to book (crash_after_completions
        # can land on a tick where every slot just drained)
        if (not crashed and srv1.state == "serving"
                and srv1.srv.batcher.n_active >= 1):
            # any device NOT owning the whole live state (by the time the
            # server is busy the background fill may have collapsed the
            # chain onto one device, so a partial-loss victim need not
            # exist here — relay exactness is covered above)
            losts = {d: sum(srv1.engine.lost_state_layers([d]))
                     for d in range(4)}
            cands = [d for d, n in losts.items() if 0 < n < cfg.n_layers]
            victim = cands[0] if cands else \
                next(d for d in range(4) if losts[d] == 0)
            router.crash_server(0, [victim])
            crashed = True
        if i >= len(arrivals) and router.pending == 0:
            break
    assert crashed, "crash scenario never armed"
    assert len(done) == len(trace)
    srv1 = router.servers[0]
    assert srv1.state == "serving"
    assert srv1.recovery_mode == "repartition"
    s = router.metrics.summary()
    assert s["recovery_mode_repartition"] >= 1
    assert s["recovery_reprefill_tokens"] == 0.0
    assert s["recovery_mode_reprefill"] == 0.0
    assert s["degraded_seconds"] > 0.0      # device 0 never rejoined
    kinds = [k for _, k, _ in router.metrics.events]
    assert "recover" in kinds
    for r in done:
        assert r.generated == _solo(cfg, params, r.tokens, 4), r.rid


def test_router_chaos_partial_crash_and_rejoin_real_servers(setup):
    """A scripted partial-crash + device-rejoin ChaosSchedule against real
    servers: every stream token-exact, zero re-prefill, and degraded
    seconds stop accruing at the rejoin."""
    cfg, params = setup
    trace = poisson_trace(8.0, 0.7, seed=3, max_new_tokens=4)
    chaos = ChaosSchedule([ChaosEvent(0.313, "partial_crash", 0, (1,)),
                           ChaosEvent(0.913, "rejoin", 0, (1,))])
    router = ClusterRouter(
        cfg, params, n_servers=1,
        ccfg=ClusterConfig(n_devices=4, n_slots=4,
                           partial_recovery="repartition"))
    done = router.run(trace, chaos=chaos)
    assert len(done) == len(trace)
    s = router.metrics.summary()
    assert s["recovery_mode_repartition"] >= 1
    assert s["recovery_reprefill_tokens"] == 0.0
    # degraded for ~0.6s of the schedule, not the whole run
    assert 0.0 < s["degraded_seconds"] <= 0.6 + 0.1
    assert router.servers[0].degraded_devices == 0
    for r in done:
        assert r.generated == _solo(cfg, params, r.tokens, 4), r.rid


# ---------------------------------------------------------------------------
# chaos schedules: replayable, seeded, engine-equivalent
# ---------------------------------------------------------------------------

def test_chaos_schedule_roundtrip_and_validation(tmp_path):
    sched = random_chaos(3, horizon=5.0, n_servers=2, seed=4, n_devices=4,
                         partial_prob=0.5)
    path = str(tmp_path / "chaos.json")
    save_chaos(path, sched)
    back = load_chaos(path)
    assert back.events == sched.events
    # deterministic by seed
    again = random_chaos(3, horizon=5.0, n_servers=2, seed=4, n_devices=4,
                         partial_prob=0.5)
    assert again.events == sched.events
    other = random_chaos(3, horizon=5.0, n_servers=2, seed=5, n_devices=4,
                         partial_prob=0.5)
    assert other.events != sched.events
    # events are sorted by time, kinds validated
    times = [e.time for e in sched]
    assert times == sorted(times)
    with pytest.raises(ValueError, match="unknown chaos kind"):
        ChaosEvent(1.0, "meteor", 0)
    # unknown file version refuses instead of mis-parsing
    bad = tmp_path / "bad.json"
    bad.write_text('{"version": 99, "events": []}')
    with pytest.raises(ValueError, match="unknown chaos version"):
        load_chaos(str(bad))


def _sim_router():
    return ClusterRouter(
        None, None, n_servers=2,
        ccfg=ClusterConfig(n_devices=1, n_slots=4),
        autoscaler=Autoscaler(AutoscalerConfig(
            target_queue_per_server=4.0, max_servers=4, min_servers=1,
            idle_seconds_before_retire=1.0)),
        dispatch=LeastLoaded(),
        server_factory=sim_server_factory(SimProfile(ready_ticks=2,
                                                     full_ticks=6)),
        materialize_prompts=False)


def test_chaos_event_equals_tick_sim_fleet():
    """A seeded chaos schedule over the modeled fleet replays identically
    under the tick and the event engines: same streams, same chaos event
    sequence (applied + skipped), same summary metrics."""
    chaos = random_chaos(3, horizon=4.0, n_servers=2, seed=11,
                         rejoin_delay_s=1.0)
    trace = poisson_trace(30.0, 2.0, seed=7, max_new_tokens=4)
    routers, dones = {}, {}
    for eng in ("event", "tick"):
        r = _sim_router()
        dones[eng] = r.run(list(trace), engine=eng, chaos=chaos)
        routers[eng] = r
    assert len(dones["event"]) == len(trace)
    evt = {r.rid: tuple(r.generated) for r in dones["event"]}
    tick = {r.rid: tuple(r.generated) for r in dones["tick"]}
    assert evt == tick
    chaos_kinds = ("crash", "rejoin", "rejoin_skipped", "chaos_skip")
    seqs = {e: [(t, k, d) for t, k, d in routers[e].metrics.events
                if k in chaos_kinds] for e in routers}
    assert len(seqs["event"]) == len(seqs["tick"])
    for (te, ke, de), (tt, kt, dt) in zip(seqs["event"], seqs["tick"]):
        assert (ke, de) == (kt, dt)
        assert te == pytest.approx(tt, abs=1e-9)
    assert any(k == "crash" for _, k, _ in seqs["event"])
    se, st = (routers[e].metrics.summary() for e in ("event", "tick"))
    for k in ("n_completed", "gpu_seconds", "degraded_seconds",
              "recovery_reprefill_tokens"):
        assert se[k] == pytest.approx(st[k], rel=1e-9, abs=1e-9), k


def test_chaos_skip_is_deterministic():
    """Stale events (crash of an already-down server, rejoin with nothing
    dead) resolve to chaos_skip no-ops, not errors — the schedule replays
    however the fleet evolved."""
    chaos = ChaosSchedule([
        ChaosEvent(0.113, "crash", 0),
        ChaosEvent(0.213, "crash", 0),        # already down -> skip
        ChaosEvent(0.313, "rejoin", 1, (0,)),  # nothing dead -> skip
        ChaosEvent(0.413, "crash", 7),        # no such server -> skip
        ChaosEvent(0.513, "rejoin", 0),
    ])
    trace = poisson_trace(10.0, 1.0, seed=2, max_new_tokens=3)
    r = _sim_router()
    done = r.run(list(trace), chaos=chaos)
    assert len(done) == len(trace)
    kinds = [k for _, k, _ in r.metrics.events]
    assert kinds.count("chaos_skip") == 3
    assert "crash" in kinds and "rejoin" in kinds


# ---------------------------------------------------------------------------
# bounded retry with backoff before unservable
# ---------------------------------------------------------------------------

def _unservable_router(ccfg, setup):
    """One live server that preloads only adapter 'a'; requests tagged
    'b' can never place until the fleet changes."""
    from repro.cluster import HotAdapterPlacement
    from repro.lora.adapters import init_lora, merge_lora, randomize_lora
    cfg, params = setup
    aps = {}
    for i, name in enumerate(("a", "b")):
        lora = randomize_lora(jax.random.fold_in(KEY, 30 + i),
                              init_lora(KEY, cfg, rank=4))
        aps[name] = merge_lora(params, lora)
    router = ClusterRouter(cfg, params, n_servers=1, ccfg=ccfg,
                           adapter_params=aps,
                           placement=HotAdapterPlacement(k=1))
    router._recent_adapters.append("a")
    router.spawn_server()                  # hot-set replacement: only "a"
    router.servers[0].retire()             # the full seed leaves
    return router


@pytest.mark.parametrize("engine", ["event", "tick"])
def test_unservable_retries_with_backoff(setup, engine):
    """A placement miss retries ``unservable_retries`` times with doubling
    backoff before the single ``unservable`` event fires — identically
    under both engines."""
    ccfg = ClusterConfig(n_devices=2, n_slots=2, unservable_retries=3,
                         retry_backoff_s=0.2)
    router = _unservable_router(ccfg, setup)
    trace = [Arrival(0.0, adapter="a", max_new_tokens=2),
             Arrival(0.01, adapter="b", max_new_tokens=2)]
    done = router.run(trace, engine=engine)
    assert len(done) == 1 and done[0].adapter == "a"
    evs = [(t, k) for t, k, _ in router.metrics.events
           if k in ("retry", "unservable")]
    kinds = [k for _, k in evs]
    assert kinds == ["retry"] * 3 + ["unservable"]
    # doubling backoff: gaps between consecutive rechecks grow ~2x
    times = [t for t, _ in evs]
    g1, g2, g3 = np.diff(times)
    assert g2 == pytest.approx(2 * g1, abs=2 * ccfg.tick_s)
    assert g3 == pytest.approx(2 * g2, abs=2 * ccfg.tick_s)


def test_retry_state_clears_when_server_becomes_servable(setup):
    """If a capable server joins before the retries exhaust, the request
    dispatches and no ``unservable`` ever fires."""
    ccfg = ClusterConfig(n_devices=2, n_slots=2, unservable_retries=5,
                         retry_backoff_s=0.2)
    router = _unservable_router(ccfg, setup)
    router.submit(Arrival(0.0, adapter="b", max_new_tokens=2))
    router.tick()
    assert router._retry_state              # backoff armed
    # a server that preloads "b" joins the fleet
    router._recent_adapters.extend(["b"] * 8)
    router.spawn_server()
    for _ in range(400):
        router.tick()
        if router.pending == 0:
            break
    assert router.pending == 0
    assert not router._retry_state
    kinds = [k for _, k, _ in router.metrics.events]
    assert "unservable" not in kinds
