"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step per assigned arch asserting output shapes + no NaNs, plus
decode-vs-full-forward consistency (the KV-cache correctness invariant)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_arch
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=24):
    if cfg.family in ("audio", "vlm"):
        batch = {"embeds": jax.random.normal(KEY, (B, S, cfg.d_model),
                                             jnp.float32)}
        if cfg.mrope:
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(S)[None, :, None], (B, S, 3)).astype(jnp.int32)
    else:
        batch = {"tokens": jax.random.randint(KEY, (B, S), 0,
                                              cfg.vocab_size)}
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward(arch):
    cfg = get_arch(arch).reduced()
    params = T.init_params(cfg, KEY)
    batch = make_batch(cfg)
    logits, aux = T.forward(cfg, params, batch, mode="train")
    B, S = 2, 24
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    from repro.training.optimizer import AdamWConfig
    from repro.training.train import init_train_state, make_train_step
    cfg = get_arch(arch).reduced()
    state = init_train_state(cfg, KEY, jnp.float32)
    step = make_train_step(cfg, AdamWConfig(warmup_steps=1, total_steps=10),
                           remat=True)
    batch = make_batch(cfg)
    batch["labels"] = jax.random.randint(jax.random.fold_in(KEY, 9),
                                         (2, 24), 0, cfg.vocab_size)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    a0 = jax.tree.leaves(state.params)[1]
    a1 = jax.tree.leaves(new_state.params)[1]
    assert not np.allclose(np.asarray(a0), np.asarray(a1))


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_arch(a).has_decode])
def test_arch_decode_matches_full_forward(arch):
    cfg = get_arch(arch).reduced()
    params = T.init_params(cfg, KEY)
    B, S = 2, 24
    batch = make_batch(cfg, B, S)
    lg, cache = T.forward(cfg, params, batch, mode="prefill", max_len=S + 8)
    assert lg.shape == (B, cfg.padded_vocab)

    if cfg.family == "vlm":
        e1 = jax.random.normal(jax.random.fold_in(KEY, 3),
                               (B, 1, cfg.d_model), jnp.float32)
        p1 = jnp.full((B, 1, 3), S, jnp.int32)
        lg2, _ = T.decode_step(cfg, params, {"embeds": e1, "positions": p1},
                               cache)
        full_batch = {"embeds": jnp.concatenate([batch["embeds"], e1], 1),
                      "positions": jnp.concatenate([batch["positions"], p1],
                                                   1)}
    else:
        nxt = jnp.argmax(lg, -1).astype(jnp.int32)
        lg2, _ = T.decode_step(cfg, params, {"tokens": nxt}, cache)
        full_batch = {"tokens": jnp.concatenate(
            [batch["tokens"], nxt[:, None]], 1)}
    full, _ = T.forward(cfg, params, full_batch, mode="train")
    np.testing.assert_allclose(np.asarray(lg2),
                               np.asarray(full[:, -1, :]),
                               atol=2e-2, rtol=2e-2)


def test_encoder_has_no_decode():
    cfg = get_arch("hubert-xlarge").reduced()
    assert not cfg.has_decode
    with pytest.raises(AssertionError):
        T.decode_step(cfg, T.init_params(cfg, KEY), {"tokens": jnp.zeros(
            (1,), jnp.int32)}, {"pos": jnp.zeros((1,), jnp.int32)})


def test_local_window_ring_buffer_long_decode():
    """Windowed arch decoding past the window: ring must hold exactly the
    last `window` keys (long_500k-style bounded cache)."""
    cfg = get_arch("recurrentgemma-2b").reduced(n_layers=3, attn_window=8)
    params = T.init_params(cfg, KEY)
    B, S = 1, 20
    batch = make_batch(cfg, B, S)
    lg, cache = T.forward(cfg, params, batch, mode="prefill", max_len=64)
    assert cache["attn"]["k"].shape[2] == 8  # ring capacity == window
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    toks = [batch["tokens"], tok[:, None]]
    for i in range(12):
        lg, cache = T.decode_step(cfg, params, {"tokens": tok}, cache)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        toks.append(tok[:, None])
    # reference: full forward over the whole history
    hist = jnp.concatenate(toks, axis=1)
    full, _ = T.forward(cfg, params, {"tokens": hist[:, :-1]}, mode="train")
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(full[:, -1, :]),
                               atol=2e-2, rtol=2e-2)


def test_param_counts_match_published():
    expect = {
        "mamba2-780m": 0.780, "qwen3-1.7b": 1.72, "deepseek-coder-33b": 33.3,
        "granite-3-8b": 8.17, "qwen2.5-14b": 14.8, "hubert-xlarge": 0.95,
        "qwen2-vl-72b": 72.7, "recurrentgemma-2b": 2.67,
    }
    for a, v in expect.items():
        got = get_arch(a).param_count() / 1e9
        assert abs(got - v) / v < 0.02, (a, got, v)
    # MoE active counts
    assert abs(get_arch("qwen2-moe-a2.7b").active_param_count() / 1e9
               - 2.7) < 0.15
    assert abs(get_arch("phi3.5-moe-42b-a6.6b").active_param_count() / 1e9
               - 6.6) < 0.25


def test_cells_matrix():
    from repro.configs.base import cells
    cs = cells(include_skipped=True)
    assert len(cs) == 40
    runnable = [c for c in cs if c[2]]
    assert len(runnable) == 31
