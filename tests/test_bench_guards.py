"""Benchmark tooling guards: the compile-count verdict logic, the keyed
trajectory-JSON writer (re-runs replace, never duplicate), and the
docstring-coverage rule (pbcheck R6) CI runs over the documented
layers."""
import ast
import json

import pytest

from benchmarks.compile_guard import evaluate
from benchmarks.run import append_keyed_entry
from repro.analysis.cli import CheckConfig, run_check
from repro.analysis.rules.docstrings import iter_defs


GOOD = {"prefill_compiles": 3, "decode_compiles": 1}


def test_guard_ok_on_designed_bounds():
    verdict, msgs = evaluate(GOOD, n_done=16, n_switches=2, n_buckets=4)
    assert verdict == "ok" and not msgs


@pytest.mark.parametrize("cs", [
    {"prefill_compiles": -1, "decode_compiles": 1},
    {"prefill_compiles": 3, "decode_compiles": -1},
    {"prefill_compiles": -1, "decode_compiles": -1},
])
def test_guard_sentinel_skips_never_passes(cs):
    """compile_stats reports -1 when jax's private cache-size API is gone.
    The sentinel must SKIP (with a warning), and in particular must never
    satisfy the bound vacuously (-1 <= n_buckets) and report ok."""
    verdict, msgs = evaluate(cs, n_done=16, n_switches=2, n_buckets=4)
    assert verdict == "skip"
    assert verdict != "ok"
    assert any("WARN" in m for m in msgs)


@pytest.mark.parametrize("cs", [GOOD,
                                {"prefill_compiles": -1,
                                 "decode_compiles": -1}])
def test_guard_coverage_checks_fail_even_under_sentinel(cs):
    """Lost coverage (missing completions / no epoch switch) must FAIL
    regardless of whether the compile-count API is available — the
    sentinel only skips the count bounds, it never masks a broken run."""
    v, _ = evaluate(cs, n_done=10, n_switches=2, n_buckets=4)
    assert v == "fail"
    v, _ = evaluate(cs, n_done=16, n_switches=0, n_buckets=4)
    assert v == "fail"


def test_guard_fails_on_regressions():
    # bucketing regressed: one compile per unique length
    v, _ = evaluate({"prefill_compiles": 16, "decode_compiles": 1},
                    n_done=16, n_switches=2, n_buckets=4)
    assert v == "fail"
    # decode retrace crept in
    v, _ = evaluate({"prefill_compiles": 3, "decode_compiles": 2},
                    n_done=16, n_switches=2, n_buckets=4)
    assert v == "fail"
    # lost coverage: requests missing or epochs never switched
    v, _ = evaluate(GOOD, n_done=15, n_switches=2, n_buckets=4)
    assert v == "fail"
    v, _ = evaluate(GOOD, n_done=16, n_switches=0, n_buckets=4)
    assert v == "fail"


def test_keyed_entry_replaces_in_place(tmp_path):
    path = str(tmp_path / "BENCH_x.json")
    e1 = {"commit": "abc", "config": {"n": 1}, "value": 10}
    e2 = {"commit": "abc", "config": {"n": 1}, "value": 20}  # same key
    e3 = {"commit": "def", "config": {"n": 1}, "value": 30}  # new commit
    e4 = {"commit": "abc", "config": {"n": 2}, "value": 40}  # new config
    assert append_keyed_entry(path, e1) == 1
    assert append_keyed_entry(path, e2) == 1        # replaced, not appended
    assert append_keyed_entry(path, e3) == 2
    assert append_keyed_entry(path, e4) == 3
    with open(path) as f:
        entries = json.load(f)["entries"]
    assert [e["value"] for e in entries] == [20, 30, 40]


def test_keyed_entry_shelves_corrupt_file(tmp_path):
    """An unreadable trajectory file must be moved aside, not erased."""
    path = str(tmp_path / "BENCH_z.json")
    with open(path, "w") as f:
        f.write('{"entries": [{"truncat')          # interrupted write
    append_keyed_entry(path, {"commit": "abc", "config": {}, "value": 1})
    with open(path) as f:
        assert [e["value"] for e in json.load(f)["entries"]] == [1]
    with open(path + ".corrupt") as f:
        assert f.read().startswith('{"entries"')   # history preserved


def test_keyed_entry_preserves_legacy_unkeyed_rows(tmp_path):
    """Pre-existing trajectory rows without commit/config keys stay."""
    path = str(tmp_path / "BENCH_y.json")
    with open(path, "w") as f:
        json.dump({"entries": [{"ts": 1.0, "value": 5}]}, f)
    append_keyed_entry(path, {"commit": "abc", "config": {}, "value": 6})
    with open(path) as f:
        entries = json.load(f)["entries"]
    assert len(entries) == 2 and entries[0]["value"] == 5

# ---------------------------------------------------------------------------
# docstring coverage (pbcheck R6 — the successor of the retired
# benchmarks/docstring_gate.py percentage gate; same walk, per-item
# findings instead of a coverage number)
# ---------------------------------------------------------------------------

_SAMPLE = '''"""Module doc."""


class Public:
    """Class doc."""

    def __init__(self, x):          # dunder: excluded (class doc covers it)
        self.x = x

    @property
    def value(self):                # property getter: excluded
        return self.x

    @value.setter
    def value(self, v):             # setter: excluded
        self.x = v

    def documented(self):
        """Has one."""

    def bare(self):                 # counted, missing
        return self.x

    def _helper(self):              # private: excluded
        return None


class _Private:
    def anything_inside(self):      # private scope: excluded entirely
        return 1


def documented_fn():
    """Has one."""
    def nested():                   # nested in function: excluded
        return 2
    return nested


def bare_fn():                      # counted, missing
    return 3
'''


def _write_sample(tmp_path, name="mod.py", text=_SAMPLE):
    p = tmp_path / name
    p.write_text(text)
    return str(p)


# docstring_paths=("",) scopes R6 onto the tmp files (substring match)
def _r6(paths, root):
    return run_check(paths, CheckConfig(rules=("R6",),
                                        docstring_paths=("",)),
                     root=root)


def test_r6_exclusions_mirror_interrogate():
    """Only module + public class + public non-property defs count:
    dunders, properties/setters, private names, private scopes, and
    function-nested functions are all invisible to the walk."""
    quals = {q: ok for _, q, _, ok in iter_defs(ast.parse(_SAMPLE))}
    assert set(quals) == {"<module>", "Public", "Public.documented",
                          "Public.bare", "documented_fn", "bare_fn"}
    assert [q for q, ok in sorted(quals.items()) if not ok] == \
        ["Public.bare", "bare_fn"]


def test_r6_reports_each_missing_name(tmp_path):
    """Per-item findings (the reason R6 replaced the percentage gate):
    exactly the two undocumented defs are flagged, by qualname."""
    _write_sample(tmp_path)
    res = _r6([str(tmp_path)], root=str(tmp_path))
    details = sorted(f.detail for f in res.findings)
    assert details == ["missing-doc:function:Public.bare",
                       "missing-doc:function:bare_fn"]
    assert not any("documented" in d for d in details)


def test_r6_clean_file_has_no_findings(tmp_path):
    _write_sample(tmp_path, text='"""Doc."""\n\ndef f():\n    """D."""\n')
    assert _r6([str(tmp_path)], root=str(tmp_path)).ok


def test_r6_walks_directories_and_skips_pycache(tmp_path):
    _write_sample(tmp_path, "a.py")
    (tmp_path / "__pycache__").mkdir()
    _write_sample(tmp_path / "__pycache__", "b.py",
                  text="def junk():\n    return 0\n")
    res = _r6([str(tmp_path)], root=str(tmp_path))
    assert res.n_files == 1
    assert all("__pycache__" not in f.path for f in res.findings)


def test_r6_rejects_unparseable_source(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("def broken(:\n")
    with pytest.raises(SystemExit, match="not parseable"):
        _r6([str(bad)], root=str(tmp_path))


def test_r6_scoping_skips_paths_outside_the_documented_layers(tmp_path):
    """R6 only fires inside ``docstring_paths`` — the same scoping the
    CI invocation relies on to leave undocumented scratch code alone."""
    _write_sample(tmp_path)
    res = run_check([str(tmp_path)],
                    CheckConfig(rules=("R6",),
                                docstring_paths=("repro/cluster/",)),
                    root=str(tmp_path))
    assert res.ok and not res.findings


def test_cluster_layer_meets_r6():
    """The CI gate verbatim: the shipped documented layers carry full
    public-API docstring coverage under R6."""
    res = run_check(["src/repro/cluster", "src/repro/analysis"],
                    CheckConfig(rules=("R6",)))
    assert res.ok, [f.render() for f in res.findings]


# ---------------------------------------------------------------------------
# elastic-repartition compile-guard verdicts
# ---------------------------------------------------------------------------

def test_repartition_guard_ok_per_stage_count():
    from benchmarks.compile_guard import evaluate_repartition
    v, msgs = evaluate_repartition(
        {"decode_compiles": 1, "pipeline_prefill_compiles": 3},
        n_stage_counts=3, n_crash_events=6, chain_ok=True)
    assert v == "ok" and not msgs
    # single-XLA-device host: pipeline never engages, decode still guards
    v, _ = evaluate_repartition(
        {"decode_compiles": 1, "pipeline_prefill_compiles": 0},
        n_stage_counts=0, n_crash_events=6, chain_ok=True)
    assert v == "ok"


def test_repartition_guard_fails_on_per_event_recompiles():
    from benchmarks.compile_guard import evaluate_repartition
    # pipeline recompiled once per crash event instead of per stage count
    v, msgs = evaluate_repartition(
        {"decode_compiles": 1, "pipeline_prefill_compiles": 6},
        n_stage_counts=2, n_crash_events=6, chain_ok=True)
    assert v == "fail"
    assert any("per event" in m for m in msgs)
    # decode retraced across a repartition
    v, _ = evaluate_repartition(
        {"decode_compiles": 2, "pipeline_prefill_compiles": 2},
        n_stage_counts=2, n_crash_events=6, chain_ok=True)
    assert v == "fail"


def test_repartition_guard_sentinel_skips_never_passes():
    from benchmarks.compile_guard import evaluate_repartition
    v, msgs = evaluate_repartition(
        {"decode_compiles": -1, "pipeline_prefill_compiles": 0},
        n_stage_counts=2, n_crash_events=6, chain_ok=True)
    assert v == "skip" and any("WARN" in m for m in msgs)
    # lost coverage fails even under the sentinel
    v, _ = evaluate_repartition(
        {"decode_compiles": -1, "pipeline_prefill_compiles": 0},
        n_stage_counts=2, n_crash_events=6, chain_ok=False)
    assert v == "fail"
    v, _ = evaluate_repartition(
        {"decode_compiles": 1, "pipeline_prefill_compiles": 2},
        n_stage_counts=2, n_crash_events=6, chain_ok=False)
    assert v == "fail"
