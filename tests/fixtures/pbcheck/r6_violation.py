"""R6 fixture: a public function with no docstring."""


def undocumented(x):
    return x + 1
