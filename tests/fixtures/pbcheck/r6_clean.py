"""R6 fixture (clean): everything public carries a docstring."""


def documented(x):
    """Add one."""
    return x + 1
