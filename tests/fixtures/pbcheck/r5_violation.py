"""R5 fixture: a chaos handler with a typo'd kind, missing kinds, and
an out-of-vocabulary recovery mode (self-contained schema + handler)."""

CHAOS_KINDS = ("crash", "partial_crash", "rejoin")


class Metrics:
    """Recovery-metrics sink with the asserted mode vocabulary."""

    def on_recovery(self, mode, t):
        """Record one recovery of the given mode at time ``t``."""
        assert mode in ("migrate", "reprefill", "repartition")


def apply_chaos(ev, metrics):
    """Dispatch one chaos event (deliberately broken for the test)."""
    if ev.kind == "crash":
        metrics.on_recovery("migrate", 0.0)
    elif ev.kind == "partial_cras":            # typo: unknown kind
        metrics.on_recovery("replay", 0.0)     # unknown recovery mode
    # "partial_crash" and "rejoin" are never handled
