"""R1 fixture (clean): the donated name is rebound before any read."""
import jax

step = jax.jit(lambda cache, tok: (tok, cache), donate_argnums=(0,))


def decode_loop(cache, tok):
    """The canonical donation pattern: rebind, then use freely."""
    out, cache = step(cache, tok)
    return out, cache["k"]
