"""R4 fixture: every retrace-hazard shape at a jitted call site."""
import jax

embed = jax.jit(lambda s: s)


def hot_step(xs):
    """Four hazards: IIFE jit, jit-in-loop, f-string arg, lambda arg."""
    out = jax.jit(lambda x: x + 1)(xs)      # compiles every call
    total = 0
    for x in xs:
        g = jax.jit(lambda v: v * 2)        # fresh jit per iteration
        total = total + g(x)
    label = embed(f"step-{total}")          # fresh str -> new static key
    h = embed(lambda q: q)                  # fresh lambda -> retrace
    return out, total, label, h
