"""R5 fixture (suppressed): a partial handler that documents why."""

CHAOS_KINDS = ("crash", "partial_crash", "rejoin")


class Metrics:
    """Recovery-metrics sink with the asserted mode vocabulary."""

    def on_recovery(self, mode, t):
        """Record one recovery of the given mode at time ``t``."""
        assert mode in ("migrate", "reprefill", "repartition")


def apply_crash_only(ev, metrics):
    """Handles crashes only; the caller filters other kinds upstream."""
    # pbcheck: disable=R5 (upstream filter guarantees kind == "crash")
    if ev.kind == "crash":
        metrics.on_recovery("migrate", 0.0)
