"""R3 fixture: a thread-shared attribute read outside the lock."""
import threading


class Engine:
    """Background fill thread mutates ``rounds``; a reader skips the
    lock."""

    def __init__(self):
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self.rounds = []

    def start(self):
        """Spawn the fill thread."""
        t = threading.Thread(target=self._run, daemon=True)
        t.start()

    def _run(self):
        while not self._stop.is_set():
            self.load()

    def load(self):
        """One fill round (correctly locked)."""
        with self._lock:
            self.rounds.append(1)

    def status(self):
        """Unlocked read of the shared list — the R3 violation."""
        return len(self.rounds)
