"""R2 fixture (clean): numpy ops on host-origin data only."""
import numpy as np


def pack_batch(rows):
    """Pure host-side packing — np.asarray of a host array is free."""
    toks = np.zeros((len(rows),), np.int32)
    for i, r in enumerate(rows):
        toks[i] = r
    return np.asarray(toks)
