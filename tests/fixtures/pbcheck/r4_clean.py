"""R4 fixture (clean): jits built once at module scope, stable args."""
import jax

embed = jax.jit(lambda s: s)
double = jax.jit(lambda v: v * 2)


def hot_step(xs):
    """Module-level jits, plain array args — compiles exactly once."""
    total = 0
    for x in xs:
        total = total + double(x)
    return embed(total)
