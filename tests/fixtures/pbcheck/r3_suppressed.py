"""R3 fixture (suppressed): a tolerated racy read, with a reason."""
import threading


class Engine:
    """A monitoring read that tolerates staleness suppresses R3."""

    def __init__(self):
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self.rounds = []

    def start(self):
        """Spawn the fill thread."""
        t = threading.Thread(target=self._run, daemon=True)
        t.start()

    def _run(self):
        while not self._stop.is_set():
            self.load()

    def load(self):
        """One fill round (locked)."""
        with self._lock:
            self.rounds.append(1)

    def status(self):
        """Racy-by-design monitoring read."""
        # pbcheck: disable=R3 (monitoring read; stale len is acceptable)
        return len(self.rounds)
