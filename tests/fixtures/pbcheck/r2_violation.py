"""R2 fixture: host syncs on jit-produced values in a hot module."""
import jax
import numpy as np

decode = jax.jit(lambda tok: tok + 1)


def hot_step(tokens):
    """Three distinct device->host syncs in the decode hot path."""
    out = decode(tokens)
    val = out.item()                  # sync: scalar readback
    host = np.asarray(out)            # sync: full-array transfer
    out.block_until_ready()           # sync: blocks the dispatch queue
    return val, host
