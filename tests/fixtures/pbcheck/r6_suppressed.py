"""R6 fixture (suppressed): an exempted public function."""


# pbcheck: disable=R6 (generated shim; name is the documentation)
def undocumented(x):
    return x + 1
