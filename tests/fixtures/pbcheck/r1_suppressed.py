"""R1 fixture (suppressed): a deliberate read of the donated buffer."""
import jax

step = jax.jit(lambda cache, tok: (tok, cache), donate_argnums=(0,))


def decode_loop(cache, tok):
    """Reads the donated arg on purpose (host-side dict, not a buffer)."""
    out, new_cache = step(cache, tok)
    stale = cache["k"]  # pbcheck: disable=R1 (host dict, not a device buffer)
    return out, new_cache, stale
