"""R3 fixture (clean): every shared access goes through the lock."""
import threading


class Engine:
    """Same shape as the violating fixture but lock-disciplined."""

    def __init__(self):
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self.rounds = []

    def start(self):
        """Spawn the fill thread."""
        t = threading.Thread(target=self._run, daemon=True)
        t.start()

    def _run(self):
        while not self._stop.is_set():
            self.load()

    def load(self):
        """One fill round (locked)."""
        with self._lock:
            self.rounds.append(1)

    def status(self):
        """Locked read of the shared list."""
        with self._lock:
            return len(self.rounds)
