"""R5 fixture (clean): every kind handled, every mode in vocabulary."""

CHAOS_KINDS = ("crash", "partial_crash", "rejoin")


class Metrics:
    """Recovery-metrics sink with the asserted mode vocabulary."""

    def on_recovery(self, mode, t):
        """Record one recovery of the given mode at time ``t``."""
        assert mode in ("migrate", "reprefill", "repartition")


def apply_chaos(ev, metrics):
    """Dispatch one chaos event, exhaustively over CHAOS_KINDS."""
    if ev.kind == "crash":
        metrics.on_recovery("migrate", 0.0)
    elif ev.kind == "partial_crash":
        metrics.on_recovery("reprefill", 0.0)
    elif ev.kind == "rejoin":
        metrics.on_recovery("repartition", 0.0)
