"""R1 fixture: the donated cache is read after the donating call."""
import jax

step = jax.jit(lambda cache, tok: (tok, cache), donate_argnums=(0,))


def decode_loop(cache, tok):
    """Donates ``cache`` to ``step``, then reads the dead buffer."""
    out, new_cache = step(cache, tok)
    stale = cache["k"]          # use-after-donate: buffer already freed
    return out, new_cache, stale
