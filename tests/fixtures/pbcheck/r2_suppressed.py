"""R2 fixture (suppressed): the one designed transfer, documented."""
import jax
import numpy as np

decode = jax.jit(lambda tok: tok + 1)


def hot_step(tokens):
    """One deliberate host transfer with an inline justification."""
    out = decode(tokens)
    # pbcheck: disable=R2 (the one designed transfer per step)
    host = np.asarray(out)
    return host
