"""R4 fixture (suppressed): a deliberate one-shot jit, documented."""
import jax


def calibrate(xs):
    """One-off calibration path; the single retrace is intended."""
    # pbcheck: disable=R4 (one-shot calibration; compiles exactly once)
    return jax.jit(lambda x: x + 1)(xs)
