"""Overlapped cold start (paper §4.2–4.3): serving first tokens while
layers are still loading.

The paper's claims as executable invariants:
  * the async background fill (thread or generator-stepped) runs
    concurrently with decode and changes NOTHING about the tokens;
  * the strategy switch mid-decode never retraces the decode step;
  * per-round wall-clock/byte accounting stamps time_to_ready and
    time_to_fully_loaded;
  * the shard_map pipeline prefill (multi-device, subprocess) produces the
    same tokens and hands its cache to the fused decode without a retrace;
  * a partial chain that doesn't cover the model refuses to serve.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core.engine import EngineError, PipeBoostEngine, generate
from repro.models import transformer as T

KEY = jax.random.PRNGKey(23)

# dense GQA / MoE / SSM stacks (the hybrid pipelines via the functional
# engine only, covered in test_system)
ARCHS = [("qwen3-1.7b", {"n_layers": 8}),
         ("qwen2-moe-a2.7b", {"n_layers": 8}),
         ("mamba2-780m", {"n_layers": 8})]


def _setup(arch, red):
    cfg = get_arch(arch).reduced(**red)
    params = T.init_params(cfg, KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0,
                                          min(cfg.vocab_size, 250))}
    return cfg, params, batch


@pytest.mark.parametrize("arch,red", ARCHS)
def test_async_fill_overlap_equals_fully_loaded(arch, red):
    """Token streams are identical whether decode overlaps the background
    fill THREAD or the model was fully resident before the first token."""
    cfg, params, batch = _setup(arch, red)
    e1 = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    e1.load_round()
    assert e1.ready and not e1.fully_loaded
    # background fill with a pause per round so it genuinely interleaves
    # with the decode loop below
    e1.start_fill(interval_s=0.005)
    early = generate(e1, batch, 8)
    e1.stop_fill()
    while e1.load_round():      # finish whatever the thread didn't
        pass
    assert e1.fully_loaded

    e2 = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    while e2.load_round():
        pass
    full = generate(e2, batch, 8)
    np.testing.assert_array_equal(np.asarray(early), np.asarray(full))


def test_fill_steps_accounting():
    """The generator-step driver yields per-round wall/byte accounting and
    stamps the two cold-start milestones."""
    cfg, params, batch = _setup("qwen3-1.7b", {"n_layers": 8})
    eng = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    assert eng.time_to_ready is None and eng.time_to_fully_loaded is None
    rounds = list(eng.fill_steps())
    assert eng.fully_loaded
    assert len(rounds) == 4                      # 4 segments, 1/round/device
    assert [r.idx for r in rounds] == [0, 1, 2, 3]
    assert all(r.bytes > 0 and r.wall_s >= 0 for r in rounds)
    assert all(len(r.segments) == 4 for r in rounds)   # one per device
    assert eng.time_to_ready is not None
    assert eng.time_to_fully_loaded is not None
    assert eng.time_to_fully_loaded >= eng.time_to_ready
    st = eng.status()
    assert st.loaded_bytes == st.total_bytes > 0
    assert st.n_rounds == 4
    cs = eng.cold_start_stats()
    assert cs["loaded_bytes"] == cs["total_bytes"]
    assert sum(cs["round_bytes"]) == cs["total_bytes"]


def test_segments_per_round_budget():
    """The configurable fill budget loads several segments per device per
    round (fewer, bigger rounds — same bytes)."""
    cfg, params, _ = _setup("qwen3-1.7b", {"n_layers": 8})
    e1 = PipeBoostEngine(cfg, params, n_devices=4, max_len=64,
                         segments_per_round=2)
    rounds = list(e1.fill_steps())
    assert len(rounds) == 2 and e1.fully_loaded
    e2 = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    rounds2 = list(e2.fill_steps())
    assert len(rounds2) == 4
    assert sum(r.bytes for r in rounds) == sum(r.bytes for r in rounds2)
    # one-off budget override on a plain round
    e3 = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    e3.load_round(budget=4)
    assert e3.fully_loaded


def test_strategy_switch_mid_decode_never_retraces():
    """Decode keeps its single compilation across prefill-during-load,
    background fill completion, and the §4.3.3 strategy switch."""
    cfg, params, batch = _setup("qwen3-1.7b", {"n_layers": 8})
    eng = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    eng.load_round()
    logits = eng.prefill(batch)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        tok = jnp.argmax(eng.decode(tok), -1).astype(jnp.int32)
    while eng.load_round():
        pass
    assert eng.maybe_switch_strategy(request_rate=1.0)
    for _ in range(3):
        tok = jnp.argmax(eng.decode(tok), -1).astype(jnp.int32)
    cs = eng.compile_stats()
    if cs["decode_compiles"] >= 0:       # -1 = private API unavailable
        assert cs["decode_compiles"] == 1, cs


def test_prefill_refuses_without_viable_chain():
    """No viable chain (mid-load gap or crash hole) => EngineError, on both
    the standard and the pipeline-enabled dispatch path."""
    cfg, params, batch = _setup("qwen3-1.7b", {"n_layers": 8})
    eng = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    with pytest.raises(EngineError):
        eng.prefill(batch)
    # crash a device holding a unique segment mid-load: chain breaks again
    eng.load_round()
    eng.crash([1])
    assert eng.chain() is None
    with pytest.raises(EngineError):
        eng.prefill(batch)


def test_enable_pipeline_prefill_gates():
    """The shard_map dispatch refuses on 1-device backends and hybrid
    stacks instead of mis-lowering (falls back to the single path)."""
    cfg, params, batch = _setup("qwen3-1.7b", {"n_layers": 8})
    eng = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
    assert not eng.enable_pipeline_prefill()       # 1 XLA device here
    hy = get_arch("recurrentgemma-2b").reduced(n_layers=6)
    ph = T.init_params(hy, KEY)
    ehy = PipeBoostEngine(hy, ph, n_devices=2, max_len=64)
    assert not ehy.enable_pipeline_prefill()       # hybrid stack
    # the refusal leaves the standard path fully functional
    eng.load_round()
    eng.prefill(batch)
    assert eng.prefill_backend_used == "single"


_PIPE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import get_arch
    from repro.core.engine import PipeBoostEngine, generate
    from repro.models import transformer as T
    from repro.serving.engine import ServeRequest, ServingEngine, \\
        quantized_greedy

    for arch in ("qwen3-1.7b", "mamba2-780m"):
        cfg = get_arch(arch).reduced(n_layers=4, vocab_size=256)
        params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (4, 32), 0, 256)}
        # overlapped: pipeline prefill on the 1/N partial chain
        e1 = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
        assert e1.enable_pipeline_prefill()
        e1.load_round()
        assert e1.ready and not e1.fully_loaded
        toks1 = generate(e1, batch, 8)
        assert e1.prefill_backend_used == "pipeline"
        # baseline: fully loaded, standard lowering
        e2 = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
        while e2.load_round(): pass
        e2.maybe_switch_strategy(1.0)
        toks2 = generate(e2, batch, 8)
        assert e2.prefill_backend_used == "single"
        np.testing.assert_array_equal(np.asarray(toks1), np.asarray(toks2))
        # strategy switch mid-decode: same decode jit, no retrace
        while e1.load_round(): pass
        assert e1.maybe_switch_strategy(request_rate=1.0)
        e1.decode(toks1[:, -1])
        e1.prefill(batch)              # post-switch prefill -> single
        assert e1.prefill_backend_used == "single"
        e1.decode(toks1[:, -1])
        cs = e1.compile_stats()
        assert cs["decode_compiles"] in (-1, 1), cs
        assert cs["pipeline_prefill_compiles"] >= 1

    # serving engine dispatch: admissions mid-load lower through the
    # pipeline fn, post-switch admissions through the single lowering,
    # token streams identical to a single-lowering engine
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=4, vocab_size=256)
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = PipeBoostEngine(cfg, params, n_devices=4, max_len=128)
    assert eng.enable_pipeline_prefill(n_micro=1)
    eng.load_round()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 256, size=12 + i) for i in range(8)]

    def serve(pipeline):
        srv = ServingEngine(cfg, params, n_slots=4, max_len=128)
        srv.batcher.sampler = quantized_greedy
        if pipeline:
            srv.batcher.set_pipeline_prefill(
                eng.serving_pipeline_prefill,
                fits=eng.serving_pipeline_fits)
            srv.batcher.prefill_backend = (
                lambda: "pipeline" if eng.strategy == "pipeline"
                else "single")
        for i, p in enumerate(prompts[:4]):
            srv.submit(ServeRequest(i, p, max_new_tokens=4))
        srv.run()
        if pipeline:
            assert srv.batcher.n_prefill_pipeline >= 4, \\
                srv.batcher.n_prefill_pipeline
            # background fill completes; the strategy switches
            while eng.load_round(): pass
            eng.maybe_switch_strategy(request_rate=1.0)
        n_pipe = srv.batcher.n_prefill_pipeline
        for i, p in enumerate(prompts[4:]):
            srv.submit(ServeRequest(10 + i, p, max_new_tokens=4))
        srv.run()
        if pipeline:   # post-switch admissions went through the single jit
            assert srv.batcher.n_prefill_pipeline == n_pipe
        return sorted((r.rid, tuple(r.generated)) for r in srv.completed)

    out_pipe = serve(pipeline=True)
    out_single = serve(pipeline=False)
    assert out_pipe == out_single, (out_pipe, out_single)
    print("PIPE_OK")
""")


@pytest.mark.slow
def test_pipeline_prefill_wiring_multi_device():
    """Subprocess (8 fake devices): the shard_map pipeline prefill serves
    the first tokens off the partial chain — engine and serving-engine
    dispatch — with bit-identical streams and no decode retrace."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _PIPE], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PIPE_OK" in r.stdout


def test_crash_during_background_fill_stops_thread_cleanly():
    """A crash while the fill thread is mid-round must stop the thread
    (no leak), land each LoadRound's accounting exactly once (bytes sum
    consistent, round indices strictly increasing), and leave the
    survivors' load plan consistent for recovery."""
    cfg, params, batch = _setup("qwen3-1.7b", {"n_layers": 8})
    for trial in range(3):                   # race window varies per run
        eng = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
        eng.load_round()
        eng.start_fill(interval_s=0.002)
        eng.crash([3])
        assert not eng.fill_running          # joined, not leaked
        # accounting landed exactly once per completed round
        idxs = [r.idx for r in eng.rounds]
        assert idxs == sorted(set(idxs)), idxs
        booked = sum(r.bytes for r in eng.rounds)
        per_dev = {}
        with eng._load_lock:
            for d in eng.devices:
                per_dev[d.idx] = sum(eng.plan.segments[s].bytes
                                     for s in d.loaded)
        assert booked == sum(per_dev.values()), (trial, booked, per_dev)
        # survivors recover onto a viable chain and serve
        eng.recover()
        toks = generate(eng, batch, 4)
        ref = PipeBoostEngine(cfg, params, n_devices=4, max_len=64)
        ref.load_round()
        np.testing.assert_array_equal(np.asarray(toks),
                                      np.asarray(generate(ref, batch, 4)))


def test_cluster_server_crash_mid_fill_consistent_accounting():
    """ClusterServer.crash() during an engine-level background fill: the
    fill thread stops, cold-start accounting stays consistent, and the
    whole-server drain hands back the in-flight work."""
    from repro.cluster import ClusterConfig, ClusterServer
    from repro.serving.engine import ServeRequest
    cfg, params, _ = _setup("qwen3-1.7b", {"n_layers": 8})
    server = ClusterServer(0, cfg, params,
                           ClusterConfig(n_devices=4, n_slots=2))
    server.tick(0.0)                         # ready: chain after 1 round
    assert server.state == "serving" and not server.engine.fully_loaded
    rng = np.random.default_rng(11)
    req = ServeRequest(0, rng.integers(0, 250, size=10), max_new_tokens=8)
    server.submit(req)
    server.tick(0.05)
    # a thread-driven fill runs concurrently with the crash (the router's
    # tick-driven fill is synchronous; the thread is the racy variant)
    server.engine.start_fill(interval_s=0.002)
    drained = server.crash()
    assert server.state == "down"
    assert not server.engine.fill_running
    assert drained and drained[0].rid == 0
    cs = server.engine.cold_start_stats()
    assert cs["n_rounds"] == len(cs["round_bytes"])
    assert sum(cs["round_bytes"]) >= 0
    # the reboot path still works after the mid-fill crash
    server.rejoin()
    now = 0.1
    while server.state == "loading":
        server.tick(now)
        now += 0.05
    assert server.state == "serving"
