"""Peer-to-peer multicast scale-out: scheduler units, chaos round-trip,
and tick==event parity under load-stage faults.

Load-bearing invariants:
* ``MulticastManager`` is deterministic pure bookkeeping: the same
  (register/advance/remove) call sequence produces the same transfers,
  deliveries, and stats — no wall clock, no RNG.
* Mid-transfer failover is resume, never restart: a dependent of a
  crashed source keeps every fully-received segment, re-roots onto a
  surviving holder (bounded retry-with-backoff for orphaned segments),
  and degrades to a host fill only after ``max_retries``.
* The load-stage ``ChaosEvent`` kinds (``source_crash``/``fill_crash``)
  round-trip through the versioned JSON schema and replay token-exactly
  under both the tick and event cluster engines.

Everything here runs on fakes / the modeled ``SimServer`` fleet except
the final real-server smoke (one small JAX-backed router).
"""
import json
import os
from dataclasses import replace

import pytest

from repro.cluster import (Arrival, ChaosEvent, ChaosSchedule,
                           ClusterConfig, ClusterRouter, MulticastConfig,
                           MulticastManager, SimProfile, load_chaos,
                           random_chaos, save_chaos, sim_server_factory)
from repro.cluster.traces import (CHAOS_KINDS, CHAOS_SCHEMA_VERSIONS,
                                  LOAD_CHAOS_KINDS)
from repro.core.simulator import GPU_PAPER, host_bw_effective


# ---------------------------------------------------------------------------
# chaos schema: new kinds, versioned round-trip, clear errors
# ---------------------------------------------------------------------------

def test_load_kinds_are_chaos_kinds():
    assert set(LOAD_CHAOS_KINDS) <= set(CHAOS_KINDS)
    assert "source_crash" in LOAD_CHAOS_KINDS
    assert "fill_crash" in LOAD_CHAOS_KINDS


def test_chaos_roundtrip_v2(tmp_path):
    sched = ChaosSchedule([
        ChaosEvent(0.213, "source_crash", 0),
        ChaosEvent(0.413, "fill_crash", 2),
        ChaosEvent(1.213, "rejoin", 0),
    ])
    p = str(tmp_path / "chaos.json")
    save_chaos(p, sched)
    with open(p) as f:
        doc = json.load(f)
    assert doc["version"] == 2          # load-stage kinds bump the schema
    back = load_chaos(p)
    assert [(e.time, e.kind, e.server) for e in back] == \
        [(e.time, e.kind, e.server) for e in sched]


def test_chaos_legacy_kinds_save_as_v1(tmp_path):
    sched = ChaosSchedule([ChaosEvent(0.1, "crash", 0),
                           ChaosEvent(0.9, "rejoin", 0)])
    p = str(tmp_path / "chaos.json")
    save_chaos(p, sched)
    with open(p) as f:
        assert json.load(f)["version"] == 1
    assert len(load_chaos(p)) == 2


def test_chaos_unknown_version_error(tmp_path):
    p = str(tmp_path / "chaos.json")
    with open(p, "w") as f:
        json.dump({"version": 99, "events": []}, f)
    with pytest.raises(ValueError) as ei:
        load_chaos(p)
    msg = str(ei.value)
    assert "99" in msg and str(CHAOS_SCHEMA_VERSIONS) in msg


def test_chaos_unknown_kind_error_names_event(tmp_path):
    p = str(tmp_path / "chaos.json")
    with open(p, "w") as f:
        json.dump({"version": 2, "events": [
            {"time": 0.1, "kind": "crash", "server": 0},
            {"time": 0.2, "kind": "meteor_strike", "server": 1},
        ]}, f)
    with pytest.raises(ValueError) as ei:
        load_chaos(p)
    msg = str(ei.value)
    assert "#1" in msg and "meteor_strike" in msg and "crash" in msg


def test_random_chaos_load_faults_seeded_and_off_grid():
    kw = dict(horizon=4.0, n_servers=3, seed=5, load_fault_prob=1.0,
              rejoin_delay_s=1.0, tick_s=0.05)
    a = random_chaos(4, **kw)
    b = random_chaos(4, **kw)
    assert [(e.time, e.kind, e.server) for e in a] == \
        [(e.time, e.kind, e.server) for e in b]
    faults = [e for e in a if e.kind != "rejoin"]
    assert faults and all(e.kind in LOAD_CHAOS_KINDS for e in faults)
    # every fault pairs with a rejoin; times sit off the tick grid
    assert sum(1 for e in a if e.kind == "rejoin") == len(faults)
    for e in a:
        frac = (e.time / 0.05) % 1.0
        assert 1e-6 < frac < 1 - 1e-6, e.time


# ---------------------------------------------------------------------------
# manager units (fakes, no router, no JAX)
# ---------------------------------------------------------------------------

# easy-math hardware: host moves 100 B/s (aggregate == link, so one
# 100-byte segment costs exactly one 1-second advance), peers 1000 B/s
HW_UNIT = replace(GPU_PAPER, host_link_bw=100.0, host_agg_bw=100.0,
                  ici_bw=1000.0, hop_latency=0.0)


def test_topology_validation():
    with pytest.raises(ValueError):
        MulticastConfig(topology="mesh")
    assert MulticastConfig(topology="chain").effective_fanout == 1
    assert MulticastConfig(topology="tree").effective_fanout == 2
    assert MulticastConfig(topology="host").effective_fanout == 0


def test_host_bw_effective_contention():
    assert host_bw_effective(HW_UNIT, 1) == 100.0
    assert host_bw_effective(HW_UNIT, 4) == 25.0
    # never above the per-stream link even with spare aggregate
    wide = replace(HW_UNIT, host_agg_bw=1e6)
    assert host_bw_effective(wide, 1) == 100.0


def _drain(mgr, t0=0.0, dt=1.0, cap=100):
    """Advance until no receiver is pending; returns {sid: [segs]} in
    delivery order and the final time."""
    got, t = {}, t0
    for _ in range(cap):
        if not mgr.active:
            break
        for sid, segs in mgr.advance(t, dt).items():
            got.setdefault(sid, []).extend(segs)
        t += dt
    assert not mgr.active, "drain did not converge"
    return got, t


def test_bootstrap_single_host_root_then_relay():
    mgr = MulticastManager(MulticastConfig(topology="tree", hw=HW_UNIT))
    for sid in range(3):
        mgr.register_receiver(sid, [100] * 4)
    got, _ = _drain(mgr)
    st = mgr.stats()
    # everyone completes every segment exactly once, in index order
    assert all(got[sid] == [0, 1, 2, 3] for sid in range(3))
    # peers relay: strictly less host traffic than 3 full copies
    assert st["peer_segments"] > 0
    assert st["host_segments"] + st["peer_segments"] == 12
    assert st["host_bytes"] < 3 * 400
    assert st["host_fallbacks"] == 0 and st["reroots"] == 0


def test_host_topology_never_uses_peers():
    mgr = MulticastManager(MulticastConfig(topology="host", hw=HW_UNIT))
    for sid in range(2):
        mgr.register_receiver(sid, [100] * 2)
    _drain(mgr)
    st = mgr.stats()
    assert st["peer_bytes"] == 0 and st["peer_segments"] == 0
    assert st["host_segments"] == 4


def test_reroot_retry_ladder_then_host_fallback():
    # slow peers (50 B/s) so the first transfer is mid-flight when the
    # only source dies; its segments are seeded, so the orphaned receiver
    # walks the retry ladder before each graceful host fallback
    hw = replace(HW_UNIT, ici_bw=50.0)
    mgr = MulticastManager(MulticastConfig(
        topology="tree", hw=hw, max_retries=2, retry_backoff_s=0.1))
    mgr.register_source(99, [0, 1, 2, 3])
    mgr.register_receiver(0, [100] * 4)
    out = mgr.advance(0.0, 1.0)
    assert out == {}                    # seg0 in flight from the source
    st = mgr.stats()
    assert st["peer_bytes"] == pytest.approx(50.0)
    mgr.remove(99)                      # source dies mid-transfer
    assert mgr.stats()["reroots"] == 1
    got, _ = _drain(mgr, t0=1.0)
    st = mgr.stats()
    # resume semantics: each segment delivered exactly once, in order
    assert got[0] == [0, 1, 2, 3]
    # every segment was seeded-but-orphaned: 2 retries then a fallback
    assert st["retries"] == 8 and st["host_fallbacks"] == 4
    assert st["host_segments"] == 4 and st["peer_segments"] == 0


def test_receiver_crash_preserves_survivor_segments():
    mgr = MulticastManager(MulticastConfig(topology="chain", hw=HW_UNIT))
    mgr.register_receiver(0, [100] * 4)
    mgr.register_receiver(1, [100] * 4)
    mgr.advance(0.0, 2.0)               # root has segs 0-1, r1 relays
    r1_have = set(mgr.receivers[1].have)
    mgr.remove(1)                       # in-flight receiver crashes
    assert 1 not in mgr.receivers
    # the surviving root keeps its progress and still completes
    assert set(mgr.receivers[0].have) >= {0}
    got, _ = _drain(mgr, t0=2.0)
    assert sorted(set(mgr.receivers[0].have)) == [0, 1, 2, 3]
    assert r1_have <= {0, 1, 2, 3}


def test_eta_decreases_and_zeroes():
    mgr = MulticastManager(MulticastConfig(topology="tree", hw=HW_UNIT))
    mgr.register_receiver(0, [100] * 4)
    e0 = mgr.eta_s(0)
    assert e0 > 0 and mgr.eta_s(0, 2) < e0
    _drain(mgr)
    assert mgr.eta_s(0) == 0.0
    assert mgr.eta_s(123) == 0.0        # unknown sid: nothing pending


# ---------------------------------------------------------------------------
# fleet integration: sim servers, engines, rejoin
# ---------------------------------------------------------------------------

N_SPAWN = 4
PROF = SimProfile(ready_ticks=2, full_ticks=10, bytes_total=1 << 30,
                  n_segments=8)
HW_FLEET = replace(GPU_PAPER, host_agg_bw=GPU_PAPER.host_link_bw)


def _fleet(topology="tree"):
    ccfg = ClusterConfig(n_devices=1, n_slots=4, tick_s=0.05,
                         multicast=MulticastConfig(topology=topology,
                                                   hw=HW_FLEET))
    return ClusterRouter(None, None, n_servers=N_SPAWN, ccfg=ccfg,
                         server_factory=sim_server_factory(PROF),
                         materialize_prompts=False)


def _trace(t0=2.0):
    # arrivals after the fill window isolate load-stage faults; the late
    # sentinel keeps run() alive until every background fill completes
    return [Arrival(t0 + 0.01 * i, prompt_len=8, max_new_tokens=4)
            for i in range(8)] + [Arrival(5.0, prompt_len=8,
                                          max_new_tokens=1)]


def test_multicast_one_host_read_vs_host_only():
    r_mc, r_host = _fleet("tree"), _fleet("host")
    assert len(r_mc.run(_trace(), engine="event")) == 9
    assert len(r_host.run(_trace(), engine="event")) == 9
    s_mc = r_mc.metrics.summary()
    s_host = r_host.metrics.summary()
    assert all(s.fully_loaded for s in r_mc.servers)
    # tree: ~one copy over host; host-only: one copy per server
    assert s_mc["multicast_host_bytes"] <= 1.25 * PROF.bytes_total
    assert s_host["multicast_host_bytes"] >= \
        0.99 * N_SPAWN * PROF.bytes_total
    assert s_mc["multicast_peer_bytes"] > 0
    assert s_host["multicast_peer_bytes"] == 0


def test_source_crash_tick_event_parity():
    chaos = [ChaosEvent(0.0685, "source_crash", 0)]
    runs = {}
    for name, eng in (("event", "event"), ("tick", "tick"),
                      ("event2", "event")):
        r = _fleet()
        done = r.run(_trace(), chaos=list(chaos), engine=eng)
        runs[name] = (r, {q.rid: tuple(q.generated) for q in done})
    assert runs["event"][1] == runs["tick"][1] == runs["event2"][1]
    s_evt = runs["event"][0].metrics.summary()
    s_tick = runs["tick"][0].metrics.summary()
    for k in ("n_completed", "multicast_reroots", "multicast_host_bytes",
              "multicast_peer_bytes", "multicast_host_fallbacks",
              "recovery_reprefill_tokens", "gpu_seconds"):
        assert abs(s_evt[k] - s_tick[k]) < 1e-9, (k, s_evt[k], s_tick[k])
    # the crash really hit the propagation tree, nothing re-prefilled,
    # and every surviving spawn still completed its copy
    assert s_evt["multicast_reroots"] >= 1
    assert s_evt["recovery_reprefill_tokens"] == 0.0
    assert s_evt["n_completed"] == 9
    assert all(s.fully_loaded for s in runs["event"][0].servers
               if s.state not in ("down", "retired"))


def test_fill_crash_executes_as_whole_server_crash():
    r = _fleet()
    done = r.run(_trace(), chaos=[ChaosEvent(0.0685, "fill_crash", 2)],
                 engine="event")
    assert len(done) == 9
    assert r.servers[2].state == "down"
    kinds = [k for _, k, _ in r.metrics.events]
    assert "crash" in kinds


def test_source_crash_then_rejoin_refills_via_multicast():
    chaos = [ChaosEvent(0.0685, "source_crash", 0),
             ChaosEvent(1.2185, "rejoin", 0)]
    r = _fleet()
    done = r.run(_trace(), chaos=chaos, engine="event")
    assert len(done) == 9
    s0 = r.servers[0]
    assert s0.state == "serving" and s0.fully_loaded
    summ = r.metrics.summary()
    assert summ["multicast_reroots"] >= 1
    # the reboot's copy came from the (now warm) survivors, not host:
    # aggregate host traffic stays well under two full copies
    assert summ["multicast_host_bytes"] < 2.0 * PROF.bytes_total


def test_chaos_script_with_load_kinds_replays_from_disk(tmp_path):
    p = str(tmp_path / "chaos.json")
    save_chaos(p, ChaosSchedule([ChaosEvent(0.0685, "source_crash", 0)]))
    streams = []
    for eng in ("event", "tick"):
        r = _fleet()
        done = r.run(_trace(), chaos=load_chaos(p), engine=eng)
        streams.append({q.rid: tuple(q.generated) for q in done})
    assert streams[0] == streams[1]


# ---------------------------------------------------------------------------
# real servers: engine-level peer delivery (small, JAX-backed)
# ---------------------------------------------------------------------------

def test_real_server_peer_fill_smoke():
    jax = pytest.importorskip("jax")
    from repro.configs.base import get_arch
    from repro.models import transformer as T

    cfg = get_arch("qwen3-1.7b").reduced(n_layers=2)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ccfg = ClusterConfig(n_devices=2, n_slots=2,
                         multicast=MulticastConfig(topology="tree"))
    router = ClusterRouter(cfg, params, n_servers=2, ccfg=ccfg)
    trace = [Arrival(0.001 * i, prompt_len=8, max_new_tokens=3)
             for i in range(3)]
    done = router.run(trace, engine="event")
    assert len(done) == 3
    assert all(s.engine.fully_loaded for s in router.servers)
    # at least one server filled from a peer, not host (tagged rounds)
    peer = sum(s.engine.peer_loaded_bytes() for s in router.servers)
    assert peer > 0
    assert router.metrics.summary()["multicast_peer_bytes"] > 0
