"""KV / state reconstruction invariants (paper §4.4.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_arch
from repro.core.kv_reconstruct import reconstruct_cache
from repro.models import transformer as T

KEY = jax.random.PRNGKey(21)


def _prefill(cfg, params, batch, max_len):
    return T.forward(cfg, params, batch, mode="prefill", max_len=max_len)


def _assert_cache_close(a, b, atol=2e-3):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=atol)


@pytest.mark.parametrize("arch,layers", [
    ("qwen3-1.7b", 6), ("mamba2-780m", 6), ("recurrentgemma-2b", 6),
])
def test_reconstruction_equals_fresh_prefill(arch, layers):
    cfg = get_arch(arch).reduced(n_layers=layers)
    params = T.init_params(cfg, KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 20), 0, cfg.vocab_size)}
    _, fresh = _prefill(cfg, params, batch, 48)

    # wipe a subset of layers' state, reconstruct, compare
    for missing in ([2], [0, 3], list(range(layers))):
        has = [i not in missing for i in range(layers)]
        damaged = jax.tree.map(jnp.copy, fresh)
        rebuilt, stats = reconstruct_cache(cfg, params, batch, damaged, has,
                                           max_len=48)
        _assert_cache_close(rebuilt, fresh)
        assert stats["full_prefill"] >= len(missing)


def test_reconstruction_reuses_kv(dense_cfg=None):
    """Layers with surviving KV must be recomputed via the Q-only path."""
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=6)
    params = T.init_params(cfg, KEY)
    batch = {"tokens": jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)}
    _, fresh = _prefill(cfg, params, batch, 32)
    has = [True, True, False, True, True, True]
    rebuilt, stats = reconstruct_cache(cfg, params, batch, fresh, has,
                                       max_len=32)
    assert stats["kv_reused"] == 2          # layers 0,1 (above stops at 2)
    assert stats["full_prefill"] == 1       # layer 2
    assert stats["layers_skipped"] >= 1     # layers 3.. untouched
    _assert_cache_close(rebuilt, fresh)


def test_decode_continues_after_reconstruction():
    """Decode tokens after reconstruction == decode without any crash."""
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=4)
    params = T.init_params(cfg, KEY)
    batch = {"tokens": jax.random.randint(KEY, (1, 12), 0, cfg.vocab_size)}
    lg, cache = _prefill(cfg, params, batch, 32)
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    toks = batch["tokens"]
    # two clean decode steps
    for _ in range(2):
        toks = jnp.concatenate([toks, tok[:, None]], 1)
        lg, cache = T.decode_step(cfg, params, {"tokens": tok}, cache)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
    # crash: rebuild everything from the merged sequence (paper Fig. 7b)
    rebuilt, _ = reconstruct_cache(cfg, params, {"tokens": toks},
                                   cache, [False] * 4, max_len=32)
    lg2, _ = T.decode_step(cfg, params, {"tokens": tok}, rebuilt)
    lg_ref, _ = T.decode_step(cfg, params, {"tokens": tok}, cache)
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(lg_ref),
                               atol=2e-3)


@settings(max_examples=15, deadline=None)
@given(mask=st.lists(st.booleans(), min_size=4, max_size=4),
       seed=st.integers(0, 50))
def test_property_any_mask_reconstructs(mask, seed):
    cfg = get_arch("qwen3-1.7b").reduced(n_layers=4)
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(seed), (1, 10),
                                          0, cfg.vocab_size)}
    _, fresh = _prefill(cfg, params, batch, 16)
    rebuilt, _ = reconstruct_cache(cfg, params, batch,
                                   jax.tree.map(jnp.copy, fresh),
                                   list(mask), max_len=16)
    _assert_cache_close(rebuilt, fresh)
