"""Cold-start / recovery simulator: the paper's orderings as invariants."""
import pytest

from repro.configs.base import get_arch
from repro.core import simulator as sim
from repro.core.simulator import GPU_PAPER, TPU_V5E

CFG = get_arch("pipeboost-opt-1.3b")
MISTRAL = get_arch("qwen3-1.7b")  # closest stand-in for a 7B-class dense


@pytest.mark.parametrize("hw", [GPU_PAPER, TPU_V5E])
@pytest.mark.parametrize("n", [2, 4, 8])
def test_cold_start_ordering(hw, n):
    """PipeBoost < ServerlessLLM < Transformers for every N and hw."""
    tr = sim.simulate_cold_start(CFG, hw, n, "transformers")
    sl = sim.simulate_cold_start(CFG, hw, n, "serverlessllm")
    pb = sim.simulate_cold_start(CFG, hw, n, "pipeboost")
    assert pb.ttft < sl.ttft < tr.ttft
    assert pb.t_ready < pb.t_full        # background fill continues


def test_ttft_reduction_in_paper_band():
    """Paper §5.2: 30%-47% vs ServerlessLLM on 2-4 GPU setups."""
    for n in (2, 4):
        sl = sim.simulate_cold_start(CFG, GPU_PAPER, n, "serverlessllm")
        pb = sim.simulate_cold_start(CFG, GPU_PAPER, n, "pipeboost")
        red = 1 - pb.ttft / sl.ttft
        assert 0.25 < red < 0.60, (n, red)


def test_loading_dominates_ttft():
    """Paper §3.1: model loading dominates cold-start TTFT (~95% for 7B+
    models; smaller for 1.3B where prefill is relatively larger)."""
    big = get_arch("qwen2.5-14b")
    for strat in ("serverlessllm", "pipeboost"):
        r = sim.simulate_cold_start(big, GPU_PAPER, 2, strat)
        load = r.breakdown["load_ckpt_dram"] + r.breakdown["load_params"]
        thresh = 0.85 if strat == "serverlessllm" else 0.7
        assert load / r.ttft > thresh, (strat, load / r.ttft)
        assert load > 4 * r.breakdown["prefill"]
    r = sim.simulate_cold_start(CFG, GPU_PAPER, 2, "serverlessllm")
    load = r.breakdown["load_ckpt_dram"] + r.breakdown["load_params"]
    assert load / r.ttft > 0.6


def test_more_devices_faster_pipeboost_only():
    """Paper Fig. 13: PipeBoost TTFT falls with device count; full-copy
    loaders do not improve."""
    pb = [sim.simulate_cold_start(CFG, GPU_PAPER, n, "pipeboost").ttft
          for n in (1, 2, 4)]
    assert pb[2] < pb[1] < pb[0]
    sl = [sim.simulate_cold_start(CFG, GPU_PAPER, n, "serverlessllm").ttft
          for n in (1, 2, 4)]
    assert sl[2] >= sl[0] * 0.95


def test_lora_overhead_small():
    """Paper §5.3: LoRA adds ~<6% TTFT."""
    base = sim.simulate_cold_start(MISTRAL, GPU_PAPER, 2, "pipeboost")
    lora = sim.simulate_cold_start(MISTRAL, GPU_PAPER, 2, "pipeboost",
                                   lora_rank=16)
    assert (lora.ttft - base.ttft) / base.ttft < 0.08


def test_recovery_pp_faster_than_full():
    """Paper Fig. 15: ~50% recovery-time cut vs full restart."""
    pp = sim.simulate_loading_failure(MISTRAL, GPU_PAPER, 4, failed=[1, 2],
                                      mode="pp")
    full = sim.simulate_loading_failure(MISTRAL, GPU_PAPER, 4,
                                        failed=[1, 2], mode="full")
    assert pp.recovery_time < full.recovery_time
    assert pp.ttft < full.ttft
    cut = 1 - pp.recovery_time / full.recovery_time
    assert 0.25 < cut < 0.75, cut


def test_recovery_improves_with_devices():
    """Paper Fig. 16: recovery TTFT falls as device count grows."""
    ttfts = [sim.simulate_loading_failure(MISTRAL, GPU_PAPER, n, failed=[0],
                                          mode="pp").ttft
             for n in (2, 3, 4)]
    assert ttfts[2] < ttfts[0]


def test_inference_crash_timeline():
    """Paper Fig. 17: PP recovery dips but never halts; full recovery
    flatlines then resumes."""
    pp = sim.simulate_inference_failure(MISTRAL, GPU_PAPER, 4, mode="pp")
    full = sim.simulate_inference_failure(MISTRAL, GPU_PAPER, 4, mode="full")
    pp_min = min(thr for t, thr in pp if t > 6.0)
    full_min = min(thr for t, thr in full if t > 6.0)
    assert full_min == 0.0 and pp_min > 0.0   # pp never halts; full does
    # both recover eventually
    assert pp[-1][1] > 0 and full[-1][1] > 0
    # pp reaches its steady post-crash throughput no later than full
    pp_steady = pp[-1][1]
    full_steady = full[-1][1]
    t_pp = min(t for t, thr in pp if t > 6.0 and thr >= pp_steady * 0.99)
    t_full = min(t for t, thr in full
                 if t > 6.0 and thr >= full_steady * 0.99)
    assert t_pp <= t_full


def test_strategy_crossover():
    """Paper Fig. 6: single-replica beats pipeline at high request rates."""
    lo_pipe = sim.simulate_request_latency(CFG, GPU_PAPER, 4, rps=0.5,
                                           strategy="pipeline")
    lo_single = sim.simulate_request_latency(CFG, GPU_PAPER, 4, rps=0.5,
                                             strategy="single")
    hi_pipe = sim.simulate_request_latency(CFG, GPU_PAPER, 4, rps=50.0,
                                           strategy="pipeline")
    hi_single = sim.simulate_request_latency(CFG, GPU_PAPER, 4, rps=50.0,
                                             strategy="single")
    assert hi_single["mean"] < hi_pipe["mean"]
    # and the gap widens with rate (paper: "gap widens as rates increase")
    gap_hi = hi_pipe["mean"] - hi_single["mean"]
    gap_lo = lo_pipe["mean"] - lo_single["mean"]
    assert gap_hi >= gap_lo


from hypothesis import given, settings, strategies as st
from repro.core.simulator import HwModel


@settings(max_examples=40, deadline=None)
@given(
    link=st.floats(1e9, 40e9),
    agg=st.floats(20e9, 400e9),
    ssd=st.floats(2e9, 20e9),
    n=st.sampled_from([2, 4, 8]),
)
def test_property_pipeboost_never_slower(link, agg, ssd, n):
    """For ANY hardware point, PipeBoost's critical-path loading is never
    slower than full-copy loading, and TTFT is monotone non-increasing in
    device count (the paper's core claim, hardware-independent)."""
    hw = HwModel(ssd_bw=ssd, host_link_bw=link, host_agg_bw=agg)
    pb = sim.simulate_cold_start(CFG, hw, n, "pipeboost")
    slm = sim.simulate_cold_start(CFG, hw, n, "serverlessllm")
    assert pb.ttft <= slm.ttft + 1e-9
    if n > 2:
        pb_small = sim.simulate_cold_start(CFG, hw, n // 2, "pipeboost")
        assert pb.ttft <= pb_small.ttft + 0.05  # hop overheads may add ms
    # background fill never finishes before the serve-ready point
    assert pb.t_full >= pb.t_ready - 1e-9


# ---------------------------------------------------------------------------
# host bandwidth sharing + state-tier resurrect pricing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hw", [GPU_PAPER, TPU_V5E])
def test_host_bw_effective_exact(hw):
    """N concurrent streams split host_agg_bw, each capped at its link:
    the exact min(link, agg/N) law, checked for both hardware models."""
    for n in (1, 2, 4, 8, 64):
        eff = sim.host_bw_effective(hw, n)
        assert eff == min(hw.host_link_bw, hw.host_agg_bw / n)
    # link-limited regime: few streams each saturate their own link
    assert sim.host_bw_effective(hw, 1) == hw.host_link_bw
    # aggregate-limited regime: enough streams to oversubscribe the host
    many = int(hw.host_agg_bw / hw.host_link_bw) * 4
    assert sim.host_bw_effective(hw, many) == hw.host_agg_bw / many


def test_host_bw_effective_monotone_and_guarded():
    """More streams never get MORE per-stream bandwidth, and degenerate
    concurrent counts (0, negative) behave like a single stream."""
    prev = None
    for n in range(1, 33):
        eff = sim.host_bw_effective(GPU_PAPER, n)
        if prev is not None:
            assert eff <= prev + 1e-9
        prev = eff
    assert sim.host_bw_effective(GPU_PAPER, 0) == \
        sim.host_bw_effective(GPU_PAPER, 1)
    assert sim.host_bw_effective(GPU_PAPER, -3) == \
        sim.host_bw_effective(GPU_PAPER, 1)


@settings(max_examples=40, deadline=None)
@given(
    link=st.floats(1e9, 40e9),
    agg=st.floats(20e9, 400e9),
    n=st.integers(1, 128),
)
def test_property_host_bw_conservation(link, agg, n):
    """For ANY hardware point: no stream exceeds its link, and the fleet
    of N streams never collectively exceeds the aggregate path."""
    hw = HwModel(host_link_bw=link, host_agg_bw=agg)
    eff = sim.host_bw_effective(hw, n)
    assert eff <= link + 1e-9
    assert n * eff <= agg * (1 + 1e-9) or eff == link


def test_state_resurrect_time_prices_contention():
    """Resurrect pulls pay the fixed setup plus bytes over the SHARED
    host path: single-stream matches link rate, concurrent pulls slow
    down once the aggregate saturates, zero bytes cost only the setup."""
    nb = 1 << 30
    t1 = sim.state_resurrect_time(nb, GPU_PAPER)
    assert t1 == pytest.approx(GPU_PAPER.transfer_fixed_s
                               + nb / GPU_PAPER.host_link_bw)
    # enough concurrency to push per-stream below the link rate
    many = int(GPU_PAPER.host_agg_bw / GPU_PAPER.host_link_bw) + 1
    assert sim.state_resurrect_time(nb, GPU_PAPER, many) > t1
    assert sim.state_resurrect_time(0, GPU_PAPER) == \
        GPU_PAPER.transfer_fixed_s
    # bigger bundles take longer; monotone in payload
    assert sim.state_resurrect_time(2 * nb, GPU_PAPER) > t1
